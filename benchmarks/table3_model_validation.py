"""Paper Table 3: performance-model validation.

Reports, per kernel configuration:
  * the analytic columns (naive instruction limit, L1/streaming bandwidth
    limits) -- these reproduce the published numbers exactly;
  * our scheduler's simulated throughput under the paper simulator's
    OOO-renaming semantics vs the paper's simulated and observed values;
  * the strictly in-order-safe schedule (WAR=1) -- deployable as-emitted;
  * the steady-state pipelined estimate (cross-iteration overlap).
"""

from __future__ import annotations

import time
from typing import List

from repro.core.perfmodel import PAPER_TABLE3, analyze
from repro.core.synth import PAPER_CONFIGS


def run() -> List[str]:
    rows = []
    errs_analytic = []
    errs_sim = []
    for cfg in PAPER_CONFIGS:
        t0 = time.perf_counter()
        e = analyze(cfg)
        us = (time.perf_counter() - t0) * 1e6
        p = PAPER_TABLE3[cfg.name]
        errs_analytic += [abs(e.naive_mstencil - p[0]) / p[0],
                          abs(e.l1_bw_mstencil - p[2]) / p[2],
                          abs(e.streaming_bw_mstencil - p[3]) / p[3]]
        sim_err = (e.simulated_mstencil - p[1]) / p[1]
        errs_sim.append(sim_err)
        rows.append(
            f"table3.{cfg.name},{us:.1f},"
            f"naive={e.naive_mstencil:.2f}(paper {p[0]}) "
            f"sim={e.simulated_mstencil:.2f}(paper {p[1]}; {sim_err:+.1%}) "
            f"strict={e.simulated_strict_mstencil:.2f} "
            f"piped={e.pipelined_mstencil:.2f} "
            f"l1bw={e.l1_bw_mstencil:.2f}(paper {p[2]}) "
            f"strm={e.streaming_bw_mstencil:.2f}(paper {p[3]}) "
            f"pred_l1={e.predicted_l1:.2f}(obs {p[5]}) "
            f"pred_strm={e.predicted_streaming:.2f}(obs {p[7]})")
    rows.append(f"table3.analytic_max_err,0.0,"
                f"{max(errs_analytic):.2%} (naive+bandwidth columns)")
    rows.append(f"table3.sim_err_range,0.0,"
                f"[{min(errs_sim):+.1%}, {max(errs_sim):+.1%}] vs paper "
                f"greedy (ours >= paper on "
                f"{sum(1 for x in errs_sim if x >= -0.001)}/{len(errs_sim)})")
    # the headline claim: 27-pt at 85%+ of arithmetic peak in-L1
    from repro.core.synth import StencilConfig
    e27 = analyze(StencilConfig(27, "mm", 2, 3))
    rows.append(f"table3.27pt_peak_fraction,0.0,"
                f"{e27.predicted_l1 / 62.96:.1%} of arithmetic peak "
                f"(paper: 85%)")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
