"""Paper Table 1: resource usage per stencil of mutate-mutate and load-copy."""

from __future__ import annotations

import time
from typing import List

from repro.core.synth import StencilConfig, synth_stencil


def run() -> List[str]:
    rows = []
    t0 = time.perf_counter()
    for kernel, expect in (("mm", (2, 1, 3, 6, 3, 1)),
                           ("lc", (1, 1, 4, 4, 4, 2))):
        k = synth_stencil(StencilConfig(3, kernel, 1, 1))
        c = k.counts
        got = (c.loads, c.stores, c.fpu, c.lsu_cycles, c.fpu, c.input_regs)
        ok = got == expect
        rows.append(f"table1.{kernel},"
                    f"{(time.perf_counter() - t0) * 1e6:.1f},"
                    f"ld={c.loads} st={c.stores} fpu={c.fpu} "
                    f"ld-st-cyc={c.lsu_cycles} regs={c.input_regs} "
                    f"match_paper={ok}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
