"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md sect. Roofline).

Per (arch x shape x mesh) cell, derives the three roofline terms from the
compiled dry-run (all per-chip; the SPMD module IS the per-chip program):

  compute    = HLO_FLOPs_per_chip / 197 TFLOP/s   (bf16 peak, TPU v5e)
  memory     = HLO_bytes_per_chip / 819 GB/s      (HBM bandwidth)
  collective = collective_bytes_per_chip / 50 GB/s (ICI per-link)

plus MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference), the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips), the dominant term,
the roofline-fraction score MODEL_FLOPS / (chips * peak * t_dominant), and a
what-would-move-it note.  HLO quantities are trip-count-corrected
(launch/hlo_analysis.py).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "dryrun")


def terms(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n = rec["n_devices"]
    t_c = rec["hlo_flops"] / PEAK_FLOPS
    t_m = rec["hlo_bytes"] / HBM_BW
    # analytic floor: every argument/output byte (params, optimizer state,
    # caches, batch) moves through HBM at least once per step
    mem = rec.get("memory") or {}
    floor = (mem.get("argument_bytes", 0) + mem.get("output_bytes", 0))
    t_m = max(t_m, floor / HBM_BW)
    t_x = rec["collective_bytes_total"] / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    useful = rec["model_flops"] / max(rec["hlo_flops"] * n, 1.0)
    frac = rec["model_flops"] / (n * PEAK_FLOPS * max(dom[1], 1e-12))
    move = {
        "compute": "cut redundant HLO flops (remat policy, MoE capacity "
                   "factor, fused attention kernel)",
        "memory": "raise arithmetic intensity: larger per-chip batch, "
                  "bf16 cache/states, fuse bandwidth-bound chains",
        "collective": "re-shard to cut resharding collectives; overlap "
                      "via latency-hiding scheduler; int8-compress DP "
                      "all-reduce",
    }[dom[0]]
    return {"t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
            "dominant": dom[0], "t_dominant": dom[1], "useful_ratio": useful,
            "roofline_fraction": frac, "move": move}


def load_records(art_dir: str = ART_DIR) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(art_dir: str = ART_DIR) -> List[str]:
    rows = []
    recs = load_records(art_dir)
    if not recs:
        return ["roofline.no_artifacts,0.0,run repro.launch.dryrun first"]
    n_ok = n_skip = n_err = 0
    for rec in recs:
        tag = f"{rec['arch']}:{rec['shape']}:{rec['mesh']}"
        if rec.get("status") == "skipped":
            n_skip += 1
            rows.append(f"roofline.{tag},0.0,SKIP ({rec['reason'][:60]})")
            continue
        if rec.get("status") != "ok":
            n_err += 1
            rows.append(f"roofline.{tag},0.0,ERROR {rec.get('error','')[:80]}")
            continue
        n_ok += 1
        t = terms(rec)
        extra = ""
        if rec["shape"] in ("decode_32k", "long_500k"):
            # serving cells: the roofline bound on throughput is the batch
            # over the dominant (memory) term -- tok/s, not flop fraction
            from repro.models.common import SHAPES
            bsz = SHAPES[rec["shape"]].global_batch
            extra = f" decode_tok/s<={bsz / max(t['t_dominant'], 1e-12):.0f}"
        rows.append(
            f"roofline.{tag},{t['t_dominant']*1e6:.1f},"
            f"compute={t['t_compute']*1e3:.2f}ms "
            f"memory={t['t_memory']*1e3:.2f}ms "
            f"collective={t['t_collective']*1e3:.2f}ms "
            f"dom={t['dominant']} "
            f"useful={t['useful_ratio']:.2f} "
            f"roofline_frac={t['roofline_fraction']:.3f}{extra}")
    rows.append(f"roofline.summary,0.0,ok={n_ok} skipped={n_skip} "
                f"errors={n_err}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
