"""CI benchmark-regression gate: fresh ``BENCH_stencil.json`` vs baseline.

Usage::

    python benchmarks/check_regression.py BASELINE.json FRESH.json [--tol 0.05]

Compares the *modeled* quantities the engine's perf claims rest on -- the
per-path ``bytes_per_point_*`` keys, the per-spec plan op counts
(``shifts``, ``flops``, ``ops``, ``peak_live``) under every plan kind, and
the cost-driven ``selection`` table: each spec's chosen plan must not
regress its modeled cycles/point by more than ``tol``, and a selection
that *flips* to a different ``(kind, unroll)`` must be justified by the
fresh cost table (the new choice modeled no slower than the baseline's
choice costs now), and (schema v5) the sweeps-aware ``sweeps`` table: the
chosen (fused / wavefront / chained) mode's modeled bytes/point must not
regress beyond ``tol`` and a mode flip must be consistent with the fresh
race (feasibility, then bytes, then time), and (schema v7) the multi-axis
grid's modeled per-axis halo-exchange bytes/point -- and fails (exit 1)
when any
fresh value regresses more than ``tol`` (5% default) above the committed
baseline, or when a baseline key disappeared.  Rows present only in the
fresh run (new specs, new sweep configurations) are reported as "new, not
gated yet" notes, never failures -- growth is not a regression.
Timing rows are deliberately ignored (CI runners are too noisy to gate on
wall clock); the modeled numbers are deterministic, so any drift is a real
code change that must be justified by refreshing the committed baseline in
the same PR.  Improvements (fresh < baseline) always pass, with a note.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

# (json section, per-entry numeric keys gated "higher is a regression")
GATED_PLAN_KEYS = ("shifts", "flops", "ops", "peak_live")


def _flatten(doc: Dict) -> Dict[str, float]:
    """Flat ``section/name[/kind]/key -> value`` map of the gated numbers."""
    flat: Dict[str, float] = {}
    for path_name, keys in (doc.get("paths") or {}).items():
        for k, v in keys.items():
            if k.startswith("bytes_per_point") and isinstance(v, (int, float)):
                flat[f"paths/{path_name}/{k}"] = float(v)
    for spec_name, kinds in (doc.get("plans") or {}).items():
        for kind, desc in kinds.items():
            for k in GATED_PLAN_KEYS:
                if isinstance(desc.get(k), (int, float)):
                    flat[f"plans/{spec_name}/{kind}/{k}"] = float(desc[k])
    guard = doc.get("guard") or {}
    if isinstance(guard.get("bytes_per_point_f32"), (int, float)):
        # schema v6: the default guard policy's modeled check traffic
        flat["guard/bytes_per_point_f32"] = float(guard["bytes_per_point_f32"])
    sharded = doc.get("sharded") or {}
    for ax, v in (sharded.get("exchange_bytes_per_point") or {}).items():
        # schema v7: the multi-axis grid's modeled per-axis halo-exchange
        # traffic at the benchmark's reference geometry
        if isinstance(v, (int, float)):
            flat[f"sharded/exchange_bytes_per_point/{ax}"] = float(v)
    return flat


def _selection_checks(baseline: Dict, fresh: Dict,
                      tol: float) -> Tuple[List[str], List[str]]:
    """Gate the cost-driven selection table (schema v4).

    Two failure modes per spec: the chosen plan's modeled cycles/point
    regressed beyond ``tol``, or the selection flipped to a ``(kind,
    unroll)`` that the *fresh* cost table rates slower than what the
    baseline's choice costs now (a flip the model itself argues against --
    a selection-logic bug, not a model change)."""
    failures, notes = [], []
    bsel = baseline.get("selection") or {}
    fsel = fresh.get("selection") or {}
    for name, b in sorted(bsel.items()):
        f = fsel.get(name)
        if f is None:
            failures.append(f"selection/{name}: present in baseline but "
                            f"missing from the fresh run")
            continue
        b_cpp, f_cpp = b["cycles_per_point"], f["cycles_per_point"]
        if f_cpp > b_cpp * (1.0 + tol) + 1e-12:
            failures.append(
                f"selection/{name}: chosen plan's modeled cycles/point "
                f"{b_cpp:g} -> {f_cpp:g} (+{(f_cpp / b_cpp - 1) * 100:.1f}%, "
                f"limit +{tol:.0%})")
        elif f_cpp < b_cpp:
            notes.append(f"selection/{name}: modeled cycles/point improved "
                         f"{b_cpp:g} -> {f_cpp:g}")
        b_choice = (b["kind"], b["unroll"])
        f_choice = (f["kind"], f["unroll"])
        if f_choice != b_choice:
            old_now = next((c["cycles_per_point"] for c in f["candidates"]
                            if (c["kind"], c["unroll"]) == b_choice), None)
            if old_now is not None and f_cpp > old_now + 1e-6:
                failures.append(
                    f"selection/{name}: flipped {b_choice} -> {f_choice} "
                    f"but the fresh cost table rates the old choice faster "
                    f"({old_now:g} vs {f_cpp:g} cycles/point)")
            else:
                notes.append(f"selection/{name}: choice moved {b_choice} -> "
                             f"{f_choice} (consistent with the fresh cost "
                             f"table)")
    for name in sorted(set(fsel) - set(bsel)):
        notes.append(f"selection/{name}: new spec, not gated yet")
    return failures, notes


def _sweeps_checks(baseline: Dict, fresh: Dict,
                   tol: float) -> Tuple[List[str], List[str]]:
    """Gate the sweeps-aware mode-selection table (schema v5).

    Per ``spec/s`` entry: the chosen (fused / wavefront / chained) mode's
    modeled bytes/point must not regress beyond ``tol``, and a *mode flip*
    must be one the fresh race itself argues for -- the old mode, priced by
    the fresh candidate table, must not beat the new choice on (bytes,
    time).  Fresh-only entries (new specs / new ``s``) are notes, not
    failures."""
    failures, notes = [], []
    bsw = baseline.get("sweeps") or {}
    fsw = fresh.get("sweeps") or {}
    for name, b in sorted(bsw.items()):
        f = fsw.get(name)
        if f is None:
            failures.append(f"sweeps/{name}: present in baseline but "
                            f"missing from the fresh run")
            continue
        b_bpp, f_bpp = b.get("bytes_per_point"), f.get("bytes_per_point")
        if isinstance(b_bpp, (int, float)) and isinstance(f_bpp, (int, float)):
            if f_bpp > b_bpp * (1.0 + tol) + 1e-12:
                failures.append(
                    f"sweeps/{name}: chosen mode's modeled bytes/point "
                    f"{b_bpp:g} -> {f_bpp:g} "
                    f"(+{(f_bpp / b_bpp - 1) * 100:.1f}%, limit +{tol:.0%})")
            elif f_bpp < b_bpp:
                notes.append(f"sweeps/{name}: modeled bytes/point improved "
                             f"{b_bpp:g} -> {f_bpp:g}")
        if f.get("mode") != b.get("mode"):
            old = next((c for c in f.get("candidates") or []
                        if c.get("mode") == b.get("mode")), None)
            worse = False
            if old is not None and f_bpp is not None:
                o_bpp = old.get("bytes_per_point")
                o_tpp = old.get("time_per_point")
                f_tpp = f.get("time_per_point")
                worse = (o_bpp is not None and f_bpp > o_bpp + 1e-12) or (
                    o_bpp is not None and abs(f_bpp - o_bpp) <= 1e-12
                    and o_tpp is not None and f_tpp is not None
                    and f_tpp > o_tpp + 1e-15)
            if worse:
                failures.append(
                    f"sweeps/{name}: flipped {b.get('mode')} -> "
                    f"{f.get('mode')} but the fresh race rates the old "
                    f"mode better ({o_bpp:g} B/pt vs {f_bpp:g})")
            else:
                notes.append(f"sweeps/{name}: mode moved {b.get('mode')} "
                             f"-> {f.get('mode')} (consistent with the "
                             f"fresh race)")
    for name in sorted(set(fsw) - set(bsw)):
        notes.append(f"sweeps/{name}: new sweep configuration, not gated "
                     f"yet")
    return failures, notes


def compare(baseline: Dict, fresh: Dict,
            tol: float) -> Tuple[List[str], List[str]]:
    """Returns (failures, notes)."""
    base, new = _flatten(baseline), _flatten(fresh)
    failures, notes = _selection_checks(baseline, fresh, tol)
    sw_fail, sw_notes = _sweeps_checks(baseline, fresh, tol)
    failures.extend(sw_fail)
    notes.extend(sw_notes)
    if not base:
        failures.append("baseline has no gated keys (paths/plans sections "
                        "missing?) -- refusing to vacuously pass")
        return failures, notes
    for key, b in sorted(base.items()):
        if key not in new:
            failures.append(f"{key}: present in baseline ({b:g}) but "
                            f"missing from the fresh run")
            continue
        n = new[key]
        limit = b * (1.0 + tol)
        if n > limit + 1e-12:
            failures.append(f"{key}: {b:g} -> {n:g} "
                            f"(+{(n / b - 1) * 100:.1f}%, limit +{tol:.0%})")
        elif n < b:
            notes.append(f"{key}: improved {b:g} -> {n:g}")
    for key in sorted(set(new) - set(base)):
        notes.append(f"{key}: new key ({new[key]:g}), not gated yet")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--tol", type=float, default=0.05,
                    help="allowed fractional regression (default 0.05)")
    args = ap.parse_args(argv)
    loaded = []
    for role, path in (("baseline", args.baseline), ("fresh", args.fresh)):
        try:
            with open(path) as f:
                doc = json.load(f)
        except OSError as e:
            print(f"check_regression: cannot read {role} file {path!r}: "
                  f"{e.strerror or e}")
            return 2
        except json.JSONDecodeError as e:
            print(f"check_regression: {role} file {path!r} is not valid "
                  f"JSON (truncated or corrupt?): {e}")
            return 2
        if not isinstance(doc, dict):
            print(f"check_regression: {role} file {path!r} holds a JSON "
                  f"{type(doc).__name__}, expected an object")
            return 2
        loaded.append(doc)
    baseline, fresh = loaded
    bs, fs = baseline.get("schema"), fresh.get("schema")
    if bs != fs:
        print(f"note: schema changed {bs!r} -> {fs!r}; gating on the "
              f"shared keys")
    failures, notes = compare(baseline, fresh, args.tol)
    for n in notes:
        print(f"  ok: {n}")
    if failures:
        print(f"benchmark regression gate FAILED ({len(failures)} "
              f"violation(s) vs {args.baseline}):")
        for f_ in failures:
            print(f"  REGRESSION {f_}")
        print("if intentional, refresh the committed baseline "
              "(PYTHONPATH=src:. python benchmarks/run.py "
              "stencil_throughput) in this PR and justify the change")
        return 1
    print(f"benchmark regression gate passed: {len(_flatten(baseline))} "
          f"gated keys within +{args.tol:.0%} of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
