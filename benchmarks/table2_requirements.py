"""Paper Table 2: computational requirements of every unroll-and-jam config."""

from __future__ import annotations

import time
from typing import List

from repro.core.perfmodel import PAPER_TABLE2
from repro.core.synth import PAPER_CONFIGS, synth_stencil


def run() -> List[str]:
    rows = []
    n_match = 0
    for cfg in PAPER_CONFIGS:
        t0 = time.perf_counter()
        k = synth_stencil(cfg)
        us = (time.perf_counter() - t0) * 1e6
        c = k.counts
        paper = PAPER_TABLE2[cfg.name]
        bps = (c.read_bytes + c.write_bytes) / cfg.stencils_per_iter
        got = (len(k.rows), cfg.stencils_per_iter, c.input_regs,
               c.result_regs, c.weight_regs, c.loads, c.stores, c.fpu,
               round(bps, 3))
        # input-register column deviates for 7-lc (documented, DESIGN.md s8)
        cmp_idx = [0, 1, 3, 4, 5, 6, 7]
        match = all(abs(got[i] - paper[i]) < 0.01 for i in cmp_idx) \
            and abs(bps - paper[8]) < 0.01
        n_match += match
        rows.append(f"table2.{cfg.name},{us:.1f},"
                    f"streams={len(k.rows)} ld={c.loads} st={c.stores} "
                    f"fpu={c.fpu} regs={c.input_regs} B/st={bps:.1f} "
                    f"match_paper={match}")
    rows.append(f"table2.summary,0.0,{n_match}/{len(PAPER_CONFIGS)} rows "
                f"match the published table")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
