"""Benchmark harness: one module per paper table + system benches.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).  A
sub-benchmark that raises is reported as a ``FAILED`` row and the process
exits non-zero -- a crashed run can't green-wash the CI bench step.  Each
sub-benchmark also runs under a wall-clock timeout (``BENCH_TIMEOUT_S``
seconds, default 900) so a hung benchmark produces a FAILED row and exit 1
instead of stalling CI until the job-level kill.
"""

from __future__ import annotations

import os
import signal
import sys
import traceback

DEFAULT_TIMEOUT_S = 900


class BenchTimeout(Exception):
    pass


def _timeout_s() -> int:
    try:
        return max(0, int(os.environ.get("BENCH_TIMEOUT_S",
                                         DEFAULT_TIMEOUT_S)))
    except ValueError:
        return DEFAULT_TIMEOUT_S


def _run_rows(name: str, mod, timeout_s: int) -> None:
    """Print the module's rows, raising :class:`BenchTimeout` if the module
    exceeds the wall-clock budget.  SIGALRM-based, so it interrupts a
    genuinely wedged benchmark (not just one that checks a flag); on
    platforms without SIGALRM the benchmark runs unbounded."""
    use_alarm = timeout_s > 0 and hasattr(signal, "SIGALRM")
    if use_alarm:
        def _on_alarm(signum, frame):
            raise BenchTimeout(
                f"{name} exceeded BENCH_TIMEOUT_S={timeout_s}s")
        prev = signal.signal(signal.SIGALRM, _on_alarm)
        signal.alarm(timeout_s)
    try:
        for row in mod.run():
            print(row)
    finally:
        if use_alarm:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, prev)


def main() -> None:
    from benchmarks import (roofline, stencil_throughput, table1_subkernels,
                            table2_requirements, table3_model_validation)
    mods = [("table1", table1_subkernels), ("table2", table2_requirements),
            ("table3", table3_model_validation),
            ("stencil_throughput", stencil_throughput),
            ("roofline", roofline)]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    if only and only not in [n for n, _ in mods]:
        print(f"unknown benchmark {only!r}; available: "
              f"{[n for n, _ in mods]}", file=sys.stderr)
        sys.exit(2)
    timeout_s = _timeout_s()
    failed = []
    print("name,us_per_call,derived")
    for name, mod in mods:
        if only and only != name:
            continue
        try:
            _run_rows(name, mod, timeout_s)
        except SystemExit:
            raise                      # an explicit gate verdict: keep it
        except BenchTimeout as exc:
            failed.append(name)
            print(f"{name},nan,FAILED: timeout: {exc}")
            print(f"benchmark {name} timed out after {timeout_s}s",
                  file=sys.stderr)
        except Exception as exc:       # noqa: BLE001 - report, then fail
            failed.append(name)
            print(f"{name},nan,FAILED: {type(exc).__name__}: {exc}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        print(f"benchmark failures: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
