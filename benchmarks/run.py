"""Benchmark harness: one module per paper table + system benches.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (roofline, stencil_throughput, table1_subkernels,
                            table2_requirements, table3_model_validation)
    mods = [("table1", table1_subkernels), ("table2", table2_requirements),
            ("table3", table3_model_validation),
            ("stencil_throughput", stencil_throughput),
            ("roofline", roofline)]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in mods:
        if only and only != name:
            continue
        for row in mod.run():
            print(row)


if __name__ == "__main__":
    main()
