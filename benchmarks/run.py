"""Benchmark harness: one module per paper table + system benches.

Prints ``name,us_per_call,derived`` CSV (one line per measurement).  A
sub-benchmark that raises is reported as a ``FAILED`` row and the process
exits non-zero -- a crashed run can't green-wash the CI bench step.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (roofline, stencil_throughput, table1_subkernels,
                            table2_requirements, table3_model_validation)
    mods = [("table1", table1_subkernels), ("table2", table2_requirements),
            ("table3", table3_model_validation),
            ("stencil_throughput", stencil_throughput),
            ("roofline", roofline)]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    if only and only not in [n for n, _ in mods]:
        print(f"unknown benchmark {only!r}; available: "
              f"{[n for n, _ in mods]}", file=sys.stderr)
        sys.exit(2)
    failed = []
    print("name,us_per_call,derived")
    for name, mod in mods:
        if only and only != name:
            continue
        try:
            for row in mod.run():
                print(row)
        except SystemExit:
            raise                      # an explicit gate verdict: keep it
        except Exception as exc:       # noqa: BLE001 - report, then fail
            failed.append(name)
            print(f"{name},nan,FAILED: {type(exc).__name__}: {exc}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        print(f"benchmark failures: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
