"""Figures 8-10 analogue: measured stencil throughput over problem sizes.

On this CPU container we measure the jitted XLA stencil (the ref oracle) --
wall-clock Mstencil/s across the cache hierarchy, the same experiment shape
as the paper's Figures 8-10 -- and verify the Pallas kernel (interpret mode)
against it at each size.  TPU numbers come from running the same harness on
real hardware.

The tail rows exercise the unified stencil engine: batched execution, fused
multi-sweep Jacobi (``s`` operator applications per HBM round-trip), a
direct-vs-cse-vs-factored plan comparison (the paper's synthesized schedule
vs the naive one, with each plan's static shift/flop counts and pass list),
a streamed-vs-replicated path comparison (the paper's plane-streaming
kernel vs the halo-replicated one, with each path's modeled bytes/point and
achieved HBM bandwidth), the radius-2 builtins (star13 / box125: streaming
still ~2 x itemsize/point where the replicated path pays 6 x), a j-tiled
run at a size where the untiled N x P slab exceeds the VMEM budget
(previously a hard wall), a 2-device halo-exchange ``shard_map`` run
(forced host-platform devices, in a subprocess so this process keeps its
single-device view), and an 8-device 2x2x2 process-grid pair timing the
serialized vs compute/communication-overlap schedules.

Besides the ``name,us_per_call,derived`` text rows, every measurement is
recorded as a dict and the whole run is dumped to ``BENCH_stencil.json``
(path overridable via ``$BENCH_STENCIL_JSON``; schema v7: per-spec plan op
counts with ``radius`` + ``pass_list`` columns, per-path modeled
bytes/point at radius 1 and 2, a per-spec ``selection`` section recording
the cost-driven compiler's chosen ``(pass_list, unroll)``, its modeled
cycles/point, and the losing candidates -- including a
variable-coefficient variant -- a ``sweeps`` section recording the
sweeps-aware autotuner's (fused / wavefront / chained) verdict per
``(spec, s)`` with each mode's modeled bytes/point and time, a ``guard``
section recording the default :class:`GuardPolicy`'s modeled check traffic
as a fraction of the streaming path, and a ``sharded`` section recording
the multi-axis grid's modeled per-axis halo-exchange bytes/point at the
``GRID_REF`` geometry) -- which CI uploads as an artifact.

``python benchmarks/stencil_throughput.py --quick`` runs only the
streamed-vs-replicated rows plus the cost-model gates (exit 1 if the
streamed path's modeled bytes/point exceeds 2.5 x itemsize -- at radius 1
*and* radius 2 -- or regresses above the replicated path, for the
reference 27-point and star13 configurations; if the temporal
wavefront's modeled bytes/point exceeds ``1.25 * 2 * itemsize / s`` for
stencil27 at s=4; or if the default guard policy's modeled check traffic
reaches 10% of the streaming path's bytes/point) -- the fast CI guard.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perfmodel import streaming_roofline
from repro.kernels import (GuardPolicy, autotune_engine, autotune_sweeps,
                           bytes_per_point, compile_plan,
                           exchange_bytes_per_point,
                           guard_bytes_per_point, stencil_apply,
                           stencil_ref, stencil_sweep_driver, stencil3_ref,
                           stencil7_ref, stencil27, stencil27_ref)
from repro.kernels.stencil_engine.autotune import HBM_BW, VPU_FLOPS

# The guard-overhead gate's canonical geometry: a production-depth i axis
# (the sampled checks amortize over M; REF_CONFIG's m=16 is a kernel-stress
# shape, not a serving one).
GUARD_GATE_M = 128

SIZES = (14, 30, 62, 126)

_RECORDS: List[Dict] = []


def _row(name: str, usec: float, derived: str, **fields) -> str:
    """Format one text row and mirror it into the JSON record list."""
    _RECORDS.append({"name": name, "us_per_call": round(usec, 1), **fields})
    return f"{name},{usec:.1f},{derived}"


def _time(fn, *args, reps: int = 5) -> float:
    fn(*args).block_until_ready()          # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


SELECTION_SPECS = ("stencil3", "stencil7", "stencil27", "star13", "box125",
                   "stencil27_var")

# The ``sharded`` section's reference grid: a 64^3 f32 domain on a 2x2x2
# process grid at s=2 (radius-1 deep halo = 2 planes/face).  The modeled
# per-axis exchange bytes/point are deterministic, so the regression gate
# holds them like the path/plan numbers.
GRID_REF = dict(shape=(64, 64, 64), grid=(2, 2, 2), halo=2, itemsize=4,
                sweeps=2)

# (spec, s) configurations recorded in the ``sweeps`` section: the
# sweeps-aware autotuner's (fused / wavefront / chained) race at the
# reference shape, including a radius-2 and a red-black entrant.
SWEEPS_CONFIGS = (("stencil27", 2), ("stencil27", 4), ("star13", 4),
                  ("stencil27_redblack", 2))


def _sweeps_doc(name: str, s: int) -> Dict:
    """The sweeps-aware autotuner's verdict for ``(name, s)`` at the
    reference shape: chosen mode/path/blocks, its modeled bytes/point and
    time/point per sweep, and the full candidate table it beat."""
    m, n, p, itemsize = (REF_CONFIG[k] for k in ("m", "n", "p", "itemsize"))
    sel = autotune_sweeps(m, n, p, itemsize, s, compile_plan(name))
    return sel.describe()["selection"]


def _selection_doc(name: str) -> Dict:
    """The cost-driven compiler's choice for one spec (``_var`` suffix:
    the variable-coefficient spelling): chosen kind + pass list + unroll,
    its modeled cycles/point (and which core model produced it), and the
    full candidate table it beat."""
    from repro.kernels import get_stencil
    spec = get_stencil(name[:-len("_var")]).with_coef("var") \
        if name.endswith("_var") else get_stencil(name)
    cplan = compile_plan(spec)
    d = cplan.describe()
    return {"kind": cplan.kind, "unroll": cplan.unroll,
            "pass_list": d["pass_list"], "coef": cplan.spec.coef,
            "cycles_per_point": d["selection"]["cycles_per_point"],
            "source": d["selection"]["source"],
            "candidates": d["selection"]["candidates"]}


def write_json(path: Optional[str] = None,
               default: str = "BENCH_stencil.json") -> str:
    """Dump the recorded rows + per-spec plan op counts (with ``radius``,
    ``pass_list``, and ``bc`` columns) + per-path modeled bytes/point at
    radius 1 and 2 + the per-spec cost-driven ``selection`` table to
    ``path``.  ``default`` is the fallback when neither ``path`` nor
    ``$BENCH_STENCIL_JSON`` is set: the full run refreshes the committed
    ``BENCH_stencil.json`` regression baseline; the quick gate writes the
    gitignored ``BENCH_stencil.quick.json`` so a local ``--quick`` can't
    silently clobber the baseline with a partial record set."""
    path = path or os.environ.get("BENCH_STENCIL_JSON", default)
    import dataclasses as _dc
    itemsize = REF_CONFIG["itemsize"]
    g_bpp = guard_bytes_per_point(GuardPolicy(), itemsize, GUARD_GATE_M)
    g = GRID_REF
    locs = tuple(s // n for s, n in zip(g["shape"], g["grid"]))
    doc = {
        "schema": "bench_stencil/v7",
        "guard": {
            "default_policy": _dc.asdict(GuardPolicy()),
            "gate_m": GUARD_GATE_M,
            "bytes_per_point_f32": g_bpp,
            "fraction_of_stream": g_bpp / (2.0 * itemsize),
        },
        "plans": {name: {kind: compile_plan(name, kind).describe()
                         for kind in ("direct", "cse", "factored")}
                  for name in ("stencil27", "star13", "box125")},
        "selection": {name: _selection_doc(name)
                      for name in SELECTION_SPECS},
        "sweeps": {f"{name}/s{s}": _sweeps_doc(name, s)
                   for name, s in SWEEPS_CONFIGS},
        "sharded": {
            # schema v7: the multi-axis halo-exchange traffic model at the
            # GRID_REF geometry -- the j faces ship bare, the k faces carry
            # the j ghosts, the i faces carry both (the transitive
            # j -> k -> i exchange), so per-axis bytes/point is the number
            # the overlap scheduler has to hide for i and *cannot* hide for
            # j/k.  Deterministic, gated by check_regression like the
            # per-path bytes/point.
            "grid_ref": dict(g),
            "exchange_bytes_per_point": exchange_bytes_per_point(
                g["itemsize"], (g["halo"],) * 3, locs, sweeps=g["sweeps"]),
        },
        "paths": {p: {"bytes_per_point_f32": bytes_per_point(p, 4),
                      "bytes_per_point_f32_jtiled":
                          bytes_per_point(p, 4, j_tiled=True),
                      "bytes_per_point_f32_r2":
                          bytes_per_point(p, 4, radius=2),
                      "bytes_per_point_f32_r2_jtiled":
                          bytes_per_point(p, 4, j_tiled=True, radius=2)}
                  for p in ("stream", "replicate")},
        "rows": _RECORDS,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def run() -> List[str]:
    _RECORDS.clear()
    rows = []
    rng = np.random.default_rng(0)
    j27 = jax.jit(stencil27_ref)
    j7 = jax.jit(stencil7_ref)
    j3 = jax.jit(stencil3_ref)
    for n in SIZES:
        a = jnp.asarray(rng.standard_normal((n, n, n)), jnp.float32)
        w27 = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
        w7 = jnp.asarray(rng.uniform(0.1, 1, 4), jnp.float32)
        w3 = jnp.asarray(rng.uniform(0.1, 1, 2), jnp.float32)
        st = (n - 2) ** 3
        t = _time(j27, a, w27)
        rows.append(_row(f"stencil27.{n}^3", t * 1e6,
                         f"{st/t/1e6:.1f} Mstencil/s",
                         mstencil_per_s=st / t / 1e6))
        t = _time(j7, a, w7)
        rows.append(_row(f"stencil7.{n}^3", t * 1e6,
                         f"{st/t/1e6:.1f} Mstencil/s",
                         mstencil_per_s=st / t / 1e6))
        a2 = a.reshape(n * n, n)
        t = _time(j3, a2, w3)
        st3 = n * n * (n - 2)
        rows.append(_row(f"stencil3.{n}^3", t * 1e6,
                         f"{st3/t/1e6:.1f} Mstencil/s",
                         mstencil_per_s=st3 / t / 1e6))
    # Pallas kernel correctness at a bench size (interpret mode)
    n = 30
    a = jnp.asarray(rng.standard_normal((n + 2, n + 2, 128)), jnp.float32)
    w27 = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
    got = stencil27(a, w27, block_i=4)
    ref = stencil27_ref(a, w27)
    err = float(jnp.max(jnp.abs(got - ref)))
    rows.append(_row("stencil27.pallas_vs_ref", 0.0,
                     f"max_err={err:.2e} ok={err < 1e-4}",
                     max_err=err, ok=bool(err < 1e-4)))
    # beyond-paper MXU form: correctness + napkin speedup on the TPU target
    from repro.kernels import stencil27_mxu
    got_mxu = stencil27_mxu(a, w27, block_i=4)
    err_mxu = float(jnp.max(jnp.abs(got_mxu - ref)))
    p = a.shape[-1]
    vpu_t = 54.0 / 3e12              # ~54 VPU flops/pt at ~3 TFLOP/s
    mxu_t = 8.0 * p / 197e12 + 5.0 / 3e12   # 8P MXU flops + 5 VPU adds
    rows.append(_row("stencil27.mxu_vs_ref", 0.0,
                     f"max_err={err_mxu:.2e} ok={err_mxu < 1e-4} "
                     f"napkin_speedup_v5e={vpu_t/mxu_t:.1f}x (P={p})",
                     max_err=err_mxu, ok=bool(err_mxu < 1e-4),
                     napkin_speedup_v5e=vpu_t / mxu_t))
    rows.extend(_engine_rows(rng))
    rows.extend(_plan_rows(rng))
    rows.extend(_path_rows(rng))
    rows.extend(_sweeps_rows(rng))
    rows.extend(_radius_rows(rng))
    rows.extend(_bc_rows(rng))
    rows.append(_jtiled_row(rng))
    rows.append(_guard_row(rng))
    rows.extend(check_guard_model())
    rows.append(_sharded_row())
    rows.extend(_sharded_grid_rows())
    write_json()
    return rows


def run_quick() -> List[str]:
    """CI guard: only the streamed-vs-replicated rows + the cost-model and
    wavefront gates (no size sweep, no subprocess sharding)."""
    _RECORDS.clear()
    rng = np.random.default_rng(0)
    rows = _path_rows(rng)
    rows.extend(check_stream_model())
    rows.extend(check_wavefront_model())
    rows.extend(check_guard_model())
    write_json(default="BENCH_stencil.quick.json")
    return rows


def _engine_rows(rng) -> List[str]:
    """Engine-backed scenarios: batched and fused-sweep."""
    rows: List[str] = []
    b, m, n, p = 4, 16, 24, 128
    w = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
    a4 = jnp.asarray(rng.standard_normal((b, m, n, p)), jnp.float32)
    st = b * (m - 2) * (n - 2) * (p - 2)

    t = _time(lambda x: stencil_apply(x, w, "stencil27", block_i=4), a4)
    err = float(jnp.max(jnp.abs(stencil_apply(a4, w, "stencil27", block_i=4)
                                - stencil_ref(a4, w, "stencil27"))))
    rows.append(_row(f"engine27.batched.{b}x{m}x{n}x{p}", t * 1e6,
                     f"{st/t/1e6:.2f} Mstencil/s max_err={err:.2e} "
                     f"ok={err < 1e-4}",
                     mstencil_per_s=st / t / 1e6, max_err=err,
                     ok=bool(err < 1e-4)))

    a3 = a4[0]
    st1 = (m - 2) * (n - 2) * (p - 2)
    for s in (1, 2, 3):
        t = _time(lambda x, s=s: stencil_apply(x, w, "stencil27", block_i=4,
                                               sweeps=s), a3)
        err = float(jnp.max(jnp.abs(
            stencil_apply(a3, w, "stencil27", block_i=4, sweeps=s)
            - stencil_ref(a3, w, "stencil27", sweeps=s))))
        rows.append(_row(f"engine27.fused_s{s}.{m}^3-ish", t * 1e6,
                         f"{s*st1/t/1e6:.2f} Mstencil/s "
                         f"(sweeps x points / time) "
                         f"max_err={err:.2e} ok={err < 1e-4}",
                         sweeps=s, mstencil_per_s=s * st1 / t / 1e6,
                         max_err=err, ok=bool(err < 1e-4)))
    return rows


def _plan_rows(rng) -> List[str]:
    """Direct vs CSE vs factored schedules for stencil27 -- the paper's
    synthesized-vs-naive comparison, with each plan's static op counts."""
    rows: List[str] = []
    m, n, p = 16, 24, 128
    w = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((m, n, p)), jnp.float32)
    st = (m - 2) * (n - 2) * (p - 2)
    t_direct = None
    for kind in ("direct", "cse", "factored"):
        cplan = compile_plan("stencil27", kind)
        t = _time(lambda x, k=kind: stencil_apply(x, w, "stencil27",
                                                  block_i=4, plan=k), a)
        err = float(jnp.max(jnp.abs(
            stencil_apply(a, w, "stencil27", block_i=4, plan=kind)
            - stencil_ref(a, w, "stencil27", plan=kind))))
        t_direct = t_direct if t_direct is not None else t
        rows.append(_row(f"engine27.plan_{kind}.{m}x{n}x{p}", t * 1e6,
                         f"{st/t/1e6:.2f} Mstencil/s shifts={cplan.shifts} "
                         f"flops={cplan.flops} vs_direct={t_direct/t:.2f}x "
                         f"max_err={err:.2e} ok={err < 1e-4}",
                         plan=cplan.describe(), plan_kind=kind,
                         radius=list(cplan.spec.radius),
                         pass_list=list(cplan.passes),
                         mstencil_per_s=st / t / 1e6,
                         speedup_vs_direct=t_direct / t, max_err=err,
                         ok=bool(err < 1e-4)))
    return rows


def _radius_rows(rng) -> List[str]:
    """Radius-2 builtins (star13 / box125): streamed vs replicated with the
    radius-aware modeled bytes/point -- streaming stays ~2 x itemsize/point
    while the replicated path pays (2r+2) = 6 x -- plus parity against the
    reference."""
    rows: List[str] = []
    m, n, p, bi = 16, 24, 128, 4
    for name, wshape in (("star13", (3,)), ("box125", (3, 3, 3))):
        cplan = compile_plan(name)
        w = jnp.asarray(rng.uniform(0.1, 1, wshape), jnp.float32)
        a = jnp.asarray(rng.standard_normal((m, n, p)), jnp.float32)
        st = (m - 2) * (n - 2) * (p - 2)
        base = None
        for path in ("replicate", "stream"):
            bpp = bytes_per_point(path, 4, radius=2)
            t = _time(lambda x, pa=path: stencil_apply(
                x, w, name, block_i=bi, path=pa), a, reps=3)
            err = float(jnp.max(jnp.abs(
                stencil_apply(a, w, name, block_i=bi, path=path)
                - stencil_ref(a, w, name))))
            base = t if path == "replicate" else base
            rows.append(_row(
                f"engine_r2.{name}_{path}.{m}x{n}x{p}", t * 1e6,
                f"{st/t/1e6:.2f} Mstencil/s bytes_per_pt={bpp:.1f} "
                f"shifts={cplan.shifts} flops={cplan.flops} "
                f"vs_replicate={base/t:.2f}x max_err={err:.2e} "
                f"ok={err < 1e-3}",
                path=path, radius=list(cplan.spec.radius),
                pass_list=list(cplan.passes), bytes_per_point=bpp,
                plan=cplan.describe(), mstencil_per_s=st / t / 1e6,
                speedup_vs_replicate=base / t, max_err=err,
                ok=bool(err < 1e-3)))
    return rows


def _bc_rows(rng) -> List[str]:
    """Boundary-condition variants of the streamed 27-point kernel: the
    same plan and data movement under periodic (wrapped stream lead-in),
    neumann (mirror ghost fill), and dirichlet ghosts -- timed, and
    verified against the per-BC ``np.pad``-mode reference."""
    rows: List[str] = []
    m, n, p, bi = (REF_CONFIG[k] for k in ("m", "n", "p", "block_i"))
    w = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((m, n, p)), jnp.float32)
    for bc in ("clamp", "periodic", "neumann", "dirichlet"):
        name = "stencil27" if bc == "clamp" else f"stencil27_{bc}"
        # non-clamp BCs update every point; clamp leaves the ring fixed
        st = (m - 2) * (n - 2) * (p - 2) if bc == "clamp" else m * n * p
        t = _time(lambda x, nm=name: stencil_apply(
            x, w, nm, block_i=bi, path="stream"), a, reps=3)
        err = float(jnp.max(jnp.abs(
            stencil_apply(a, w, name, block_i=bi, path="stream")
            - stencil_ref(a, w, name))))
        rows.append(_row(
            f"engine27.bc_{bc}.{m}x{n}x{p}", t * 1e6,
            f"{st/t/1e6:.2f} Mstencil/s bc={bc} max_err={err:.2e} "
            f"ok={err < 1e-4}",
            bc=bc, mstencil_per_s=st / t / 1e6, max_err=err,
            ok=bool(err < 1e-4)))
    return rows


# Reference 27-point configuration for the streamed-vs-replicated
# comparison and the CI cost-model gate.
REF_CONFIG = dict(m=16, n=24, p=128, block_i=4, itemsize=4)


def _path_rows(rng) -> List[str]:
    """Streamed vs replicated data movement for stencil27 -- the paper's
    plane-streaming kernel (each input plane fetched once, halo carried in
    VMEM scratch) against the halo-replicated one, with each path's modeled
    bytes/point, roofline, and achieved HBM bandwidth."""
    rows: List[str] = []
    m, n, p, bi = (REF_CONFIG[k] for k in ("m", "n", "p", "block_i"))
    w = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((m, n, p)), jnp.float32)
    st = (m - 2) * (n - 2) * (p - 2)
    cplan = compile_plan("stencil27")
    itemsize = a.dtype.itemsize
    base = None
    for sweeps in (1, 2):
        for path in ("replicate", "stream"):
            bpp = bytes_per_point(path, itemsize, j_tiled=False,
                                  sweeps=sweeps)
            roof = streaming_roofline(bpp - itemsize / sweeps,
                                      itemsize / sweeps,
                                      (cplan.flops + cplan.shifts),
                                      HBM_BW, VPU_FLOPS)
            t = _time(lambda x, pa=path, s=sweeps: stencil_apply(
                x, w, "stencil27", block_i=bi, sweeps=s, path=pa), a)
            err = float(jnp.max(jnp.abs(
                stencil_apply(a, w, "stencil27", block_i=bi, sweeps=sweeps,
                              path=path)
                - stencil_ref(a, w, "stencil27", sweeps=sweeps))))
            moved = bpp * sweeps * m * n * p          # bytes per call
            gbps = moved / t / 1e9
            base = t if path == "replicate" else base
            rows.append(_row(
                f"engine27.path_{path}_s{sweeps}.{m}x{n}x{p}", t * 1e6,
                f"{sweeps*st/t/1e6:.2f} Mstencil/s "
                f"bytes_per_pt={bpp:.1f} achieved={gbps:.2f} GB/s "
                f"vs_replicate={base/t:.2f}x bound={roof.bound} "
                f"max_err={err:.2e} ok={err < 1e-4}",
                path=path, sweeps=sweeps, bytes_per_point=bpp,
                achieved_gbps=gbps, modeled_bound=roof.bound,
                mstencil_per_s=sweeps * st / t / 1e6,
                speedup_vs_replicate=base / t, max_err=err,
                ok=bool(err < 1e-4)))
    return rows


def _sweeps_rows(rng) -> List[str]:
    """Temporal-integration modes for ``s`` sweeps of stencil27: ``s``
    chained single-sweep calls (one HBM round-trip each) vs one fused
    ``sweeps=s`` call vs the temporal-wavefront pipeline, with each mode's
    modeled bytes/point, verified against the reference -- plus a
    red-black Gauss-Seidel run through the driver."""
    rows: List[str] = []
    m, n, p, itemsize = (REF_CONFIG[k] for k in ("m", "n", "p", "itemsize"))
    w = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((m, n, p)), jnp.float32)
    s = 4
    st = (m - 2) * (n - 2) * (p - 2)
    ref = stencil_ref(a, w, "stencil27", sweeps=s)
    for mode in ("chained", "fused", "wavefront"):
        bpp = (2.0 * itemsize if mode == "chained"
               else 2.0 * itemsize / s)
        t = _time(lambda x, mo=mode: stencil_sweep_driver(
            x, w, "stencil27", sweeps=s, mode=mo), a, reps=3)
        err = float(jnp.max(jnp.abs(stencil_sweep_driver(
            a, w, "stencil27", sweeps=s, mode=mode) - ref)))
        rows.append(_row(
            f"engine27.sweeps_{mode}_s{s}.{m}x{n}x{p}", t * 1e6,
            f"{s*st/t/1e6:.2f} Mstencil/s bytes_per_pt={bpp:.1f} "
            f"max_err={err:.2e} ok={err < 1e-4}",
            mode=mode, sweeps=s, bytes_per_point=bpp,
            mstencil_per_s=s * st / t / 1e6, max_err=err,
            ok=bool(err < 1e-4)))
    # red-black Gauss-Seidel ordering through the auto-raced driver
    t = _time(lambda x: stencil_sweep_driver(
        x, w, "stencil27_redblack", sweeps=2), a, reps=3)
    err = float(jnp.max(jnp.abs(
        stencil_sweep_driver(a, w, "stencil27_redblack", sweeps=2)
        - stencil_ref(a, w, "stencil27_redblack", sweeps=2))))
    rows.append(_row(
        f"engine27.sweeps_redblack_s2.{m}x{n}x{p}", t * 1e6,
        f"{2*st/t/1e6:.2f} Mstencil/s ordering=redblack max_err={err:.2e} "
        f"ok={err < 1e-4}",
        ordering="redblack", sweeps=2, mstencil_per_s=2 * st / t / 1e6,
        max_err=err, ok=bool(err < 1e-4)))
    return rows


def check_wavefront_model() -> List[str]:
    """The CI gate (satellite): the temporal wavefront for stencil27 at
    s=4 must model bytes/point within 1.25 x of the ideal
    ``2 * itemsize / s`` and the sweeps-aware autotuner must not fall back
    to the chained per-sweep round-trip.  Appends a gate row; raises
    ``SystemExit(1)`` on violation so the workflow fails."""
    itemsize = REF_CONFIG["itemsize"]
    m, n, p = (REF_CONFIG[k] for k in ("m", "n", "p"))
    s = 4
    sel = autotune_sweeps(m, n, p, itemsize, s, compile_plan("stencil27"))
    wf = [c for c in sel.candidates if c[0] == "wavefront"]
    wf_bpp = wf[0][4] if wf else float("inf")
    limit = 1.25 * (2 * itemsize / s)
    ok = wf_bpp <= limit and sel.mode != "chained"
    rows = [_row("engine27.wavefront_gate", 0.0,
                 f"wavefront={wf_bpp:.2f} B/pt limit={limit:.2f} s={s} "
                 f"auto_mode={sel.mode} ok={ok}",
                 wavefront_bytes_per_point=wf_bpp, limit=limit, sweeps=s,
                 auto_mode=sel.mode, ok=bool(ok))]
    if not ok:
        print("\n".join(rows))
        write_json(default="BENCH_stencil.quick.json")
        raise SystemExit(
            f"stencil wavefront gate failed: stencil27 s={s} wavefront "
            f"modeled {wf_bpp} bytes/point (limit {limit}), auto mode "
            f"{sel.mode!r}")
    return rows


def check_guard_model() -> List[str]:
    """The CI gate (guarded-execution PR): the *default* guard policy's
    modeled check traffic -- :func:`guard_bytes_per_point`, the sampled
    NaN + invariant checks sharing one gathered strip per sampled plane --
    must cost < 10% of the streaming path's ``2 * itemsize`` bytes/point at
    the canonical serving depth ``m = GUARD_GATE_M``.  Appends a gate row;
    raises ``SystemExit(1)`` on violation so the workflow fails."""
    itemsize = REF_CONFIG["itemsize"]
    policy = GuardPolicy()
    g_bpp = guard_bytes_per_point(policy, itemsize, GUARD_GATE_M)
    stream = 2.0 * itemsize
    frac = g_bpp / stream
    ok = frac < 0.10
    rows = [_row("engine27.guard_gate", 0.0,
                 f"guard={g_bpp:.3f} B/pt stream={stream:.1f} B/pt "
                 f"fraction={frac:.3f} limit=0.10 m={GUARD_GATE_M} "
                 f"sample={policy.sample} ok={ok}",
                 guard_bytes_per_point=g_bpp,
                 stream_bytes_per_point=stream, fraction=frac,
                 gate_m=GUARD_GATE_M, sample=policy.sample, ok=bool(ok))]
    if not ok:
        print("\n".join(rows))
        write_json(default="BENCH_stencil.quick.json")
        raise SystemExit(
            f"stencil guard-overhead gate failed: default policy models "
            f"{g_bpp} bytes/point = {frac:.1%} of the streaming path's "
            f"{stream} (limit 10%) at m={GUARD_GATE_M}")
    return rows


def _guard_row(rng) -> str:
    """Measured guard overhead: the default sampled policy vs ``guard="off"``
    on the reference shape (interpret-mode wall clock is indicative only --
    the modeled fraction in ``check_guard_model`` is the gated number)."""
    m, n, p = (REF_CONFIG[k] for k in ("m", "n", "p"))
    a = jnp.asarray(rng.integers(-4, 5, size=(m, n, p)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
    t_off = _time(lambda x: stencil_apply(x, w, "stencil27"), a, reps=3)
    policy = GuardPolicy()
    t_on = _time(lambda x: stencil_apply(x, w, "stencil27", guard=policy),
                 a, reps=3)
    g_bpp = guard_bytes_per_point(policy, 4, GUARD_GATE_M)
    frac = g_bpp / (2.0 * 4)
    return _row(
        "engine27.guard_overhead", t_on * 1e6,
        f"off={t_off * 1e6:.1f}us on={t_on * 1e6:.1f}us "
        f"modeled_check_bytes={g_bpp:.3f} B/pt "
        f"({frac:.1%} of stream @ m={GUARD_GATE_M})",
        us_off=t_off * 1e6, us_on=t_on * 1e6,
        guard_bytes_per_point=g_bpp, modeled_fraction=frac)


def check_stream_model() -> List[str]:
    """The CI gate (satellite): for the reference 27-point configuration the
    streamed path must model <= 2.5 x itemsize bytes/point at sweeps=1 and
    never regress above the replicated path -- and the same bound must hold
    at radius 2 (star13), where the replicated path pays 6 x itemsize.
    Appends gate rows; raises ``SystemExit(1)`` on violation so the
    workflow fails."""
    itemsize = REF_CONFIG["itemsize"]
    m, n, p = (REF_CONFIG[k] for k in ("m", "n", "p"))
    rows: List[str] = []
    failures: List[str] = []
    for label, name, radius in (("engine27.model_gate", "stencil27", 1),
                                ("engine_r2.model_gate", "star13", 2)):
        stream = bytes_per_point("stream", itemsize, radius=radius)
        rep = bytes_per_point("replicate", itemsize, radius=radius)
        path, bi, bj = autotune_engine(m, n, p, itemsize,
                                       plan=compile_plan(name))
        ok = (stream <= 2.5 * itemsize) and (stream <= rep) \
            and path == "stream"
        rows.append(_row(label, 0.0,
                         f"stream={stream:.1f} replicate={rep:.1f} B/pt "
                         f"limit={2.5 * itemsize:.1f} radius={radius} "
                         f"auto_path={path} ok={ok}",
                         stream_bytes_per_point=stream, radius=radius,
                         replicate_bytes_per_point=rep, auto_path=path,
                         ok=bool(ok)))
        if not ok:
            failures.append(
                f"{name} (radius {radius}): streamed bytes/point {stream} "
                f"vs replicated {rep} (limit {2.5 * itemsize}), auto path "
                f"{path!r}")
    if failures:
        # surface the diagnostics the gate exists for: the gate rows and the
        # measured rows recorded so far still reach stdout + the artifact
        print("\n".join(rows))
        write_json(default="BENCH_stencil.quick.json")
        raise SystemExit("stencil cost-model gate failed: "
                         + "; ".join(failures))
    return rows


def _jtiled_row(rng) -> str:
    """A size whose full N x P slab exceeds the VMEM budget: the cost model
    must pick a j-tiled blocking (previously a hard wall) and the result
    must still match the reference."""
    m, n, p = 4, 2048, 128
    cplan = compile_plan("stencil27")
    path, bi, bj = autotune_engine(m, n, p, 4, sweeps=1, plan=cplan)
    w = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((m, n, p)), jnp.float32)
    st = (m - 2) * (n - 2) * (p - 2)
    t = _time(lambda x: stencil_apply(x, w, "stencil27"), a, reps=3)
    err = float(jnp.max(jnp.abs(stencil_apply(a, w, "stencil27")
                                - stencil_ref(a, w, "stencil27"))))
    return _row(f"engine27.jtiled.{m}x{n}x{p}", t * 1e6,
                f"{st/t/1e6:.2f} Mstencil/s path={path} blocks=({bi},{bj}) "
                f"max_err={err:.2e} ok={bj is not None and err < 1e-4}",
                path=path, block_i=bi, block_j=bj,
                mstencil_per_s=st / t / 1e6,
                max_err=err, ok=bool(bj is not None and err < 1e-4))


def _sharded_row() -> str:
    """Time the 2-device halo-exchange path on forced host devices."""
    code = """
        import time
        import jax, numpy as np, jax.numpy as jnp
        from repro.kernels import stencil_apply, stencil_sharded
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((16, 24, 128)), jnp.float32)
        w = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
        mesh = jax.make_mesh((2,), ("data",))
        run = lambda: stencil_sharded(a, w, "stencil27", mesh=mesh,
                                      sweeps=2).block_until_ready()
        run()                                   # compile + warm
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter(); run()
            best = min(best, time.perf_counter() - t0)
        one = stencil_apply(a, w, "stencil27", block_i=4, sweeps=2)
        err = float(jnp.max(jnp.abs(stencil_sharded(
            a, w, "stencil27", mesh=mesh, sweeps=2) - one)))
        st = 2 * 14 * 22 * 126
        print(f"engine27.sharded_2dev_s2.16x24x128,{best*1e6:.1f},"
              f"{st/best/1e6:.2f} Mstencil/s n_dev={jax.device_count()} "
              f"max_err_vs_single={err:.2e} ok={err < 1e-4}")
    """
    return _subprocess_rows(code, "engine27.sharded_2dev_s2.16x24x128",
                            n_dev=2)[0]


def _subprocess_rows(code: str, fallback_name: str, n_dev: int) -> List[str]:
    """Run ``code`` under ``n_dev`` forced host devices and parse every
    ``name,usec,derived`` stdout line into text rows + JSON records."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
        + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600, env=env)
    if out.returncode != 0:
        err_lines = out.stderr.strip().splitlines() or ["(no stderr)"]
        _RECORDS.append({"name": fallback_name, "us_per_call": None,
                         "ok": False, "error": err_lines[-1][:200]})
        return [f"{fallback_name},nan,FAILED: {err_lines[-1][:120]}"]
    rows = []
    for line in out.stdout.strip().splitlines() or ["(no stdout)"]:
        parts = line.split(",", 2)
        if len(parts) == 3:
            name, usec, derived = parts
            _RECORDS.append({"name": name, "us_per_call": float(usec),
                             "ok": "ok=True" in derived, "derived": derived})
            rows.append(line)
        else:
            _RECORDS.append({"name": fallback_name, "us_per_call": None,
                             "ok": False,
                             "error": f"unparseable row: {line[:200]}"})
            rows.append(f"{fallback_name},nan,unparseable: {line[:120]}")
    return rows


def _sharded_grid_rows() -> List[str]:
    """The multi-axis grid on 8 forced host devices: a 2x2x2 stencil27 run
    with the serialized exchange (``overlap="off"``) and the
    compute/communication-overlap schedule (``overlap="on"``), both checked
    against the single-device oracle.  Timing rows (never gated -- host
    devices on a CI runner measure scheduling, not bandwidth); correctness
    ``ok`` flags ride the ``derived`` column like the other sharded row."""
    code = """
        import time
        import jax, numpy as np, jax.numpy as jnp
        from repro.kernels import stencil_apply, stencil_sharded
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(-4, 5, (32, 32, 64)), jnp.float32)
        w = jnp.asarray(rng.integers(-3, 4, (2, 2, 2)), jnp.float32)
        mesh = jax.make_mesh((2, 2, 2), ("x", "y", "z"))
        one = stencil_apply(a, w, "stencil27", sweeps=2)
        for overlap in ("off", "on"):
            run = lambda: stencil_sharded(
                a, w, "stencil27", mesh=mesh, axes=("x", "y", "z"),
                sweeps=2, overlap=overlap).block_until_ready()
            got = run()                             # compile + warm
            err = float(jnp.max(jnp.abs(got - one)))
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter(); run()
                best = min(best, time.perf_counter() - t0)
            st = 2 * 30 * 30 * 62
            print(f"engine27.grid_2x2x2_s2_overlap_{overlap}.32x32x64,"
                  f"{best*1e6:.1f},{st/best/1e6:.2f} Mstencil/s "
                  f"n_dev={jax.device_count()} max_err_vs_single={err:.2e} "
                  f"ok={err == 0.0}")
    """
    return _subprocess_rows(code, "engine27.grid_2x2x2_s2.32x32x64", n_dev=8)


if __name__ == "__main__":
    quick = "--quick" in sys.argv[1:]
    print("\n".join(run_quick() if quick else run()))
