"""Figures 8-10 analogue: measured stencil throughput over problem sizes.

On this CPU container we measure the jitted XLA stencil (the ref oracle) --
wall-clock Mstencil/s across the cache hierarchy, the same experiment shape
as the paper's Figures 8-10 -- and verify the Pallas kernel (interpret mode)
against it at each size.  TPU numbers come from running the same harness on
real hardware.

The tail rows exercise the unified stencil engine: batched execution, fused
multi-sweep Jacobi (``s`` operator applications per HBM round-trip), and a
2-device halo-exchange ``shard_map`` run (forced host-platform devices, in a
subprocess so this process keeps its single-device view).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (stencil_apply, stencil_ref, stencil3_ref,
                           stencil7_ref, stencil27, stencil27_ref)

SIZES = (14, 30, 62, 126)


def _time(fn, *args, reps: int = 5) -> float:
    fn(*args).block_until_ready()          # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> List[str]:
    rows = []
    rng = np.random.default_rng(0)
    j27 = jax.jit(stencil27_ref)
    j7 = jax.jit(stencil7_ref)
    j3 = jax.jit(stencil3_ref)
    for n in SIZES:
        a = jnp.asarray(rng.standard_normal((n, n, n)), jnp.float32)
        w27 = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
        w7 = jnp.asarray(rng.uniform(0.1, 1, 4), jnp.float32)
        w3 = jnp.asarray(rng.uniform(0.1, 1, 2), jnp.float32)
        st = (n - 2) ** 3
        t = _time(j27, a, w27)
        rows.append(f"stencil27.{n}^3,{t*1e6:.1f},{st/t/1e6:.1f} Mstencil/s")
        t = _time(j7, a, w7)
        rows.append(f"stencil7.{n}^3,{t*1e6:.1f},{st/t/1e6:.1f} Mstencil/s")
        a2 = a.reshape(n * n, n)
        t = _time(j3, a2, w3)
        st3 = n * n * (n - 2)
        rows.append(f"stencil3.{n}^3,{t*1e6:.1f},{st3/t/1e6:.1f} Mstencil/s")
    # Pallas kernel correctness at a bench size (interpret mode)
    n = 30
    a = jnp.asarray(rng.standard_normal((n + 2, n + 2, 128)), jnp.float32)
    w27 = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
    got = stencil27(a, w27, block_i=4)
    ref = stencil27_ref(a, w27)
    err = float(jnp.max(jnp.abs(got - ref)))
    rows.append(f"stencil27.pallas_vs_ref,0.0,max_err={err:.2e} "
                f"ok={err < 1e-4}")
    # beyond-paper MXU form: correctness + napkin speedup on the TPU target
    from repro.kernels import stencil27_mxu
    got_mxu = stencil27_mxu(a, w27, block_i=4)
    err_mxu = float(jnp.max(jnp.abs(got_mxu - ref)))
    p = a.shape[-1]
    vpu_t = 54.0 / 3e12              # ~54 VPU flops/pt at ~3 TFLOP/s
    mxu_t = 8.0 * p / 197e12 + 5.0 / 3e12   # 8P MXU flops + 5 VPU adds
    rows.append(f"stencil27.mxu_vs_ref,0.0,max_err={err_mxu:.2e} "
                f"ok={err_mxu < 1e-4} napkin_speedup_v5e={vpu_t/mxu_t:.1f}x "
                f"(P={p})")
    rows.extend(_engine_rows(rng))
    return rows


def _engine_rows(rng) -> List[str]:
    """Engine-backed scenarios: batched, fused-sweep, 2-device sharded."""
    rows: List[str] = []
    b, m, n, p = 4, 16, 24, 128
    w = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
    a4 = jnp.asarray(rng.standard_normal((b, m, n, p)), jnp.float32)
    st = b * (m - 2) * (n - 2) * (p - 2)

    t = _time(lambda x: stencil_apply(x, w, "stencil27", block_i=4), a4)
    err = float(jnp.max(jnp.abs(stencil_apply(a4, w, "stencil27", block_i=4)
                                - stencil_ref(a4, w, "stencil27"))))
    rows.append(f"engine27.batched.{b}x{m}x{n}x{p},{t*1e6:.1f},"
                f"{st/t/1e6:.2f} Mstencil/s max_err={err:.2e} "
                f"ok={err < 1e-4}")

    a3 = a4[0]
    st1 = (m - 2) * (n - 2) * (p - 2)
    for s in (1, 2, 3):
        t = _time(lambda x, s=s: stencil_apply(x, w, "stencil27", block_i=4,
                                               sweeps=s), a3)
        err = float(jnp.max(jnp.abs(
            stencil_apply(a3, w, "stencil27", block_i=4, sweeps=s)
            - stencil_ref(a3, w, "stencil27", sweeps=s))))
        rows.append(f"engine27.fused_s{s}.{m}^3-ish,{t*1e6:.1f},"
                    f"{s*st1/t/1e6:.2f} Mstencil/s (sweeps x points / time) "
                    f"max_err={err:.2e} ok={err < 1e-4}")

    rows.append(_sharded_row())
    return rows


def _sharded_row() -> str:
    """Time the 2-device halo-exchange path on forced host devices."""
    code = """
        import time
        import jax, numpy as np, jax.numpy as jnp
        from repro.kernels import stencil_apply, stencil_sharded
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((16, 24, 128)), jnp.float32)
        w = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
        mesh = jax.make_mesh((2,), ("data",))
        run = lambda: stencil_sharded(a, w, "stencil27", mesh=mesh,
                                      sweeps=2).block_until_ready()
        run()                                   # compile + warm
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter(); run()
            best = min(best, time.perf_counter() - t0)
        one = stencil_apply(a, w, "stencil27", block_i=4, sweeps=2)
        err = float(jnp.max(jnp.abs(stencil_sharded(
            a, w, "stencil27", mesh=mesh, sweeps=2) - one)))
        st = 2 * 14 * 22 * 126
        print(f"engine27.sharded_2dev_s2.16x24x128,{best*1e6:.1f},"
              f"{st/best/1e6:.2f} Mstencil/s n_dev={jax.device_count()} "
              f"max_err_vs_single={err:.2e} ok={err < 1e-4}")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
        + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600, env=env)
    if out.returncode != 0:
        err_lines = out.stderr.strip().splitlines() or ["(no stderr)"]
        return ("engine27.sharded_2dev_s2.16x24x128,nan,"
                f"FAILED: {err_lines[-1][:120]}")
    out_lines = out.stdout.strip().splitlines() or ["(no stdout)"]
    return out_lines[-1]


if __name__ == "__main__":
    print("\n".join(run()))
