"""Figures 8-10 analogue: measured stencil throughput over problem sizes.

On this CPU container we measure the jitted XLA stencil (the ref oracle) --
wall-clock Mstencil/s across the cache hierarchy, the same experiment shape
as the paper's Figures 8-10 -- and verify the Pallas kernel (interpret mode)
against it at each size.  TPU numbers come from running the same harness on
real hardware.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (stencil3_ref, stencil7_ref, stencil27,
                           stencil27_ref)

SIZES = (14, 30, 62, 126)


def _time(fn, *args, reps: int = 5) -> float:
    fn(*args).block_until_ready()          # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> List[str]:
    rows = []
    rng = np.random.default_rng(0)
    j27 = jax.jit(stencil27_ref)
    j7 = jax.jit(stencil7_ref)
    j3 = jax.jit(stencil3_ref)
    for n in SIZES:
        a = jnp.asarray(rng.standard_normal((n, n, n)), jnp.float32)
        w27 = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
        w7 = jnp.asarray(rng.uniform(0.1, 1, 4), jnp.float32)
        w3 = jnp.asarray(rng.uniform(0.1, 1, 2), jnp.float32)
        st = (n - 2) ** 3
        t = _time(j27, a, w27)
        rows.append(f"stencil27.{n}^3,{t*1e6:.1f},{st/t/1e6:.1f} Mstencil/s")
        t = _time(j7, a, w7)
        rows.append(f"stencil7.{n}^3,{t*1e6:.1f},{st/t/1e6:.1f} Mstencil/s")
        a2 = a.reshape(n * n, n)
        t = _time(j3, a2, w3)
        st3 = n * n * (n - 2)
        rows.append(f"stencil3.{n}^3,{t*1e6:.1f},{st3/t/1e6:.1f} Mstencil/s")
    # Pallas kernel correctness at a bench size (interpret mode)
    n = 30
    a = jnp.asarray(rng.standard_normal((n + 2, n + 2, 128)), jnp.float32)
    w27 = jnp.asarray(rng.uniform(0.1, 1, (2, 2, 2)), jnp.float32)
    got = stencil27(a, w27, block_i=4)
    ref = stencil27_ref(a, w27)
    err = float(jnp.max(jnp.abs(got - ref)))
    rows.append(f"stencil27.pallas_vs_ref,0.0,max_err={err:.2e} "
                f"ok={err < 1e-4}")
    # beyond-paper MXU form: correctness + napkin speedup on the TPU target
    from repro.kernels import stencil27_mxu
    got_mxu = stencil27_mxu(a, w27, block_i=4)
    err_mxu = float(jnp.max(jnp.abs(got_mxu - ref)))
    p = a.shape[-1]
    vpu_t = 54.0 / 3e12              # ~54 VPU flops/pt at ~3 TFLOP/s
    mxu_t = 8.0 * p / 197e12 + 5.0 / 3e12   # 8P MXU flops + 5 VPU adds
    rows.append(f"stencil27.mxu_vs_ref,0.0,max_err={err_mxu:.2e} "
                f"ok={err_mxu < 1e-4} napkin_speedup_v5e={vpu_t/mxu_t:.1f}x "
                f"(P={p})")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
