"""Trip-count-aware HLO analysis: the roofline's measurement layer."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (analyze_hlo, normalize_cost_analysis,
                                       parse_hlo)


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_normalize_cost_analysis_both_api_shapes():
    """Old JAX returns a dict, new JAX a list of per-module dicts."""
    assert normalize_cost_analysis({"flops": 2.0}) == {"flops": 2.0}
    assert normalize_cost_analysis([{"flops": 2.0}]) == {"flops": 2.0}
    assert normalize_cost_analysis([]) == {}
    assert normalize_cost_analysis(None) == {}


def test_cost_analysis_counts_loops_once_but_we_dont():
    """Documents the XLA behavior the analyzer exists to fix."""
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    spec = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = _compile(f, spec)
    xla_flops = normalize_cost_analysis(compiled.cost_analysis())["flops"]
    ours = analyze_hlo(compiled.as_text())["flops"]
    one_matmul = 2 * 128 ** 3
    assert abs(xla_flops - one_matmul) / one_matmul < 0.01      # loop once
    assert abs(ours - 7 * one_matmul) / (7 * one_matmul) < 0.01  # corrected


def test_nested_scan_multipliers():
    def f(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ours = analyze_hlo(_compile(f, spec).as_text())["flops"]
    expect = 15 * 2 * 64 ** 3
    assert abs(ours - expect) / expect < 0.02


def test_single_dot_flops_exact():
    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ours = analyze_hlo(_compile(lambda x: x @ x, spec).as_text())["flops"]
    assert ours == 2 * 64 ** 3


def test_batched_dot_flops():
    spec = jax.ShapeDtypeStruct((4, 32, 48), jnp.float32)
    w = jax.ShapeDtypeStruct((48, 16), jnp.float32)
    ours = analyze_hlo(_compile(lambda x, w_: x @ w_, spec, w).as_text())
    assert ours["flops"] == 2 * 4 * 32 * 48 * 16


def test_parse_handles_tuple_typed_whiles():
    """Big loop-state tuples (nested parens) must not hide while ops."""
    def f(x, y):
        def body(c, _):
            a, b = c
            return (a @ a, b + 1.0), None
        (a, b), _ = jax.lax.scan(body, (x, y), None, length=4)
        return a, b

    sx = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    sy = jax.ShapeDtypeStruct((8,), jnp.float32)
    text = _compile(f, sx, sy).as_text()
    comps, entry = parse_hlo(text)
    n_while = sum(1 for ops in comps.values()
                  for op in ops if op.opcode == "while")
    assert n_while >= 1
    ours = analyze_hlo(text)["flops"]
    expect = 4 * 2 * 32 ** 3
    assert abs(ours - expect) / expect < 0.05


def test_traffic_nonzero_and_bounded():
    spec = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    d = analyze_hlo(_compile(lambda x: jnp.tanh(x @ x) + 1.0, spec).as_text())
    nbytes = 256 * 256 * 4
    assert d["bytes"] >= 2 * nbytes          # at least in+out
    assert d["bytes"] <= 40 * nbytes         # and not wildly inflated
