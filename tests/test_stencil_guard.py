"""Guarded execution: every fault injector caught by its matching detector,
bit-exact ladder recovery vs the oracle, guard="off" byte-identity with the
historical programs, the 2-device corrupted halo exchange (subprocess), the
report schema, and the satellite harness fixes (regression-gate exit codes,
benchmark wall-clock timeout)."""

import importlib.util
import json
import os
import subprocess
import sys
import textwrap
import time
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (GuardPolicy, guard_bytes_per_point,
                           last_guard_report, stencil_apply, stencil_ref,
                           stencil_sharded, stencil_sweep_driver)
from repro.kernels.stencil_engine import (GUARD_KINDS, LADDER, BitFlipPlane,
                                          CorruptHalo, GuardError,
                                          NaNScratchWindow, NaNWindow,
                                          RaisingCandidate, as_guard,
                                          clear_blacklist, get_stencil,
                                          inject, is_blacklisted,
                                          list_blacklist, run_guard_checks,
                                          stencil_ref_planes)
from repro.kernels.stencil_engine import guard as guard_mod
from repro.kernels.stencil_engine.ops import stencil_apply_jit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(23)


def _int_field(shape):
    """Integer-valued f64 data: every path/rung is exact, so recovery can be
    asserted with ``assert_array_equal`` (bit-exact vs the oracle)."""
    return jnp.asarray(RNG.integers(-4, 5, shape).astype(np.float64))


def _int_weights(n):
    return jnp.asarray(RNG.integers(-3, 4, n).astype(np.float64))


N_WEIGHTS = {"stencil7": 4, "stencil27": 8, "star13": 3}


def _nw(name):
    return N_WEIGHTS[name.split("_")[0]]


@pytest.fixture(autouse=True)
def _clean_blacklist():
    clear_blacklist()
    yield
    clear_blacklist()


# ---------------------------------------------------------------------------
# Policy spellings and the off-path bypass.
# ---------------------------------------------------------------------------

def test_as_guard_spellings():
    assert as_guard(None) is None and as_guard("off") is None
    assert as_guard("nan") == GuardPolicy(nan=True, invariant=False,
                                          oracle=False, sample=0)
    assert as_guard("invariant").invariant and not as_guard("invariant").oracle
    assert as_guard("oracle").oracle and as_guard("oracle").sample == 4
    full = as_guard("full")
    assert full.oracle and full.sample == 0
    pol = GuardPolicy(sample=2, retries=0)
    assert as_guard(pol) is pol
    with pytest.raises(ValueError, match="unknown guard"):
        as_guard("bogus")
    with pytest.raises(ValueError):
        GuardPolicy(sample=-1)
    with pytest.raises(ValueError):
        GuardPolicy(retries=-1)


def test_spec_guard_field_validated():
    spec = get_stencil("stencil7")
    assert spec.guard == "off"
    for kind in GUARD_KINDS:
        assert spec.with_guard(kind).guard == kind
    with pytest.raises(ValueError, match="unknown guard"):
        spec.with_guard("bogus")


def test_guard_off_is_byte_identical_and_never_checks():
    """The default dispatches straight to the historical jitted program:
    same bytes out as calling it directly, and the guard's check machinery
    never runs."""
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(4)
        before = guard_mod.CHECK_RUNS[0]
        off = stencil_apply(a, w, "stencil7")               # spec default
        off2 = stencil_apply(a, w, "stencil7", guard="off")  # explicit
        jit_direct = stencil_apply_jit(a, w, "stencil7")
        assert guard_mod.CHECK_RUNS[0] == before
        np.testing.assert_array_equal(np.asarray(off), np.asarray(jit_direct))
        np.testing.assert_array_equal(np.asarray(off2),
                                      np.asarray(jit_direct))
        # no injectors installed -> the hook lists really are empty
        assert not guard_mod._OUT_HOOKS and not guard_mod._RUN_HOOKS
        drv = stencil_sweep_driver(a, w, "stencil7", sweeps=2)
        assert guard_mod.CHECK_RUNS[0] == before
        assert drv.shape == a.shape


@pytest.mark.parametrize("name", ["stencil7", "stencil7_periodic",
                                  "stencil7_neumann", "stencil27_redblack"])
def test_guarded_clean_run_matches_off(name):
    """A clean guarded call is byte-identical to the unguarded program (the
    guard only *observes*), passes its checks, and reports final == start
    with no demotions -- across BC x ordering (no false positives)."""
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(_nw(name))
        off = stencil_apply(a, w, name)
        got = stencil_apply(a, w, name, guard="oracle")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(off))
        rep = last_guard_report()
        assert rep.final == rep.start == "fused"
        assert rep.demotions == [] and rep.blacklisted == []
        assert all(c["ok"] for c in rep.attempts[0]["checks"])


@pytest.mark.parametrize("name", ["stencil7", "stencil7_periodic",
                                  "stencil27_neumann"])
@pytest.mark.parametrize("s", [1, 2])
def test_run_guard_checks_no_false_positives(name, s):
    """The detectors stay silent on honest outputs, sampled and full, and
    the sampled strip oracle agrees with the full reference."""
    with jax.experimental.enable_x64():
        a = _int_field((14, 8, 32))
        w = _int_weights(_nw(name))
        spec = get_stencil(name)
        out = stencil_sweep_driver(a, w, name, sweeps=s)
        for policy in (GuardPolicy(oracle=True, sample=4),
                       GuardPolicy(oracle=True, sample=0)):
            recs = run_guard_checks(out, a, w, spec, s, policy)
            assert all(c["ok"] for c in recs), recs
        h = spec.radius[0] * spec.sweep_apps * s
        if spec.bc[0][0].kind == "periodic":
            planes = np.asarray([0, h + 1, a.shape[0] - 1])
        else:                        # strip oracle wants interior planes
            planes = np.asarray([h, h + 1, a.shape[0] - 1 - h])
        strips = stencil_ref_planes(a, w, spec, planes, sweeps=s)
        full = stencil_ref(a, w, spec, sweeps=s)
        np.testing.assert_array_equal(np.asarray(strips),
                                      np.asarray(full)[planes])


# ---------------------------------------------------------------------------
# Each injector vs its matching detector (+ bit-exact recovery).
# ---------------------------------------------------------------------------

def test_nan_window_caught_by_nan_screen_retry_recovers():
    """A one-shot NaN store: the nan check fails attempt 0, the same-rung
    retry runs clean -- no demotion, bit-exact result."""
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(4)
        with inject(NaNWindow(seed=7, plane=5)) as (inj,):
            out = stencil_apply(a, w, "stencil7", guard="full")
        assert inj.fired == 1
        rep = last_guard_report()
        assert rep.attempts[0]["fault"] == "nan"
        assert not [c for c in rep.attempts[0]["checks"]
                    if c["check"] == "nan"][0]["ok"]
        assert rep.final == "fused" and rep.demotions == []
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(stencil_ref(a, w, "stencil7")))


def test_bitflip_plane_caught_by_invariant():
    """An exponent-bit flip is huge but *finite*: it sails through the NaN
    screen and the weight-sum invariant trips."""
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(4)
        with inject(BitFlipPlane(seed=3, plane=6)) as (inj,):
            out = stencil_apply(a, w, "stencil7_periodic", guard="full")
        assert inj.fired == 1
        rep = last_guard_report()
        checks = {c["check"]: c for c in rep.attempts[0]["checks"]}
        assert checks["nan"]["ok"]          # finite -- the screen passes
        assert not checks["invariant"]["ok"]
        np.testing.assert_array_equal(
            np.asarray(out),
            np.asarray(stencil_ref(a, w, "stencil7_periodic")))


def test_nan_scratch_kernel_fault_demotes_off_stream():
    """A NaN poisoned inside the stream kernel's VMEM rotating window (the
    static ``_fault`` hook): the screen catches it on the fused rung, the
    retry re-fires, and the ladder recovers on a lower rung, bit-exact."""
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(4)
        with inject(NaNScratchWindow(seed=1, plane=2, fires=3)) as (inj,):
            out = stencil_apply(a, w, "stencil7", guard="full",
                                path="stream")
        assert inj.fired == 3
        rep = last_guard_report()
        assert rep.demotions and rep.demotions[0]["from"] == "fused"
        assert rep.demotions[0]["fault"] == "nan"
        assert rep.final != "fused"
        assert rep.blacklisted == []     # data fault, not a raising kernel
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(stencil_ref(a, w, "stencil7")))


def test_raising_candidate_demotes_and_blacklists():
    """A candidate that raises at run time: retried once, demoted, and the
    dead rung blacklisted in the autotuner so future auto races skip it."""
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(4)
        with inject(RaisingCandidate(rungs=("fused", "chained"))) as (inj,):
            out = stencil_apply(a, w, "stencil7", guard="full")
        assert inj.fired == 4            # 2 rungs x (attempt + retry)
        rep = last_guard_report()
        assert [d["fault"] for d in rep.demotions] == \
            ["exception:RuntimeError"] * 2
        assert [d["retries"] for d in rep.demotions] == [1, 1]
        assert rep.final == "stream"
        assert ("mode", "fused") in rep.blacklisted
        assert ("mode", "chained") in rep.blacklisted
        assert is_blacklisted("stencil7", mode="fused")
        assert is_blacklisted("stencil7", mode="chained")
        assert ("stencil7", "mode", "fused") in list_blacklist()
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(stencil_ref(a, w, "stencil7")))


def test_ladder_exhaustion_raises_guard_error():
    """When every rung (the oracle included) dies, the guard refuses to
    return unverified data."""
    with jax.experimental.enable_x64():
        a = _int_field((8, 8, 32))
        w = _int_weights(4)
        with inject(RaisingCandidate(rungs=LADDER)):
            with pytest.raises(GuardError, match="every ladder rung"):
                stencil_apply(a, w, "stencil7", guard="full")


def test_corrupt_halo_unsharded_caught():
    """The single-device analogue of a bad exchange: corrupted edge planes
    trip the invariant, and the retry recovers bit-exactly."""
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(4)
        with inject(CorruptHalo(seed=9, mode="garbage",
                                sharded=False)) as (inj,):
            out = stencil_apply(a, w, "stencil7_periodic", guard="full")
        assert inj.fired == 1
        rep = last_guard_report()
        assert rep.attempts[0]["fault"] == "invariant"
        np.testing.assert_array_equal(
            np.asarray(out),
            np.asarray(stencil_ref(a, w, "stencil7_periodic")))


# ---------------------------------------------------------------------------
# Guarded driver / sharded entries.
# ---------------------------------------------------------------------------

def test_guarded_driver_clean_wavefront():
    with jax.experimental.enable_x64():
        a = _int_field((16, 8, 32))
        w = _int_weights(4)
        off = stencil_sweep_driver(a, w, "stencil7", sweeps=3,
                                   mode="wavefront")
        got = stencil_sweep_driver(a, w, "stencil7", sweeps=3,
                                   mode="wavefront", guard="oracle")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(off))
        rep = last_guard_report()
        assert rep.entry == "driver" and rep.sweeps == 3
        assert rep.start == rep.final == "wavefront"


def test_guarded_driver_wavefront_demotes_to_fused():
    """A persistent fault on the wavefront rung (fires through the retry)
    walks the driver down to the fused rung, bit-exact."""
    with jax.experimental.enable_x64():
        a = _int_field((16, 8, 32))
        w = _int_weights(4)
        with inject(NaNWindow(seed=2, plane=7, rungs=("wavefront",),
                              fires=2)) as (inj,):
            out = stencil_sweep_driver(a, w, "stencil7", sweeps=3,
                                       mode="wavefront", guard="full")
        assert inj.fired == 2
        rep = last_guard_report()
        assert rep.demotions == [{"from": "wavefront", "to": "fused",
                                  "fault": "nan", "retries": 1}]
        assert rep.final == "fused"
        np.testing.assert_array_equal(
            np.asarray(out),
            np.asarray(stencil_ref(a, w, "stencil7", sweeps=3)))


def test_guarded_sharded_single_device():
    """The sharded entry point's guard path (1-device mesh): clean run,
    sharded-entry report, bit-exact vs the oracle."""
    with jax.experimental.enable_x64():
        a = _int_field((16, 8, 32))
        w = _int_weights(4)
        mesh = jax.make_mesh((1,), ("data",))
        got = stencil_sharded(a, w, "stencil7", mesh=mesh, sweeps=2,
                              guard="oracle")
        rep = last_guard_report()
        assert rep.entry == "sharded" and rep.final == "fused"
        np.testing.assert_array_equal(
            np.asarray(got),
            np.asarray(stencil_ref(a, w, "stencil7", sweeps=2)))


def test_sharded_corrupt_halo_2dev_subprocess():
    """2 forced host devices: corrupt the ppermute'd halo slabs inside the
    traced exchange (garbage / truncate / nan), and show each detector
    firing and the ladder escaping the sharded path to recover bit-exactly
    on a single-device rung."""
    code = """
    import jax, numpy as np, jax.numpy as jnp
    from repro.kernels import stencil_ref, stencil_sharded, last_guard_report
    from repro.kernels.stencil_engine import CorruptHalo, inject
    assert jax.device_count() == 2
    with jax.experimental.enable_x64():
        rng = np.random.default_rng(5)
        a = jnp.asarray(rng.integers(-4, 5, (16, 8, 32)).astype(np.float64))
        w = jnp.asarray(rng.integers(-3, 4, 4).astype(np.float64))
        mesh = jax.make_mesh((2,), ("data",))
        ref = stencil_ref(a, w, "stencil7_periodic", sweeps=2)
        for mode, detector in (("garbage", "invariant"),
                               ("truncate", "invariant"), ("nan", "nan")):
            with inject(CorruptHalo(mode=mode)) as (inj,):
                got = stencil_sharded(a, w, "stencil7_periodic", mesh=mesh,
                                      sweeps=2, guard="full")
            assert inj.fired >= 1
            rep = last_guard_report()
            assert rep.entry == "sharded"
            assert rep.attempts[0]["fault"] == detector, (mode, rep.attempts)
            assert rep.demotions, mode
            assert rep.final in ("chained", "stream", "replicate"), mode
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        print("halo faults ok")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "halo faults ok" in out.stdout


# ---------------------------------------------------------------------------
# Report schema and the overhead model.
# ---------------------------------------------------------------------------

def test_guard_report_describe_schema():
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(4)
        stencil_apply(a, w, "stencil7", guard="invariant")
        doc = last_guard_report().describe()
    g = doc["guard"]
    assert set(g) == {"spec", "sweeps", "entry", "start", "final", "policy",
                      "attempts", "demotions", "blacklisted"}
    assert g["spec"] == "stencil7" and g["entry"] == "apply"
    assert g["policy"] == {"nan": True, "invariant": True, "oracle": False,
                           "sample": 4, "retries": 1, "rtol": None}
    att = g["attempts"][0]
    assert set(att) == {"rung", "attempt", "checks", "fault"}
    for c in att["checks"]:
        assert set(c) == {"check", "ok", "skipped", "detail"}
    json.dumps(doc)                     # machine-readable end to end


def test_guard_overhead_model_under_gate():
    """The modeled check traffic of the default policy: < 10% of the stream
    path's 2 * itemsize at the benchmark's gate shape, 0 when off."""
    assert guard_bytes_per_point(None, 4, 128) == 0.0
    bpp = guard_bytes_per_point(GuardPolicy(), 4, 128)
    assert bpp == pytest.approx(0.5)
    assert bpp / (2.0 * 4) < 0.10
    # full checks price the whole volume -- debug grade, not gated
    assert guard_bytes_per_point(GuardPolicy(sample=0), 4, 128) == \
        pytest.approx(8.0)
    # sampling never prices more planes than exist
    assert guard_bytes_per_point(GuardPolicy(sample=99), 4, 8) <= \
        guard_bytes_per_point(GuardPolicy(sample=0), 4, 8)


# ---------------------------------------------------------------------------
# Satellites: regression-gate exit codes + benchmark wall-clock timeout.
# ---------------------------------------------------------------------------

def _load_module(rel, name):
    path = os.path.join(REPO, *rel)
    mod_spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(mod_spec)
    mod_spec.loader.exec_module(mod)
    return mod


def test_check_regression_bad_baseline_exits_2(tmp_path, capsys):
    """Satellite: a missing / truncated / non-object baseline is a harness
    error (exit 2) with a one-line diagnostic naming the bad file -- never
    a silent pass or a fake regression verdict."""
    cr = _load_module(("benchmarks", "check_regression.py"),
                      "check_regression_guard_test")
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps({"schema": "bench_stencil/v6",
                                 "paths": {"stream":
                                           {"bytes_per_point_f32": 8.0}}}))
    missing = str(tmp_path / "nope.json")
    assert cr.main([missing, str(fresh)]) == 2
    assert "nope.json" in capsys.readouterr().out
    truncated = tmp_path / "trunc.json"
    truncated.write_text('{"schema": "bench_stencil/v6", "paths": {')
    assert cr.main([str(truncated), str(fresh)]) == 2
    msg = capsys.readouterr().out
    assert "trunc.json" in msg and "JSON" in msg
    listdoc = tmp_path / "list.json"
    listdoc.write_text("[1, 2, 3]")
    assert cr.main([str(listdoc), str(fresh)]) == 2
    assert "expected an object" in capsys.readouterr().out
    # a bad *fresh* file is caught the same way
    assert cr.main([str(fresh), missing]) == 2
    assert "nope.json" in capsys.readouterr().out


def test_bench_runner_timeout(capsys):
    """Satellite: a wedged sub-benchmark is interrupted by the wall-clock
    alarm (BenchTimeout), not left to stall the harness."""
    run = _load_module(("benchmarks", "run.py"), "bench_run_guard_test")
    if not hasattr(__import__("signal"), "SIGALRM"):
        pytest.skip("no SIGALRM on this platform")

    def _hung_rows():
        time.sleep(10)
        yield "never,0,unreached"

    hung = types.SimpleNamespace(run=_hung_rows)
    t0 = time.monotonic()
    with pytest.raises(run.BenchTimeout, match="BENCH_TIMEOUT_S=1"):
        run._run_rows("hung", hung, timeout_s=1)
    assert time.monotonic() - t0 < 5.0
    # a fast benchmark under the same alarm passes untouched
    quick = types.SimpleNamespace(run=lambda: iter(["quick,1.0,ok"]))
    run._run_rows("quick", quick, timeout_s=30)
    assert "quick,1.0,ok" in capsys.readouterr().out


def test_bench_timeout_env_parsing(monkeypatch):
    run = _load_module(("benchmarks", "run.py"), "bench_run_env_test")
    monkeypatch.setenv("BENCH_TIMEOUT_S", "17")
    assert run._timeout_s() == 17
    monkeypatch.setenv("BENCH_TIMEOUT_S", "not-a-number")
    assert run._timeout_s() == run.DEFAULT_TIMEOUT_S
    monkeypatch.setenv("BENCH_TIMEOUT_S", "-3")
    assert run._timeout_s() == 0    # negative disables, never crashes
    monkeypatch.delenv("BENCH_TIMEOUT_S")
    assert run._timeout_s() == run.DEFAULT_TIMEOUT_S
