"""input_specs conformance + extra property coverage across substrates."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # optional dev dep -- property tests skip, rest runs
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.configs import ARCH_IDS, get_config
from repro.launch.cells import input_specs, skip_reason
from repro.models.common import SHAPES, pad_vocab


@pytest.mark.parametrize("aid", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_every_cell(aid, shape):
    """Every non-skipped cell has well-formed, allocation-free input specs."""
    if skip_reason(aid, shape):
        return
    specs = input_specs(aid, shape)
    assert specs, (aid, shape)
    cfg = get_config(aid)
    sh = SHAPES[shape]
    for k, v in specs.items():
        assert isinstance(v, jax.ShapeDtypeStruct), k
    if sh.kind == "train":
        assert specs["tokens"].shape[0] == sh.global_batch
        assert "labels" in specs
        front = cfg.frontend_len if cfg.family == "vlm" else 0
        assert specs["tokens"].shape[1] == sh.seq_len - front
    elif sh.kind == "prefill":
        assert "labels" not in specs
    else:
        assert specs["tokens"].shape == (sh.global_batch, 1)


def test_vocab_padding_property():
    for v in (92553, 32000, 151936, 256206, 65024, 49152):
        p = pad_vocab(v)
        assert p >= v and p % 256 == 0 and p - v < 256


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 6), st.floats(1e-4, 1e-2))
def test_adamw_descends_any_seed(seed, lr):
    from repro.optim import adamw
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.standard_normal(8), jnp.float32)}
    opt = adamw(weight_decay=0.0)
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(30):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.asarray(lr))
    assert float(loss(params)) < l0


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 4))
def test_compression_roundtrip_bounded_error(seed):
    from repro.compression import compress_decompress
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(256), jnp.float32)
    ef = jnp.zeros_like(g)
    q, scale, new_ef = compress_decompress(g, ef)
    # single-shot quantization error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(new_ef))) <= float(scale) * 0.5 + 1e-7
    # and error feedback preserves the total signal exactly
    deq = q.astype(jnp.float32) * scale
    np.testing.assert_allclose(np.asarray(deq + new_ef), np.asarray(g),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3), st.sampled_from([3, 7, 27]))
def test_synth_counts_scale_with_jam(ui, uj, points):
    """Property: loads grow with the frame, FPU ops with the outputs."""
    from repro.core.synth import StencilConfig, synth_stencil
    if points == 27:
        kernel = "mm"
    elif points == 3:
        kernel = "lc"
    else:
        kernel = "mm"
    k = synth_stencil(StencilConfig(points, kernel, ui, uj))
    c = k.counts
    outs = ui * uj
    assert c.stores == outs
    per_stencil = {3: 3, 7: 7, 27: 27}[points]
    expect_fpu = per_stencil * outs + (outs if kernel == "lc" else 0)
    assert c.fpu == expect_fpu
    # effective arithmetic intensity never degrades with more jam
    k11 = synth_stencil(StencilConfig(points, kernel, 1, 1))
    bps = (c.read_bytes + c.write_bytes) / (2 * outs)
    bps11 = (k11.counts.read_bytes + k11.counts.write_bytes) / 2
    assert bps <= bps11 + 1e-9


def test_planner_specs_all_valid_divisible():
    """Property: every spec the planner emits divides the mesh axes."""
    import os
    import subprocess
    import sys
    import textwrap
    repo = __import__("os").path.dirname(
        __import__("os").path.dirname(__import__("os").path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = textwrap.dedent("""
        import jax
        import numpy as np
        from repro.configs import ARCH_IDS, get_config
        from repro.models import build_model
        from repro.sharding import param_sharding
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for aid in ARCH_IDS:
            cfg = get_config(aid)
            model = build_model(cfg)
            shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            shard, _ = param_sharding(cfg, shapes, mesh, fsdp=True)
            flat_sh = jax.tree.leaves(shard)
            flat_shape = [s.shape for s in jax.tree.leaves(shapes)]
            for s, shp in zip(flat_sh, flat_shape):
                for i, ax in enumerate(s.spec):
                    if ax is None:
                        continue
                    size = int(np.prod([mesh.shape[a] for a in
                                        (ax if isinstance(ax, tuple)
                                         else (ax,))]))
                    assert shp[i] % size == 0, (aid, shp, s.spec)
        print("all specs divisible")
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=repo,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
