"""Scheduler invariants: dependency/resource correctness, bounds, optimality."""

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # optional dev dep -- property tests skip, rest runs
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.core.dag import build_dag, lower_bound
from repro.core.isa import Unit, fxcpmadd, fxcpmul, lfpdx, stfpdx
from repro.core.scheduler import bb_schedule, greedy_schedule, ilp_formulation
from repro.core.synth import PAPER_CONFIGS, StencilConfig, synth_stencil


def _check_schedule(instrs, sched, g):
    # every instruction scheduled exactly once (ILP eq. 2)
    assert sorted(sched.order) == list(range(len(instrs)))
    # dependencies respected (eq. 5)
    for (u, v, d) in g.edges(data=True):
        assert sched.issue_cycle[v] >= sched.issue_cycle[u] + d["weight"], \
            f"dep {u}->{v} violated"
    # resource constraints (eqs. 3-4)
    by_cycle = {}
    for i, c in sched.issue_cycle.items():
        by_cycle.setdefault(c, []).append(i)
    lsu_cycles = sorted(c for i, c in sched.issue_cycle.items()
                        if instrs[i].unit is Unit.LSU)
    for a, b in zip(lsu_cycles, lsu_cycles[1:]):
        assert b - a >= 2, "LSU issued twice within 2 cycles"
    for c, idxs in by_cycle.items():
        assert sum(1 for i in idxs if instrs[i].unit is Unit.FPU) <= 1
        assert sum(1 for i in idxs if instrs[i].unit is Unit.IU) <= 1


@pytest.mark.parametrize("cfg", PAPER_CONFIGS, ids=lambda c: c.name)
@pytest.mark.parametrize("war", [True, False], ids=["inorder", "ooo"])
def test_greedy_valid_and_bounded(cfg, war):
    k = synth_stencil(cfg)
    g = build_dag(k.single_step, war=war)
    s = greedy_schedule(k.single_step, g)
    _check_schedule(k.single_step, s, g)
    assert s.makespan >= lower_bound(k.single_step, g)


def _random_block(draw):
    """A small random but well-formed instruction block."""
    n_regs = draw(st.integers(2, 5))
    regs = [f"f_r{i}" for i in range(n_regs)]
    instrs = [lfpdx(r, "g_a", 16 * i) for i, r in enumerate(regs)]
    n_ops = draw(st.integers(1, 7))
    for i in range(n_ops):
        t = draw(st.sampled_from(regs))
        a = draw(st.sampled_from(regs))
        c = draw(st.sampled_from(regs))
        if draw(st.booleans()):
            instrs.append(fxcpmadd(t, a, c))
        else:
            instrs.append(fxcpmul(t, a, c))
    instrs.append(stfpdx(regs[0], "g_r", 0))
    return instrs


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_greedy_valid_on_random_blocks(data):
    instrs = _random_block(data.draw)
    g = build_dag(instrs)
    s = greedy_schedule(instrs, g)
    _check_schedule(instrs, s, g)
    assert s.makespan >= lower_bound(instrs, g)


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_bb_never_worse_than_greedy(data):
    instrs = _random_block(data.draw)
    if len(instrs) > 12:
        return
    g = build_dag(instrs)
    greedy = greedy_schedule(instrs, g)
    exact = bb_schedule(instrs, max_nodes=12)
    assert exact is not None
    assert exact.makespan <= greedy.makespan
    assert exact.makespan >= lower_bound(instrs, g)


def test_bb_beats_greedy_and_certifies_lower_bound():
    """Regression for the dead B&B bound (it multiplied its correction by 0
    and was never consulted): on this block the greedy schedule is provably
    suboptimal and the exact solver must both improve on it and certify the
    eq.-1 lower bound."""
    instrs = [lfpdx(f"f_r{i}", "g_a", 16 * i) for i in range(4)]
    instrs += [
        fxcpmul("f_r1", "f_r1", "f_r1"),
        fxcpmul("f_r3", "f_r0", "f_r3"),
        fxcpmadd("f_r1", "f_r0", "f_r0"),
        fxcpmul("f_r1", "f_r2", "f_r2"),
        stfpdx("f_r0", "g_r", 0),
    ]
    g = build_dag(instrs)
    greedy = greedy_schedule(instrs, g)
    exact = bb_schedule(instrs, max_nodes=16)
    assert exact is not None
    _check_schedule(instrs, exact, g)
    assert exact.makespan <= greedy.makespan
    assert greedy.makespan == 12          # greedy leaves a hole
    assert exact.makespan == lower_bound(instrs, g) == 11
    assert exact.optimal


def test_greedy_optimal_on_simple_stream():
    """An embarrassingly parallel block schedules to its true optimum.

    Six loads saturate the LSU (issue 0,2,..,10); each mul lands load+4;
    the last mul issues at 14 => makespan 15, the hand-derived optimum
    (eq. 1's bound of 12 ignores the trailing load->mul latency).
    """
    instrs = []
    for i in range(6):
        instrs.append(lfpdx(f"f_a{i}", "g_a", 16 * i))
    for i in range(6):
        instrs.append(fxcpmul(f"f_t{i}", f"f_a{i}", f"f_a{i}"))
    s = greedy_schedule(instrs)
    assert s.makespan == 15
    assert s.makespan >= s.lower_bound


def test_ilp_formulation_consistent_with_greedy():
    import numpy as np
    cfg = StencilConfig(3, "lc", 1, 1)
    k = synth_stencil(cfg)
    instrs = k.single_step
    s = greedy_schedule(instrs)
    a_eq, b_eq, a_ub, b_ub, nv = ilp_formulation(instrs,
                                                 horizon=s.makespan + 1)
    m = nv // len(instrs)
    x = np.zeros(nv)
    for i, c in s.issue_cycle.items():
        x[i * m + c] = 1.0
    assert np.allclose(a_eq @ x, b_eq)
    assert (a_ub @ x <= b_ub + 1e-9).all()
