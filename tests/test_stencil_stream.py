"""Plane-streaming path: parity against the replicated escape hatch
(bit-exact on f64 / integer-valued data, to tolerance in f32/bf16) across
masks x sweeps x j-tiling -- at radius 1 and radius 2 (star13/box125, with
their 2*sweeps-deep streaming window, 5-view replicated halo, and
radius*sweeps sharded halo exchange) -- the streaming cost model's
bytes-per-point acceptance numbers, path plumbing (autotune_engine /
sharded), the interpret=None platform default, compile_plan memoization,
and the non-divisible-block / sweeps-deeper-than-block error messages."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (autotune_engine, bytes_per_point, compile_plan,
                           get_stencil, spec_from_mask, stencil_apply,
                           stencil_ref)
from repro.kernels.stencil_engine.autotune import _fits, _step_time
from repro.kernels.stencil_engine.ops import default_interpret

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(11)

# an asymmetric ad-hoc mask (cse plan) so the parity sweep isn't only the
# mirror-symmetric built-ins
_ASYM = np.zeros((3, 3, 3), bool)
_ASYM[1, 1, 1] = _ASYM[2, 0, 1] = _ASYM[1, 2, 2] = _ASYM[0, 1, 0] = True
ASYM_SPEC = spec_from_mask("stream-asym", _ASYM)


def _weights_for(spec, rng, integer=False):
    if integer:
        return jnp.asarray(rng.integers(1, 4, spec.w_shape), jnp.float32)
    return jnp.asarray(rng.uniform(0.1, 1.0, spec.w_shape), jnp.float32)


@pytest.mark.parametrize("name", ["stencil7", "stencil27", ASYM_SPEC])
@pytest.mark.parametrize("sweeps", [1, 2, 3])
@pytest.mark.parametrize("block_j", [None, 4])
def test_stream_matches_replicate_bit_exact_integer(name, sweeps, block_j):
    """Integer-valued f32 data makes every sum exact, so the streamed and
    replicated paths (and the reference) must agree bit-for-bit whatever
    the mask, fused-sweep depth, or j-tiling."""
    spec = get_stencil(name)
    a = jnp.asarray(RNG.integers(-4, 5, (9, 12, 16)), jnp.float32)
    w = _weights_for(spec, RNG, integer=True)
    st = stencil_apply(a, w, spec, block_i=3, block_j=block_j,
                       sweeps=sweeps, path="stream")
    rp = stencil_apply(a, w, spec, block_i=3, block_j=block_j,
                       sweeps=sweeps, path="replicate")
    np.testing.assert_array_equal(np.asarray(st), np.asarray(rp))
    np.testing.assert_array_equal(
        np.asarray(st), np.asarray(stencil_ref(a, w, spec, sweeps=sweeps)))


@pytest.mark.parametrize("name", ["stencil7", "stencil27", ASYM_SPEC])
@pytest.mark.parametrize("sweeps", [1, 2])
@pytest.mark.parametrize("block_j", [None, 4])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 4e-2)])
def test_stream_matches_replicate_float(name, sweeps, block_j, dtype, tol):
    """Float data: the two paths run the identical plan op walk, so they
    agree to (at most) per-program fma-contraction rounding."""
    spec = get_stencil(name)
    a = jnp.asarray(RNG.standard_normal((8, 12, 16)), dtype)
    w = _weights_for(spec, RNG)
    st = stencil_apply(a, w, spec, block_i=4, block_j=block_j,
                       sweeps=sweeps, path="stream")
    rp = stencil_apply(a, w, spec, block_i=4, block_j=block_j,
                       sweeps=sweeps, path="replicate")
    np.testing.assert_allclose(np.asarray(st, np.float32),
                               np.asarray(rp, np.float32),
                               rtol=tol, atol=tol)


def test_stream_f64_bit_identical_acceptance():
    """Acceptance: on the f64 reference configurations the streamed output
    is bit-identical to the replicated path and to stencil_ref -- fused
    sweeps and j-tiling included."""
    with jax.experimental.enable_x64():
        a = jnp.asarray(RNG.standard_normal((8, 10, 16)), jnp.float64)
        w = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float64)
        for sweeps in (1, 2):
            for bj in (None, 5):
                st = stencil_apply(a, w, "stencil27", block_i=4, block_j=bj,
                                   sweeps=sweeps, path="stream")
                rp = stencil_apply(a, w, "stencil27", block_i=4, block_j=bj,
                                   sweeps=sweeps, path="replicate")
                np.testing.assert_array_equal(np.asarray(st),
                                              np.asarray(rp))
                np.testing.assert_array_equal(
                    np.asarray(st),
                    np.asarray(stencil_ref(a, w, "stencil27",
                                           sweeps=sweeps)))


def test_stream_batched_and_blocking_invariance():
    """The scratch window re-primes per batch element and per j-tile: every
    (batch, blocking) combination is bit-identical on integer data."""
    a = jnp.asarray(RNG.integers(-4, 5, (2, 8, 12, 16)), jnp.float32)
    w = jnp.asarray(RNG.integers(1, 4, (2, 2, 2)), jnp.float32)
    base = stencil_apply(a, w, "stencil27", block_i=8, path="stream")
    for bi, bj in ((1, None), (2, None), (4, 6), (8, 3)):
        got = stencil_apply(a, w, "stencil27", block_i=bi, block_j=bj,
                            path="stream")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
    # each batch element equals its own unbatched streamed run
    one = stencil_apply(a[0], w, "stencil27", block_i=4, path="stream")
    np.testing.assert_array_equal(np.asarray(base[0]), np.asarray(one))


def test_default_path_is_streaming():
    """path="auto" resolves to the streaming kernel whenever it fits VMEM:
    via the autotuner and for pinned blocks."""
    plan = compile_plan("stencil27")
    path, bi, bj = autotune_engine(16, 24, 128, 4, plan=plan)
    assert path == "stream" and bj is None and 16 % bi == 0
    # modeled streamed step time never exceeds replicated at equal blocks
    assert (_step_time(4, None, 24, 128, 4, 1, plan.shifts, plan.flops,
                       "stream")
            <= _step_time(4, None, 24, 128, 4, 1, plan.shifts, plan.flops,
                          "replicate"))


def test_bytes_per_point_acceptance_numbers():
    """Acceptance: the cost model charges the streamed path <= 2.5 x
    itemsize bytes/point for stencil27 at sweeps=1 (each plane read once,
    written once) where the replicated path pays for every re-fetched halo
    view; j-tiled the gap widens (4 vs 10)."""
    for itemsize in (2, 4, 8):
        assert bytes_per_point("stream", itemsize) <= 2.5 * itemsize
        assert (bytes_per_point("stream", itemsize)
                < bytes_per_point("replicate", itemsize))
        assert bytes_per_point("replicate", itemsize) == 4 * itemsize
        assert bytes_per_point("stream", itemsize, j_tiled=True) \
            == 4 * itemsize
        assert bytes_per_point("replicate", itemsize, j_tiled=True) \
            == 10 * itemsize
    # fused sweeps amortize the traffic
    assert bytes_per_point("stream", 4, sweeps=2) == 4.0
    with pytest.raises(ValueError, match="path"):
        bytes_per_point("warp", 4)


def test_autotune_engine_paths():
    plan = compile_plan("stencil27")
    # pinned paths tune blocks for that path only
    for pinned in ("stream", "replicate"):
        path, bi, bj = autotune_engine(32, 48, 128, 4, plan=plan,
                                       path=pinned)
        assert path == pinned and 32 % bi == 0
    with pytest.raises(ValueError, match="path"):
        autotune_engine(8, 8, 128, 4, plan=plan, path="warp")
    # the streaming scratch window is charged against VMEM
    assert not _fits(8, None, 288, 1024, 4, 1, 4, 8 * 1024 * 1024, "stream")
    path, bi, bj = autotune_engine(8, 288, 1024, 4, plan=plan)
    assert bj is not None and 288 % bj == 0   # VMEM wall -> j-tiled stream


def test_stream_error_messages():
    a = jnp.zeros((8, 9, 16), jnp.float32)
    w = jnp.zeros((2, 2, 2), jnp.float32)
    with pytest.raises(ValueError, match="divide M"):
        stencil_apply(a, w, "stencil27", block_i=3, path="stream")
    with pytest.raises(ValueError, match="divide N"):
        stencil_apply(a, w, "stencil27", block_i=4, block_j=4, path="stream")
    with pytest.raises(ValueError, match="block_i >= sweeps"):
        stencil_apply(a, w, "stencil27", block_i=2, sweeps=3, path="stream")
    with pytest.raises(ValueError, match="block_j >= sweeps"):
        stencil_apply(a, w, "stencil27", block_i=4, block_j=3, sweeps=4,
                      path="stream")
    with pytest.raises(ValueError, match="path"):
        stencil_apply(a, w, "stencil27", block_i=4, path="warp")


def test_interpret_none_platform_default():
    """interpret=None resolves to "interpret only without a compiled
    backend for these kernels": True on CPU/GPU hosts (the engine's VMEM
    scratch windows are Mosaic-TPU-only), False on TPU -- and the resolved
    call works."""
    assert default_interpret() == (jax.default_backend() != "tpu")
    a = jnp.asarray(RNG.standard_normal((4, 6, 16)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)
    got = stencil_apply(a, w, "stencil27", block_i=2, interpret=None)
    ref = stencil_apply(a, w, "stencil27", block_i=2, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_compile_plan_memoized():
    """compile_plan is memoized on (spec identity, plan kind): repeated
    eager calls and equal-valued ad-hoc specs share one compiled plan, so
    un-jitted call sites and the autotuner don't rebuild the SSA schedule
    per call."""
    assert compile_plan("stencil27", "factored") is compile_plan(
        get_stencil("stencil27"), "factored")
    assert compile_plan("27") is compile_plan("stencil27")
    mask = np.zeros((3, 3, 3), bool)
    mask[1, 1, 0] = mask[1, 1, 1] = True
    s1 = spec_from_mask("memo-probe", mask)
    s2 = spec_from_mask("memo-probe", mask)
    assert s1 is not s2 and s1 == s2          # equal value, distinct objects
    assert compile_plan(s1, "cse") is compile_plan(s2, "cse")
    # distinct plan kinds stay distinct entries
    assert compile_plan("stencil27", "direct") is not compile_plan(
        "stencil27", "factored")


@pytest.mark.parametrize("name", ["star13", "box125"])
@pytest.mark.parametrize("sweeps", [1, 2])
@pytest.mark.parametrize("block_j", [None, 4])
def test_radius2_stream_matches_replicate_bit_exact_integer(name, sweeps,
                                                            block_j):
    """Acceptance: the radius-2 builtins run through both data-movement
    paths with bit-exact integer parity (and match the reference) across
    fused sweeps and j-tiling -- the streaming window now carries
    ``2 * sweeps`` halo planes and the replicated path stages 5 views."""
    spec = get_stencil(name)
    assert spec.radius == (2, 2, 2)
    a = jnp.asarray(RNG.integers(-4, 5, (12, 12, 16)), jnp.float32)
    w = _weights_for(spec, RNG, integer=True)
    st = stencil_apply(a, w, spec, block_i=4, block_j=block_j,
                       sweeps=sweeps, path="stream")
    rp = stencil_apply(a, w, spec, block_i=4, block_j=block_j,
                       sweeps=sweeps, path="replicate")
    np.testing.assert_array_equal(np.asarray(st), np.asarray(rp))
    np.testing.assert_array_equal(
        np.asarray(st), np.asarray(stencil_ref(a, w, spec, sweeps=sweeps)))


def test_radius2_stream_f64_bit_identical_acceptance():
    """Acceptance: on f64 *integer-valued* data (every reassociation exact
    within the mantissa -- the engine's cross-program parity discipline,
    see the plan IR docstring on per-program fma contraction) the radius-2
    streamed path, the replicated path, and stencil_ref are bit-identical
    across fused sweeps and j-tiling; on float f64 data the two compiled
    programs agree to per-op contraction rounding (<= ~1 ulp)."""
    with jax.experimental.enable_x64():
        for name in ("star13", "box125"):
            spec = get_stencil(name)
            a = jnp.asarray(RNG.integers(-4, 5, (8, 10, 16)), jnp.float64)
            w = jnp.asarray(RNG.integers(1, 4, spec.w_shape), jnp.float64)
            for sweeps in (1, 2):
                for bj in (None, 5):
                    st = stencil_apply(a, w, name, block_i=4, block_j=bj,
                                       sweeps=sweeps, path="stream")
                    rp = stencil_apply(a, w, name, block_i=4, block_j=bj,
                                       sweeps=sweeps, path="replicate")
                    np.testing.assert_array_equal(np.asarray(st),
                                                  np.asarray(rp))
                    np.testing.assert_array_equal(
                        np.asarray(st),
                        np.asarray(stencil_ref(a, w, name, sweeps=sweeps)))
            af = jnp.asarray(RNG.standard_normal((8, 10, 16)), jnp.float64)
            wf = jnp.asarray(RNG.uniform(0.1, 1.0, spec.w_shape),
                             jnp.float64)
            for sweeps in (1, 2):
                st = stencil_apply(af, wf, name, block_i=4, sweeps=sweeps,
                                   path="stream")
                rp = stencil_apply(af, wf, name, block_i=4, sweeps=sweeps,
                                   path="replicate")
                np.testing.assert_allclose(np.asarray(st), np.asarray(rp),
                                           rtol=1e-13, atol=1e-13)
                np.testing.assert_allclose(
                    np.asarray(st),
                    np.asarray(stencil_ref(af, wf, name, sweeps=sweeps)),
                    rtol=1e-13, atol=1e-13)


def test_radius2_blocking_invariance():
    """Radius-2 streaming is blocking-invariant on integer data, and the
    deep-halo validation rejects blocks thinner than radius * sweeps."""
    a = jnp.asarray(RNG.integers(-4, 5, (12, 12, 16)), jnp.float32)
    w = jnp.asarray(RNG.integers(1, 4, (3,)), jnp.float32)
    base = stencil_apply(a, w, "star13", block_i=12, path="stream")
    for bi, bj in ((2, None), (3, None), (4, 6), (6, 4)):
        got = stencil_apply(a, w, "star13", block_i=bi, block_j=bj,
                            path="stream")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
    with pytest.raises(ValueError, match="block_i >= sweeps"):
        stencil_apply(a, w, "star13", block_i=3, sweeps=2)
    with pytest.raises(ValueError, match="block_j >= sweeps"):
        stencil_apply(a, w, "star13", block_i=6, block_j=3, sweeps=2)


def test_radius2_bytes_per_point_numbers():
    """The cost model stays honest at radius 2: streaming still moves
    ~2 x itemsize/point untiled while the replicated path grows to
    (2r+2) = 6 untiled and (2r+1)^2+1 = 26 j-tiled."""
    for itemsize in (2, 4, 8):
        assert bytes_per_point("stream", itemsize, radius=2) \
            == 2 * itemsize
        assert bytes_per_point("stream", itemsize, radius=2) \
            <= 2.5 * itemsize
        assert bytes_per_point("replicate", itemsize, radius=2) \
            == 6 * itemsize
        assert bytes_per_point("stream", itemsize, j_tiled=True, radius=2) \
            == 6 * itemsize
        assert bytes_per_point("replicate", itemsize, j_tiled=True,
                               radius=2) == 26 * itemsize
    # radius defaults to the plan's spec inside autotune_engine
    plan = compile_plan("star13")
    path, bi, bj = autotune_engine(16, 24, 128, 4, plan=plan)
    assert path == "stream" and 16 % bi == 0 and bi >= 2


def test_radius2_sharded_stream_two_devices_subprocess():
    """Radius-2 halo exchange: the shard_map body trades radius * sweeps
    rows per neighbour and stays bit-identical to the single-device
    streamed run -- on forced host devices."""
    code = """
        import jax, numpy as np, jax.numpy as jnp
        assert jax.device_count() == 2, jax.devices()
        from repro.kernels import stencil_apply, stencil_sharded
        from repro.sharding.planner import stencil_halo_sharding
        rng = np.random.default_rng(5)
        a = jnp.asarray(rng.integers(-4, 5, (16, 12, 16)), jnp.float32)
        mesh = jax.make_mesh((2,), ("data",))
        for name, wshape in (("star13", (3,)), ("box125", (3, 3, 3))):
            w = jnp.asarray(rng.integers(1, 4, wshape), jnp.float32)
            for s in (1, 2):
                plan = stencil_halo_sharding(16, mesh, sweeps=s, radius=2)
                assert plan.n_shards == 2 and plan.halo == 2 * s
                st = stencil_sharded(a, w, name, mesh=mesh, sweeps=s,
                                     path="stream")
                rp = stencil_sharded(a, w, name, mesh=mesh, sweeps=s,
                                     path="replicate")
                one = stencil_apply(a, w, name, block_i=4, sweeps=s,
                                    path="stream")
                np.testing.assert_array_equal(np.asarray(st), np.asarray(rp))
                np.testing.assert_array_equal(np.asarray(st),
                                              np.asarray(one))
        print("radius2 sharded ok")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "radius2 sharded ok" in out.stdout


def test_radius2_shard_plan_halo_mismatch_raises():
    """An explicit shard_plan whose halo can't cover radius * sweeps is
    rejected with a clear message instead of silently corrupting seams."""
    from repro.sharding.planner import StencilShardPlan
    from jax.sharding import PartitionSpec as P
    a = jnp.zeros((16, 8, 16), jnp.float32)
    w = jnp.zeros((3,), jnp.float32)
    bad = StencilShardPlan(axis="data", n_shards=2, halo=1, local_rows=8,
                           spec=P(None, "data", None, None), notes=[])
    from repro.kernels import stencil_sharded
    with pytest.raises(ValueError, match="halo"):
        stencil_sharded(a, w, "star13", sweeps=1, shard_plan=bad)


def test_sharded_stream_two_devices_subprocess():
    """The shard_map body streams too: 2-device halo-exchange with
    path="stream" is bit-identical to the single-device streamed run and to
    the explicit replicated sharded run -- on forced host devices."""
    code = """
        import jax, numpy as np, jax.numpy as jnp
        assert jax.device_count() == 2, jax.devices()
        from repro.kernels import stencil_apply, stencil_sharded
        rng = np.random.default_rng(5)
        a = jnp.asarray(rng.integers(-4, 5, (16, 10, 16)), jnp.float32)
        w = jnp.asarray(rng.integers(1, 4, (2, 2, 2)), jnp.float32)
        mesh = jax.make_mesh((2,), ("data",))
        for s in (1, 2):
            st = stencil_sharded(a, w, "stencil27", mesh=mesh, sweeps=s,
                                 path="stream")
            rp = stencil_sharded(a, w, "stencil27", mesh=mesh, sweeps=s,
                                 path="replicate")
            one = stencil_apply(a, w, "stencil27", block_i=4, sweeps=s,
                                path="stream")
            np.testing.assert_array_equal(np.asarray(st), np.asarray(rp))
            np.testing.assert_array_equal(np.asarray(st), np.asarray(one))
        print("stream sharded ok")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "stream sharded ok" in out.stdout
