"""End-to-end: synthesized + scheduled kernels compute correct stencils."""

import pytest

from repro.core.codegen import allocate_registers, render_c
from repro.core.scheduler import greedy_schedule
from repro.core.synth import PAPER_CONFIGS, StencilConfig, synth_stencil
from repro.core.verify import run_kernel

EXTRA = [StencilConfig(3, "mm", 1, 1), StencilConfig(3, "mm", 2, 2),
         StencilConfig(7, "mm", 1, 1), StencilConfig(7, "lc", 1, 1),
         StencilConfig(27, "mm", 2, 1), StencilConfig(27, "mm", 3, 1)]


@pytest.mark.parametrize("cfg", PAPER_CONFIGS + EXTRA, ids=lambda c: c.name)
def test_scheduled_kernel_matches_oracle(cfg):
    r = run_kernel(cfg, t_iters=5)
    assert r.ok, f"max err {r.max_abs_err}"


@pytest.mark.parametrize("cfg", PAPER_CONFIGS, ids=lambda c: c.name)
def test_unscheduled_kernel_matches_oracle(cfg):
    r = run_kernel(cfg, t_iters=4, schedule=False)
    assert r.ok


@pytest.mark.parametrize("seed", range(4))
def test_kernel_matches_oracle_random_seeds(seed):
    r = run_kernel(StencilConfig(27, "mm", 2, 3), t_iters=4, seed=seed)
    assert r.ok


@pytest.mark.parametrize("cfg", PAPER_CONFIGS, ids=lambda c: c.name)
def test_register_budget(cfg):
    """Paper constraint (ILP eqs. 12-13): kernels fit 32 FPRs / 32 GPRs.

    Documented deviation (DESIGN.md sect. 8): our aligned-result 7-lc
    reconstruction needs 3 registers per centre stream, so at 2x3 it exceeds
    the FPR file (36) where the paper's (unreconstructible) 2-register scheme
    fits at 30.  All cycle-determining counts still match Table 2.
    """
    k = synth_stencil(cfg)
    if cfg.name == "7-lc-2x3":
        with pytest.raises(RuntimeError):
            allocate_registers(k.body)
        return
    _, fprs, gprs = allocate_registers(k.body)
    assert fprs <= 32
    assert gprs <= 32


def test_codegen_renders_scheduled_asm():
    k = synth_stencil(StencilConfig(3, "lc", 1, 1))
    s = greedy_schedule(k.body)
    src = render_c([k.body[i] for i in s.order], name="stencil3_lc")
    assert "__asm__ volatile" in src
    assert "lfpdx" in src and "stfpdx" in src and "fxcxma" in src
    assert "void stencil3_lc" in src


def test_register_pressure_detected():
    """Over-aggressive jams exceed the FPR file and are rejected."""
    k = synth_stencil(StencilConfig(27, "mm", 3, 3))   # 25 rows + 9 acc + 4 W
    with pytest.raises(RuntimeError):
        allocate_registers(k.body)
