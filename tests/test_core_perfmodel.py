"""The analytic model reproduces paper Table 3; scheduler quality is bounded."""

import pytest

from repro.core.perfmodel import PAPER_TABLE3, analyze
from repro.core.synth import PAPER_CONFIGS

ESTIMATES = {}


def _est(cfg):
    if cfg.name not in ESTIMATES:
        ESTIMATES[cfg.name] = analyze(cfg)
    return ESTIMATES[cfg.name]


@pytest.mark.parametrize("cfg", PAPER_CONFIGS, ids=lambda c: c.name)
def test_analytic_columns_exact(cfg):
    """Naive instruction limit + L1/streaming bandwidth limits match exactly."""
    e = _est(cfg)
    naive, _, l1, stream, *_ = PAPER_TABLE3[cfg.name]
    assert abs(e.naive_mstencil - naive) < 0.02
    assert abs(e.l1_bw_mstencil - l1) < 0.02
    assert abs(e.streaming_bw_mstencil - stream) < 0.02


@pytest.mark.parametrize("cfg", PAPER_CONFIGS, ids=lambda c: c.name)
def test_simulated_close_or_better(cfg):
    """OOO-mode makespan within 10% of the paper's simulated value, or better
    (our greedy scheduler finds tighter schedules for several configs)."""
    e = _est(cfg)
    paper_sim = PAPER_TABLE3[cfg.name][1]
    assert e.simulated_mstencil >= 0.90 * paper_sim


@pytest.mark.parametrize("cfg", [c for c in PAPER_CONFIGS
                                 if c.name.startswith("27")],
                         ids=lambda c: c.name)
def test_27pt_simulated_within_6pct(cfg):
    e = _est(cfg)
    paper_sim = PAPER_TABLE3[cfg.name][1]
    assert abs(e.simulated_mstencil - paper_sim) / paper_sim < 0.06


@pytest.mark.parametrize("cfg", PAPER_CONFIGS, ids=lambda c: c.name)
def test_limit_ordering(cfg):
    """Structural sanity: simulated <= naive; streaming <= L3 <= L1 bw."""
    e = _est(cfg)
    assert e.simulated_mstencil <= e.naive_mstencil + 0.01
    assert e.streaming_bw_mstencil <= e.l3_bw_mstencil <= e.l1_bw_mstencil
    assert e.predicted_l1 <= e.simulated_mstencil + 0.01
    assert e.schedule_lower_bound > 0


def test_27pt_reaches_85pct_of_peak():
    """Paper headline: 27-pt 2x3 reaches 85% of arithmetic peak in-L1.

    Peak = 62.96 Mstencil/s (27 FMAs/stencil at 1 SIMD FMA/cycle).
    """
    from repro.core.synth import StencilConfig
    e = _est(StencilConfig(27, "mm", 2, 3))
    assert e.predicted_l1 / 62.96 > 0.85


def test_mm_vs_lc_tradeoff():
    """Table 1 spectrum: mm pressures the LSU, lc pressures the FPU."""
    from repro.core.synth import StencilConfig
    mm = _est(StencilConfig(7, "mm", 2, 3))
    lc = _est(StencilConfig(7, "lc", 2, 3))
    assert mm.counts.lsu_cycles > mm.counts.fpu      # mm LSU-bound
    assert lc.counts.fpu > lc.counts.lsu_cycles      # lc FPU-bound
    # lc's naive instruction limit is higher because load/store cycles are
    # the 7-pt bottleneck (paper sect. 5.2).  (Our *schedules* close the gap:
    # both land within 2% of their structural limits, see EXPERIMENTS.md.)
    assert lc.naive_mstencil > mm.naive_mstencil
