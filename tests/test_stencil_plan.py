"""Plan-correctness properties: factored / cse / direct schedules agree --
bit-identically in f64 (integer-valued data makes every reassociation exact),
to tolerance in f32/bf16 -- across random ``spec_from_mask`` masks, fused
sweeps, and j-tiled vs untiled blockings (hypothesis, stub fallback)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # optional dev dep -- property tests skip, rest runs
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.kernels import (compile_plan, spec_from_mask, stencil_apply,
                           stencil_ref)
from repro.kernels.stencil_engine.plan import mirror_symmetric

ORBITS = [(a, b, c) for a in (0, 1) for b in (0, 1) for c in (0, 1)]


def _symmetric_mask(rng) -> np.ndarray:
    """Random mirror-symmetric coefficient-index mask: a nonempty union of
    sign-flip orbits of |offset| classes, one shared weight per orbit."""
    keep = [o for o in ORBITS if rng.random() < 0.6]
    if not keep:
        keep = [ORBITS[rng.integers(len(ORBITS))]]
    m = -np.ones((3, 3, 3), np.int64)
    for idx, (a, b, c) in enumerate(keep):
        for di in ({-a, a}):
            for dj in ({-b, b}):
                for dk in ({-c, c}):
                    m[di + 1, dj + 1, dk + 1] = idx
    return m


def _arbitrary_mask(rng) -> np.ndarray:
    m = rng.random((3, 3, 3)) < 0.4
    if not m.any():
        m[1, 1, 1] = True
    return m


def _plans_for(spec):
    plans = ["direct", "cse"]
    if mirror_symmetric(spec):
        plans.append("factored")
    return plans


def check_plans_agree(seed: int, sweeps: int, block_j, symmetric: bool):
    """The property body (also exercised by the fixed-seed smoke test)."""
    rng = np.random.default_rng(seed)
    mask = _symmetric_mask(rng) if symmetric else _arbitrary_mask(rng)
    spec = spec_from_mask(f"prop-{'s' if symmetric else 'a'}{seed}", mask)
    if symmetric:
        assert mirror_symmetric(spec)
    plans = _plans_for(spec)
    shape = (6, 8, 16)

    # f64 + integer-valued data: every sum is exact, so reassociated plans
    # (and any blocking) must agree bit-for-bit.
    with jax.experimental.enable_x64():
        a = jnp.asarray(rng.integers(-4, 5, shape), jnp.float64)
        w = jnp.asarray(rng.integers(1, 4, spec.n_weights), jnp.float64)
        outs = [np.asarray(stencil_apply(a, w, spec, block_i=3,
                                         block_j=block_j, plan=p,
                                         sweeps=sweeps))
                for p in plans]
        ref = np.asarray(stencil_ref(a, w, spec, sweeps=sweeps,
                                     plan="direct"))
        for got in outs:
            np.testing.assert_array_equal(got, ref)

    # f32 / bf16 float data: reassociation agrees to rounding.
    for dtype, tol in ((jnp.float32, 2e-5), (jnp.bfloat16, 4e-2)):
        af = jnp.asarray(rng.standard_normal(shape), dtype)
        wf = jnp.asarray(rng.uniform(0.1, 1.0, spec.n_weights), jnp.float32)
        base = None
        for p in plans:
            got = np.asarray(stencil_apply(af, wf, spec, block_i=3,
                                           block_j=block_j, plan=p,
                                           sweeps=sweeps), np.float32)
            if base is None:
                base = got
            else:
                np.testing.assert_allclose(got, base, rtol=tol, atol=tol)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 2),
       st.sampled_from([None, 4]), st.booleans())
def test_plans_agree_property(seed, sweeps, block_j, symmetric):
    check_plans_agree(seed, sweeps, block_j, symmetric)


@pytest.mark.parametrize("seed,sweeps,block_j,symmetric", [
    (7, 1, None, True),
    (7, 2, 4, True),
    (11, 1, 4, False),
    (23, 2, None, False),
])
def test_plans_agree_fixed_examples(seed, sweeps, block_j, symmetric):
    """Deterministic instances of the property -- run even without
    hypothesis installed."""
    check_plans_agree(seed, sweeps, block_j, symmetric)


def test_plan_shift_counts_never_exceed_direct():
    """cse/factored are optimizations: for random masks they never emit more
    shifts than the naive schedule, and flops never grow."""
    rng = np.random.default_rng(0)
    for k in range(20):
        sym = k % 2 == 0
        mask = _symmetric_mask(rng) if sym else _arbitrary_mask(rng)
        spec = spec_from_mask(f"cnt{k}", mask)
        direct = compile_plan(spec, "direct")
        for kind in _plans_for(spec)[1:]:
            p = compile_plan(spec, kind)
            assert p.shifts <= direct.shifts, (kind, spec.offsets)
            assert p.flops <= direct.flops, (kind, spec.offsets)
