"""Unified stencil engine: registry, parity, batching, fused sweeps,
autotuning, and 2-device halo-exchange sharding (subprocess)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (get_stencil, list_stencils, spec_from_mask,
                           stencil_apply, stencil_ref, stencil3_ref,
                           stencil7_ref, stencil27_ref)
from repro.kernels.stencil_engine.autotune import (autotune_block_i,
                                                   pick_block_i)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(7)


def _naive27(a, w):
    """Independent numpy oracle (not engine-backed)."""
    a = np.asarray(a, np.float64)
    w = np.asarray(w, np.float64)
    out = np.zeros_like(a)
    for i in range(1, a.shape[0] - 1):
        for j in range(1, a.shape[1] - 1):
            for k in range(1, a.shape[2] - 1):
                s = 0.0
                for di in (-1, 0, 1):
                    for dj in (-1, 0, 1):
                        for dk in (-1, 0, 1):
                            s += (w[abs(di), abs(dj), abs(dk)]
                                  * a[i + di, j + dj, k + dk])
                out[i, j, k] = s
    return out


def test_registry_names_and_aliases():
    assert get_stencil("stencil27") is get_stencil("27")
    assert get_stencil(27).taps == 27
    assert get_stencil("stencil7").taps == 7
    assert get_stencil("stencil3").taps == 3
    assert {"stencil3", "stencil7", "stencil27"} <= set(list_stencils())
    with pytest.raises(KeyError):
        get_stencil("stencil99")


def test_engine_matches_independent_oracle():
    """Non-circular check: the engine against a hand-rolled numpy loop."""
    a = jnp.asarray(RNG.standard_normal((6, 7, 9)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)
    got = stencil_apply(a, w, "stencil27", block_i=3)
    np.testing.assert_allclose(np.asarray(got, np.float64), _naive27(a, w),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name,wshape", [("stencil7", (4,)),
                                         ("stencil27", (2, 2, 2))])
@pytest.mark.parametrize("shape,bi", [((8, 16, 32), 4),   # even everywhere
                                      ((9, 11, 17), 3),   # odd everywhere
                                      ((10, 8, 24), 5)])  # mixed
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_volumetric_parity_sizes_dtypes(name, wshape, shape, bi, dtype):
    a = jnp.asarray(RNG.standard_normal(shape), dtype)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, wshape), jnp.float32)
    got = stencil_apply(a, w, name, block_i=bi)
    ref = stencil_ref(a.astype(jnp.float32), w, name).astype(dtype)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_f64_bit_for_bit_parity():
    """In f64 the kernel and the refs agree exactly (same tap order, same
    arithmetic) -- the engine's reference path."""
    with jax.experimental.enable_x64():
        a = jnp.asarray(RNG.standard_normal((8, 10, 16)), jnp.float64)
        a2 = jnp.asarray(RNG.standard_normal((6, 32)), jnp.float64)
        w27 = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float64)
        w7 = jnp.asarray(RNG.uniform(0.1, 1.0, 4), jnp.float64)
        w3 = jnp.asarray(RNG.uniform(0.1, 1.0, 2), jnp.float64)
        np.testing.assert_array_equal(
            np.asarray(stencil_apply(a, w27, "stencil27", block_i=4)),
            np.asarray(stencil27_ref(a, w27)))
        np.testing.assert_array_equal(
            np.asarray(stencil_apply(a, w7, "stencil7", block_i=2)),
            np.asarray(stencil7_ref(a, w7)))
        np.testing.assert_array_equal(
            np.asarray(stencil_apply(a2, w3, "stencil3", block_i=3)),
            np.asarray(stencil3_ref(a2, w3)))
        # fused sweeps stay bit-exact too
        np.testing.assert_array_equal(
            np.asarray(stencil_apply(a, w27, "stencil27", block_i=4,
                                     sweeps=3)),
            np.asarray(stencil_ref(a, w27, "stencil27", sweeps=3)))


@pytest.mark.parametrize("batch", [(2,), (2, 3)])
def test_batched_execution(batch):
    shape = batch + (8, 10, 16)
    a = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)
    got = stencil_apply(a, w, "stencil27", block_i=4)
    ref = stencil_ref(a, w, "stencil27")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # every batch element equals its own unbatched run
    one = stencil_apply(a.reshape(-1, 8, 10, 16)[0], w, "stencil27",
                        block_i=4)
    np.testing.assert_array_equal(
        np.asarray(got.reshape(-1, 8, 10, 16)[0]), np.asarray(one))


@pytest.mark.parametrize("sweeps", [1, 2, 3])
@pytest.mark.parametrize("name", ["stencil3", "stencil7", "stencil27"])
def test_fused_sweeps_match_iterated(name, sweeps):
    spec = get_stencil(name)
    if spec.ndim == 1:
        a = jnp.asarray(RNG.standard_normal((8, 32)), jnp.float32)
        w = jnp.asarray(RNG.uniform(0.1, 1.0, 2), jnp.float32)
        bi = 4
    else:
        a = jnp.asarray(RNG.standard_normal((8, 10, 16)), jnp.float32)
        w = jnp.asarray(RNG.uniform(0.1, 1.0, spec.w_shape), jnp.float32)
        bi = 4
    fused = stencil_apply(a, w, name, block_i=bi, sweeps=sweeps)
    it = a
    for _ in range(sweeps):
        it = stencil_apply(it, w, name, block_i=bi)
    # f32: up to FMA-contraction noise between the two compiled programs
    # (the f64 path is asserted bit-exact in test_f64_bit_for_bit_parity)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(it),
                               rtol=1e-6, atol=1e-6)
    ref = stencil_ref(a, w, name, sweeps=sweeps)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_sweeps_deeper_than_block_halo_raises():
    a = jnp.zeros((8, 8, 16), jnp.float32)
    w = jnp.zeros((2, 2, 2), jnp.float32)
    with pytest.raises(ValueError, match="halo"):
        stencil_apply(a, w, "stencil27", block_i=2, sweeps=3)


def test_custom_mask_spec():
    """An ad-hoc mask (i-axis-only 3-point) runs through the same engine."""
    mask = -np.ones((3, 3, 3), np.int64)
    mask[0, 1, 1] = 0          # (di=-1) -> w[0]
    mask[1, 1, 1] = 1          # centre  -> w[1]
    mask[2, 1, 1] = 0          # (di=+1) -> w[0]
    spec = spec_from_mask("i3", mask)
    assert spec.taps == 3 and spec.n_weights == 2
    a = jnp.asarray(RNG.standard_normal((8, 6, 16)), jnp.float32)
    w = jnp.asarray([0.25, 0.5], jnp.float32)
    got = stencil_apply(a, w, spec, block_i=4)
    ref = stencil_ref(a, w, spec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # hand-check one interior point
    i, j, k = 3, 2, 5
    expect = float(0.25 * a[i - 1, j, k] + 0.5 * a[i, j, k]
                   + 0.25 * a[i + 1, j, k])
    assert abs(float(got[i, j, k]) - expect) < 1e-5


def test_boolean_mask_assigns_unique_weights():
    mask = np.zeros((3, 3, 3), bool)
    mask[1, 1, 0] = mask[1, 1, 1] = mask[1, 1, 2] = True
    spec = spec_from_mask("k3-unsym", mask)
    assert spec.n_weights == 3
    a = jnp.asarray(RNG.standard_normal((4, 6, 16)), jnp.float32)
    w = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    got = stencil_apply(a, w, spec, block_i=2)
    i, j, k = 2, 3, 7
    expect = float(1.0 * a[i, j, k - 1] + 2.0 * a[i, j, k]
                   + 3.0 * a[i, j, k + 1])
    assert abs(float(got[i, j, k]) - expect) < 1e-5


def test_autotuner_properties():
    for m, n, p, s in [(32, 48, 128, 1), (30, 30, 30, 2), (16, 8, 128, 3)]:
        bi = autotune_block_i(m, n, p, 4, sweeps=s)
        assert m % bi == 0 and bi >= s, (m, bi, s)
    # legacy alias keeps its contract (divisor, fits the budget reasoning)
    assert 32 % pick_block_i(32, 48, 128, 4) == 0
    # huge planes fall back to small feasible blocks rather than exploding
    bi = autotune_block_i(1024, 512, 512, 4)
    assert 1024 % bi == 0


def test_planner_fallbacks_and_plan():
    from repro.sharding.planner import stencil_halo_sharding
    mesh = jax.make_mesh((1,), ("data",))
    plan = stencil_halo_sharding(16, mesh, sweeps=1)
    assert plan.n_shards == 1                      # 1 device: unsharded
    assert any("unsharded" in n.reason for n in plan.notes)


def test_sharded_two_devices_subprocess():
    """2-device shard_map halo-exchange == single-device engine, bit-exact,
    for s in {1, 2} -- on forced host-platform devices."""
    code = """
        import jax, numpy as np, jax.numpy as jnp
        assert jax.device_count() == 2, jax.devices()
        from repro.kernels import stencil_apply, stencil_ref, stencil_sharded
        from repro.sharding.planner import stencil_halo_sharding
        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.standard_normal((16, 10, 16)), jnp.float32)
        w = jnp.asarray(rng.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)
        mesh = jax.make_mesh((2,), ("data",))
        for s in (1, 2):
            plan = stencil_halo_sharding(16, mesh, sweeps=s)
            assert plan.n_shards == 2 and plan.halo == s
            got = stencil_sharded(a, w, "stencil27", mesh=mesh, sweeps=s)
            one = stencil_apply(a, w, "stencil27", block_i=4, sweeps=s)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(one))
            ref = stencil_ref(a, w, "stencil27", sweeps=s)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
        # batched + sharded
        ab = jnp.asarray(rng.standard_normal((2, 16, 8, 16)), jnp.float32)
        got = stencil_sharded(ab, w, "stencil27", mesh=mesh, sweeps=2)
        one = stencil_apply(ab, w, "stencil27", block_i=4, sweeps=2)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(one))
        print("sharded ok")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "sharded ok" in out.stdout
