"""Unified stencil engine: registry, parity, batching, fused sweeps,
autotuning, and 2-device halo-exchange sharding (subprocess)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (compile_plan, get_stencil, list_stencils,
                           spec_from_mask, stencil_apply, stencil_ref,
                           stencil3_ref, stencil7_ref, stencil27_ref)
from repro.kernels.stencil_engine.autotune import (autotune_block_i,
                                                   autotune_blocks,
                                                   pick_block_i,
                                                   pick_block_rows)
from repro.kernels.stencil_engine.plan import mirror_symmetric

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(7)


def _naive27(a, w):
    """Independent numpy oracle (not engine-backed)."""
    a = np.asarray(a, np.float64)
    w = np.asarray(w, np.float64)
    out = np.zeros_like(a)
    for i in range(1, a.shape[0] - 1):
        for j in range(1, a.shape[1] - 1):
            for k in range(1, a.shape[2] - 1):
                s = 0.0
                for di in (-1, 0, 1):
                    for dj in (-1, 0, 1):
                        for dk in (-1, 0, 1):
                            s += (w[abs(di), abs(dj), abs(dk)]
                                  * a[i + di, j + dj, k + dk])
                out[i, j, k] = s
    return out


def test_registry_names_and_aliases():
    assert get_stencil("stencil27") is get_stencil("27")
    assert get_stencil(27).taps == 27
    assert get_stencil("stencil7").taps == 7
    assert get_stencil("stencil3").taps == 3
    assert {"stencil3", "stencil7", "stencil27"} <= set(list_stencils())
    with pytest.raises(KeyError):
        get_stencil("stencil99")


def test_engine_matches_independent_oracle():
    """Non-circular check: the engine against a hand-rolled numpy loop."""
    a = jnp.asarray(RNG.standard_normal((6, 7, 9)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)
    got = stencil_apply(a, w, "stencil27", block_i=3)
    np.testing.assert_allclose(np.asarray(got, np.float64), _naive27(a, w),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name,wshape", [("stencil7", (4,)),
                                         ("stencil27", (2, 2, 2))])
@pytest.mark.parametrize("shape,bi", [((8, 16, 32), 4),   # even everywhere
                                      ((9, 11, 17), 3),   # odd everywhere
                                      ((10, 8, 24), 5)])  # mixed
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_volumetric_parity_sizes_dtypes(name, wshape, shape, bi, dtype):
    a = jnp.asarray(RNG.standard_normal(shape), dtype)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, wshape), jnp.float32)
    got = stencil_apply(a, w, name, block_i=bi)
    ref = stencil_ref(a.astype(jnp.float32), w, name).astype(dtype)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_f64_bit_for_bit_parity():
    """In f64 the kernel and the refs agree exactly (same tap order, same
    arithmetic) -- the engine's reference path."""
    with jax.experimental.enable_x64():
        a = jnp.asarray(RNG.standard_normal((8, 10, 16)), jnp.float64)
        a2 = jnp.asarray(RNG.standard_normal((6, 32)), jnp.float64)
        w27 = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float64)
        w7 = jnp.asarray(RNG.uniform(0.1, 1.0, 4), jnp.float64)
        w3 = jnp.asarray(RNG.uniform(0.1, 1.0, 2), jnp.float64)
        np.testing.assert_array_equal(
            np.asarray(stencil_apply(a, w27, "stencil27", block_i=4)),
            np.asarray(stencil27_ref(a, w27)))
        np.testing.assert_array_equal(
            np.asarray(stencil_apply(a, w7, "stencil7", block_i=2)),
            np.asarray(stencil7_ref(a, w7)))
        np.testing.assert_array_equal(
            np.asarray(stencil_apply(a2, w3, "stencil3", block_i=3)),
            np.asarray(stencil3_ref(a2, w3)))
        # fused sweeps stay bit-exact too
        np.testing.assert_array_equal(
            np.asarray(stencil_apply(a, w27, "stencil27", block_i=4,
                                     sweeps=3)),
            np.asarray(stencil_ref(a, w27, "stencil27", sweeps=3)))


@pytest.mark.parametrize("batch", [(2,), (2, 3)])
def test_batched_execution(batch):
    shape = batch + (8, 10, 16)
    a = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)
    got = stencil_apply(a, w, "stencil27", block_i=4)
    ref = stencil_ref(a, w, "stencil27")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # every batch element equals its own unbatched run
    one = stencil_apply(a.reshape(-1, 8, 10, 16)[0], w, "stencil27",
                        block_i=4)
    np.testing.assert_array_equal(
        np.asarray(got.reshape(-1, 8, 10, 16)[0]), np.asarray(one))


@pytest.mark.parametrize("sweeps", [1, 2, 3])
@pytest.mark.parametrize("name", ["stencil3", "stencil7", "stencil27"])
def test_fused_sweeps_match_iterated(name, sweeps):
    spec = get_stencil(name)
    if spec.ndim == 1:
        a = jnp.asarray(RNG.standard_normal((8, 32)), jnp.float32)
        w = jnp.asarray(RNG.uniform(0.1, 1.0, 2), jnp.float32)
        bi = 4
    else:
        a = jnp.asarray(RNG.standard_normal((8, 10, 16)), jnp.float32)
        w = jnp.asarray(RNG.uniform(0.1, 1.0, spec.w_shape), jnp.float32)
        bi = 4
    fused = stencil_apply(a, w, name, block_i=bi, sweeps=sweeps)
    it = a
    for _ in range(sweeps):
        it = stencil_apply(it, w, name, block_i=bi)
    # f32: up to FMA-contraction noise between the two compiled programs
    # (the f64 path is asserted bit-exact in test_f64_bit_for_bit_parity)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(it),
                               rtol=1e-6, atol=1e-6)
    ref = stencil_ref(a, w, name, sweeps=sweeps)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_sweeps_deeper_than_block_halo_raises():
    a = jnp.zeros((8, 8, 16), jnp.float32)
    w = jnp.zeros((2, 2, 2), jnp.float32)
    with pytest.raises(ValueError, match="halo"):
        stencil_apply(a, w, "stencil27", block_i=2, sweeps=3)


def test_custom_mask_spec():
    """An ad-hoc mask (i-axis-only 3-point) runs through the same engine."""
    mask = -np.ones((3, 3, 3), np.int64)
    mask[0, 1, 1] = 0          # (di=-1) -> w[0]
    mask[1, 1, 1] = 1          # centre  -> w[1]
    mask[2, 1, 1] = 0          # (di=+1) -> w[0]
    spec = spec_from_mask("i3", mask)
    assert spec.taps == 3 and spec.n_weights == 2
    a = jnp.asarray(RNG.standard_normal((8, 6, 16)), jnp.float32)
    w = jnp.asarray([0.25, 0.5], jnp.float32)
    got = stencil_apply(a, w, spec, block_i=4)
    ref = stencil_ref(a, w, spec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # hand-check one interior point
    i, j, k = 3, 2, 5
    expect = float(0.25 * a[i - 1, j, k] + 0.5 * a[i, j, k]
                   + 0.25 * a[i + 1, j, k])
    assert abs(float(got[i, j, k]) - expect) < 1e-5


def test_boolean_mask_assigns_unique_weights():
    mask = np.zeros((3, 3, 3), bool)
    mask[1, 1, 0] = mask[1, 1, 1] = mask[1, 1, 2] = True
    spec = spec_from_mask("k3-unsym", mask)
    assert spec.n_weights == 3
    a = jnp.asarray(RNG.standard_normal((4, 6, 16)), jnp.float32)
    w = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    got = stencil_apply(a, w, spec, block_i=2)
    i, j, k = 2, 3, 7
    expect = float(1.0 * a[i, j, k - 1] + 2.0 * a[i, j, k]
                   + 3.0 * a[i, j, k + 1])
    assert abs(float(got[i, j, k]) - expect) < 1e-5


def test_plan_op_counts_factored_vs_direct():
    """Acceptance: the stencil27 factored plan is <= 1/3 of the direct
    plan's shifts and <= 40% of its flops, statically, via the plan IR."""
    direct = compile_plan("stencil27", "direct")
    factored = compile_plan("stencil27", "factored")
    cse = compile_plan("stencil27", "cse")
    assert (direct.shifts, direct.flops) == (54, 53)   # 27 muls + 26 adds
    assert factored.shifts * 3 <= direct.shifts
    assert factored.flops <= 0.4 * direct.flops
    assert cse.shifts < direct.shifts and cse.flops == direct.flops
    # auto selects the modeled-fastest (kind, unroll) -- the chosen variant
    # is never modeled-slower than any explicit kind (factored stays in the
    # candidate set for the symmetric built-ins, cse otherwise)
    for name in ("stencil3", "stencil7", "stencil27"):
        assert mirror_symmetric(get_stencil(name))
        auto = compile_plan(name, "auto")
        assert auto.kind in ("cse", "factored")
        for kind in ("direct", "cse", "factored"):
            explicit = compile_plan(name, kind)
            assert (auto.modeled.cycles_per_point
                    <= explicit.modeled.cycles_per_point + 1e-9)
    mask = np.zeros((3, 3, 3), bool)
    mask[1, 1, 1] = mask[1, 1, 2] = True               # no -k mirror tap
    lop = spec_from_mask("lop", mask)
    assert not mirror_symmetric(lop)
    assert compile_plan(lop, "auto").kind == "cse"
    with pytest.raises(ValueError, match="mirror-symmetric"):
        compile_plan(lop, "factored")


def test_plan_kinds_agree_and_match_ref():
    """Every plan kind is bit-identical to the same-plan reference; across
    plan kinds the reassociated sums agree to f32 rounding."""
    a = jnp.asarray(RNG.standard_normal((8, 12, 16)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)
    outs = {}
    for plan in ("direct", "cse", "factored"):
        got = stencil_apply(a, w, "stencil27", block_i=4, plan=plan)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(stencil_ref(a, w, "stencil27",
                                                    plan=plan)))
        outs[plan] = np.asarray(got)
    for plan in ("cse", "factored"):
        np.testing.assert_allclose(outs[plan], outs["direct"],
                                   rtol=1e-5, atol=1e-5)


def test_factored_f64_bit_identical_to_ref():
    """Acceptance: stencil27 factored, f64, bit-identical to stencil_ref --
    blocked kernel vs full-array oracle, fused sweeps included."""
    with jax.experimental.enable_x64():
        a = jnp.asarray(RNG.standard_normal((8, 10, 16)), jnp.float64)
        w = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float64)
        for sweeps in (1, 2):
            got = stencil_apply(a, w, "stencil27", block_i=4,
                                plan="factored", sweeps=sweeps)
            ref = stencil_ref(a, w, "stencil27", sweeps=sweeps,
                              plan="factored")
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("name", ["stencil7", "stencil27"])
@pytest.mark.parametrize("sweeps", [1, 2])
def test_j_tiled_matches_untiled(name, sweeps):
    """j-tiling is pure data movement: on integer-valued data (exact
    arithmetic, immune to per-program fma contraction) every blocking is
    bit-identical to the untiled run and the reference; on float data it
    agrees to rounding."""
    spec = get_stencil(name)
    ai = jnp.asarray(RNG.integers(-4, 5, (8, 12, 16)), jnp.float32)
    wi = jnp.asarray(RNG.integers(1, 4, spec.w_shape), jnp.float32)
    untiled = stencil_apply(ai, wi, name, block_i=4, sweeps=sweeps)
    for bj in (3, 4, 6):
        tiled = stencil_apply(ai, wi, name, block_i=4, block_j=bj,
                              sweeps=sweeps)
        np.testing.assert_array_equal(np.asarray(tiled), np.asarray(untiled))
    np.testing.assert_array_equal(
        np.asarray(untiled),
        np.asarray(stencil_ref(ai, wi, name, sweeps=sweeps)))
    af = jnp.asarray(RNG.standard_normal((8, 12, 16)), jnp.float32)
    wf = jnp.asarray(RNG.uniform(0.1, 1.0, spec.w_shape), jnp.float32)
    uf = stencil_apply(af, wf, name, block_i=4, sweeps=sweeps)
    tf = stencil_apply(af, wf, name, block_i=4, block_j=4, sweeps=sweeps)
    np.testing.assert_allclose(np.asarray(tf), np.asarray(uf),
                               rtol=1e-6, atol=1e-6)


def test_j_tiled_batched_and_custom_mask():
    ab = jnp.asarray(RNG.integers(-4, 5, (2, 6, 9, 16)), jnp.float32)
    w = jnp.asarray(RNG.integers(1, 4, (2, 2, 2)), jnp.float32)
    got = stencil_apply(ab, w, "stencil27", block_i=3, block_j=3)
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(stencil_apply(ab, w, "stencil27", block_i=3)))
    mask = np.zeros((3, 3, 3), bool)                  # asymmetric: cse plan
    mask[1, 1, 1] = mask[2, 0, 1] = mask[1, 2, 2] = True
    spec = spec_from_mask("jt-asym", mask)
    wc = jnp.asarray([1.0, -2.0, 2.0], jnp.float32)
    a = ab[0]
    np.testing.assert_array_equal(
        np.asarray(stencil_apply(a, wc, spec, block_i=2, block_j=3)),
        np.asarray(stencil_ref(a, wc, spec)))


def test_j_tiled_sweeps_deeper_than_halo_raises():
    a = jnp.zeros((8, 8, 16), jnp.float32)
    w = jnp.zeros((2, 2, 2), jnp.float32)
    with pytest.raises(ValueError, match="block_j"):
        stencil_apply(a, w, "stencil27", block_i=4, block_j=2, sweeps=3)


def test_autotune_blocks_engages_j_tiling_past_vmem_wall():
    """When no full-N block fits the budget (previously a hard wall), the
    tuner returns a feasible (bi, bj) tile instead."""
    plan = compile_plan("stencil27")
    # comfortable slab: stays untiled
    bi, bj = autotune_blocks(32, 48, 128, 4, plan=plan)
    assert bj is None and 32 % bi == 0
    # N x P slab over budget even at bi=1: j-tiling kicks in
    bi, bj = autotune_blocks(8, 288, 1024, 4, plan=plan)
    assert bj is not None and 288 % bj == 0 and 8 % bi == 0
    from repro.kernels.stencil_engine.autotune import _fits
    assert _fits(bi, bj, 288, 1024, 4, 1, 4, 8 * 1024 * 1024)
    assert not _fits(1, None, 288, 1024, 4, 1, 4, 8 * 1024 * 1024)
    # the plan-aware model charges the factored schedule ~4x less VPU work
    direct = compile_plan("stencil27", "direct")
    from repro.kernels.stencil_engine.autotune import _step_time
    assert (_step_time(8, None, 48, 128, 4, 1, plan.shifts, plan.flops)
            <= _step_time(8, None, 48, 128, 4, 1, direct.shifts,
                          direct.flops))


def test_pick_block_rows_divisor_fallback():
    # power-of-two path unchanged
    assert pick_block_rows(256, 128, 4) == 256
    # rows=12: no power-of-two candidate divides it; the old code returned
    # all 12 rows even when that blew the budget -- now the largest fitting
    # divisor wins
    assert pick_block_rows(12, 1024, 4, vmem_budget=16 * 1024) == 4
    # and when the full tile fits, behaviour is unchanged (rows itself)
    assert pick_block_rows(12, 16, 4) == 12
    # nothing fits: degrade to single rows, never over budget by choice
    assert pick_block_rows(7, 4096, 8, vmem_budget=1024) == 1


def test_sharded_fn_cache_keyed_on_device_ids_and_bounded():
    """The shard_map program cache must not key on Mesh object identity
    (leaking meshes) and must stay bounded."""
    from jax.sharding import PartitionSpec as P
    from repro.kernels.stencil_engine import sharded as sh
    plan = compile_plan("stencil27")
    part = P(None, "data")
    from jax.sharding import Mesh
    m1 = jax.make_mesh((1,), ("data",))
    m2 = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    assert sh._mesh_key(m1) == sh._mesh_key(m2)
    f1 = sh._sharded_fn(plan, m1, "data", 4, None, 1, True, 1, 8, 1, 8, part)
    f2 = sh._sharded_fn(plan, m2, "data", 4, None, 1, True, 1, 8, 1, 8, part)
    assert f1 is f2
    for k in range(sh._SHARDED_CACHE_MAX + 8):
        sh._sharded_fn(plan, m1, "data", 4, None, 1, True, 1, 8 + k, 1,
                       8 + k, part)
    assert len(sh._SHARDED_CACHE) <= sh._SHARDED_CACHE_MAX


def test_autotuner_properties():
    for m, n, p, s in [(32, 48, 128, 1), (30, 30, 30, 2), (16, 8, 128, 3)]:
        bi = autotune_block_i(m, n, p, 4, sweeps=s)
        assert m % bi == 0 and bi >= s, (m, bi, s)
    # legacy alias keeps its contract (divisor, fits the budget reasoning)
    assert 32 % pick_block_i(32, 48, 128, 4) == 0
    # huge planes fall back to small feasible blocks rather than exploding
    bi = autotune_block_i(1024, 512, 512, 4)
    assert 1024 % bi == 0


def test_planner_fallbacks_and_plan():
    from repro.sharding.planner import stencil_halo_sharding
    mesh = jax.make_mesh((1,), ("data",))
    plan = stencil_halo_sharding(16, mesh, sweeps=1)
    assert plan.n_shards == 1                      # 1 device: unsharded
    assert any("unsharded" in n.reason for n in plan.notes)


def test_sharded_two_devices_subprocess():
    """2-device shard_map halo-exchange == single-device engine, bit-exact,
    for s in {1, 2} -- on forced host-platform devices."""
    code = """
        import jax, numpy as np, jax.numpy as jnp
        assert jax.device_count() == 2, jax.devices()
        from repro.kernels import stencil_apply, stencil_ref, stencil_sharded
        from repro.sharding.planner import stencil_halo_sharding
        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.standard_normal((16, 10, 16)), jnp.float32)
        w = jnp.asarray(rng.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)
        mesh = jax.make_mesh((2,), ("data",))
        for s in (1, 2):
            plan = stencil_halo_sharding(16, mesh, sweeps=s)
            assert plan.n_shards == 2 and plan.halo == s
            got = stencil_sharded(a, w, "stencil27", mesh=mesh, sweeps=s)
            one = stencil_apply(a, w, "stencil27", block_i=4, sweeps=s)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(one))
            ref = stencil_ref(a, w, "stencil27", sweeps=s)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
        # batched + sharded
        ab = jnp.asarray(rng.standard_normal((2, 16, 8, 16)), jnp.float32)
        got = stencil_sharded(ab, w, "stencil27", mesh=mesh, sweeps=2)
        one = stencil_apply(ab, w, "stencil27", block_i=4, sweeps=2)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(one))
        print("sharded ok")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "sharded ok" in out.stdout
