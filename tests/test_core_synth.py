"""Synthesized kernels reproduce the paper's Table 1/2 resource counts."""

import pytest

from repro.core.perfmodel import PAPER_TABLE2
from repro.core.synth import PAPER_CONFIGS, StencilConfig, synth_stencil


@pytest.mark.parametrize("cfg", PAPER_CONFIGS, ids=lambda c: c.name)
def test_table2_counts(cfg):
    k = synth_stencil(cfg)
    c = k.counts
    rows, st, in_regs, res_regs, w_regs, loads, stores, fpu, bps = \
        PAPER_TABLE2[cfg.name]
    assert len(k.rows) == rows
    assert cfg.stencils_per_iter == st
    assert c.result_regs == res_regs
    assert c.weight_regs == w_regs
    assert c.loads == loads
    assert c.stores == stores
    assert c.fpu == fpu
    assert abs((c.read_bytes + c.write_bytes) / st - bps) < 0.01
    if cfg.name == "7-lc-2x3":
        # Documented deviation (DESIGN.md sect. 8): our aligned-result lc
        # rotation uses 3 registers per centre stream (28 total) where the
        # paper's table lists 22; all cycle-determining counts match above.
        assert c.input_regs == 28
    else:
        assert c.input_regs == in_regs


def test_table1_subkernel_resources():
    """Paper Table 1: per-SIMD-iteration resources of mm and lc 3-pt kernels."""
    mm = synth_stencil(StencilConfig(3, "mm", 1, 1))
    assert mm.counts.mutate_loads == 2 and mm.counts.stores == 1
    assert mm.counts.fpu == 3 and mm.counts.input_regs == 1
    assert mm.counts.lsu_cycles == 6
    lc = synth_stencil(StencilConfig(3, "lc", 1, 1))
    assert lc.counts.quad_loads == 1 and lc.counts.stores == 1
    assert lc.counts.fpu == 4 and lc.counts.input_regs == 2
    assert lc.counts.lsu_cycles == 4


@pytest.mark.parametrize("cfg", [
    StencilConfig(3, "mm", 2, 2),
    StencilConfig(7, "mm", 1, 1),
    StencilConfig(7, "lc", 1, 2),
    StencilConfig(27, "mm", 3, 1),
], ids=lambda c: c.name)
def test_nonpaper_configs_synthesize(cfg):
    k = synth_stencil(cfg)
    assert len(k.body) > 0
    assert k.counts.stores == cfg.ui * cfg.uj
    # effective arithmetic intensity improves (or holds) with jamming
    assert k.counts.read_bytes / cfg.stencils_per_iter <= 9 * 8 + 1


def test_27pt_rejects_lc():
    with pytest.raises(ValueError):
        synth_stencil(StencilConfig(27, "lc", 1, 1))


def test_unroll_and_jam_raises_arithmetic_intensity():
    """Paper sect. 4.3: 27-pt 2x3 jam cuts bytes/stencil 80 -> 34.7."""
    b11 = synth_stencil(StencilConfig(27, "mm", 1, 1))
    b23 = synth_stencil(StencilConfig(27, "mm", 2, 3))
    bps = lambda k: ((k.counts.read_bytes + k.counts.write_bytes)
                     / k.config.stencils_per_iter)
    assert bps(b11) == 80.0
    assert abs(bps(b23) - 104 / 3) < 1e-9
