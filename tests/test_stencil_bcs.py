"""Boundary-condition parity suite (the BC tentpole's acceptance tests).

Every BC x radius {1, 2} x path {stream, replicate} is checked bit-exactly
against an *independent* NumPy ``np.pad`` oracle on f64 integer-valued data
(exact arithmetic, so tap-order reassociation can't hide a wrong ghost) and
to tolerance on f32/bf16; plus fused sweeps {1, 3}, j-tiling, the engine's
own jnp reference, per-axis-side mixes, the BC-suffixed registry builtins,
spec validation errors, and a 2-device periodic wrap-around sharded
subprocess test (the halo ring)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (BC, dirichlet, get_stencil, spec_from_mask,
                           stencil_apply, stencil_ref, stencil_sharded)
from repro.kernels.stencil_engine.spec import as_boundary, bc_labels

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(17)

_PAD_MODE = {"periodic": "wrap", "neumann": "symmetric"}


def np_pad_oracle(a, w, spec, sweeps=1):
    """Independent oracle: per sweep, ``np.pad`` the trailing ``ndim`` axes
    by ``radius`` under the per-axis-side modes (axes in i, j, k order),
    take the direct tap sum on the padded field, and zero the one-point
    ring of clamp sides.  Pure NumPy f64 -- shares no code with the
    engine."""
    u = np.asarray(a, np.float64)
    wf = np.asarray(w, np.float64).reshape(-1)
    nd = spec.ndim
    lead = u.ndim - nd
    for _ in range(sweeps):
        up = u
        for ax in range(3 - nd, 3):
            r = spec.radius[ax]
            if r == 0:
                continue
            axis = lead + (ax - (3 - nd))
            lo, hi = spec.bc[ax]
            if lo.kind == "periodic":
                pw = [(0, 0)] * up.ndim
                pw[axis] = (r, r)
                up = np.pad(up, pw, mode="wrap")
                continue
            for side, width in ((lo, (r, 0)), (hi, (0, r))):
                pw = [(0, 0)] * up.ndim
                pw[axis] = width
                if side.kind == "clamp":
                    up = np.pad(up, pw, mode="constant")
                elif side.kind == "dirichlet":
                    up = np.pad(up, pw, mode="constant",
                                constant_values=side.value)
                else:
                    up = np.pad(up, pw, mode=_PAD_MODE[side.kind])
        out = np.zeros_like(u)
        for off, widx in zip(spec.offsets, spec.w_index):
            sl = [slice(None)] * lead
            for ax in range(3 - nd, 3):
                axis = lead + (ax - (3 - nd))
                r, d = spec.radius[ax], off[ax]
                sl.append(slice(r + d, r + d + u.shape[axis]))
            out += wf[widx] * up[tuple(sl)]
        for ax in range(3 - nd, 3):
            axis = lead + (ax - (3 - nd))
            lo, hi = spec.bc[ax]
            if lo.kind == "clamp":
                s = [slice(None)] * u.ndim
                s[axis] = 0
                out[tuple(s)] = 0
            if hi.kind == "clamp":
                s = [slice(None)] * u.ndim
                s[axis] = -1
                out[tuple(s)] = 0
        u = out
    return u


def _int_data(shape, dtype=jnp.float64):
    return jnp.asarray(RNG.integers(-4, 5, shape), dtype)


def _int_weights(spec, dtype=jnp.float64):
    return jnp.asarray(RNG.integers(1, 4, spec.w_shape), dtype)


@pytest.mark.parametrize("name,block_i", [("stencil27", 4), ("star13", 6)])
@pytest.mark.parametrize("bc", ["clamp", "periodic", "neumann",
                                dirichlet(2.0)])
@pytest.mark.parametrize("sweeps", [1, 3])
@pytest.mark.parametrize("path", ["stream", "replicate"])
def test_bc_bit_exact_vs_np_pad_oracle(name, block_i, bc, sweeps, path):
    """Acceptance: periodic / dirichlet / neumann (and the clamp default)
    agree bit-exactly (f64, integer-valued data) with the NumPy np.pad
    reference across radius {1, 2} x path {stream, replicate} x sweeps
    {1, 3} -- and so does the engine's own jnp reference."""
    spec = get_stencil(name).with_bc(bc)
    with jax.experimental.enable_x64():
        a = _int_data((12, 12, 16))
        w = _int_weights(spec)
        want = np_pad_oracle(a, w, spec, sweeps=sweeps)
        got = np.asarray(stencil_apply(a, w, spec, block_i=block_i,
                                       sweeps=sweeps, path=path))
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(
            np.asarray(stencil_ref(a, w, spec, sweeps=sweeps)), want)


@pytest.mark.parametrize("name", ["stencil27", "star13"])
@pytest.mark.parametrize("bc", ["periodic", "neumann", dirichlet(0.0)])
def test_bc_jtiled_bit_exact(name, bc):
    """j-tiled blocking under every BC is bit-identical to the untiled run
    and to the oracle (the tiled j axis realizes its BC by halo fill /
    wrapped index maps instead of in-shift fill)."""
    spec = get_stencil(name).with_bc(bc)
    bi = 4 if spec.radius[0] == 1 else 6
    with jax.experimental.enable_x64():
        a = _int_data((12, 12, 16))
        w = _int_weights(spec)
        want = np_pad_oracle(a, w, spec)
        for path in ("stream", "replicate"):
            for bj in (4, 6):
                got = np.asarray(stencil_apply(a, w, spec, block_i=bi,
                                               block_j=bj, path=path))
                np.testing.assert_array_equal(got, want)


def test_bc_mixed_per_axis_and_per_side():
    """Per-axis-side mixes: periodic i, (neumann, dirichlet) j, clamp k --
    and an asymmetric ad-hoc mask (cse plan) under periodic BCs."""
    mix = ("periodic", ("neumann", "dirichlet"), "clamp")
    spec = get_stencil("stencil27").with_bc(mix)
    with jax.experimental.enable_x64():
        a = _int_data((8, 12, 16))
        w = _int_weights(spec)
        want = np_pad_oracle(a, w, spec, sweeps=2)
        for path in ("stream", "replicate"):
            got = np.asarray(stencil_apply(a, w, spec, block_i=4, sweeps=2,
                                           path=path))
            np.testing.assert_array_equal(got, want)
        mask = np.zeros((3, 3, 3), bool)
        mask[1, 1, 1] = mask[2, 0, 1] = mask[1, 2, 2] = mask[0, 1, 0] = True
        asym = spec_from_mask("bc-asym", mask, bc="periodic")
        aw = jnp.asarray(RNG.integers(1, 4, asym.w_shape), jnp.float64)
        want = np_pad_oracle(a, aw, asym)
        for path in ("stream", "replicate"):
            got = np.asarray(stencil_apply(a, aw, asym, block_i=4,
                                           path=path))
            np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("bc", ["periodic", "neumann", dirichlet(1.0)])
def test_bc_float_tolerance(dtype, tol, bc):
    """f32/bf16 runs agree with the f64 oracle to accumulation tolerance on
    float data, across both paths (the engine accumulates in f32; atol is
    scaled by the field magnitude -- two fused sweeps grow values to
    ~1e2)."""
    spec = get_stencil("stencil27").with_bc(bc)
    a = jnp.asarray(RNG.standard_normal((8, 12, 16)), dtype)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, spec.w_shape), dtype)
    want = np_pad_oracle(np.asarray(a, np.float64),
                         np.asarray(w, np.float64), spec, sweeps=2)
    scale = float(np.abs(want).max())
    for path in ("stream", "replicate"):
        got = np.asarray(stencil_apply(a, w, spec, block_i=4, sweeps=2,
                                       path=path), np.float32)
        np.testing.assert_allclose(got, want, rtol=10 * tol,
                                   atol=tol * scale)


def test_bc_1d_stencil3():
    """The k-only path realizes its BC in the shift primitive; the
    BC-suffixed stencil3 builtins match the oracle."""
    a = _int_data((6, 32), jnp.float32)
    w = jnp.asarray(RNG.integers(1, 4, (2,)), jnp.float32)
    for tag in ("stencil3", "stencil3_periodic", "stencil3_neumann",
                "stencil3_dirichlet"):
        spec = get_stencil(tag)
        want = np_pad_oracle(a, w, spec, sweeps=2).astype(np.float32)
        got = np.asarray(stencil_apply(a, w, tag, sweeps=2))
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(
            np.asarray(stencil_ref(a, w, tag, sweeps=2)), want)
    # hand check: periodic really wraps
    u = np.asarray(a, np.float64)
    wf = np.asarray(w, np.float64)
    want = wf[1] * u + wf[0] * (np.roll(u, 1, -1) + np.roll(u, -1, -1))
    np.testing.assert_array_equal(
        np.asarray(stencil_apply(a, w, "stencil3_periodic")),
        want.astype(np.float32))


def test_bc_registry_builtins_and_describe():
    """BC-suffixed builtins are registered for every base spec, carry the
    right per-axis BCs, and their plans memoize separately from (and
    describe differently to) the clamp default."""
    from repro.kernels import compile_plan
    for base in ("stencil7", "stencil27", "star13", "box125"):
        spec = get_stencil(f"{base}_periodic")
        assert all(s.kind == "periodic" for ax in spec.bc for s in ax)
        assert spec.offsets == get_stencil(base).offsets
        d = compile_plan(spec).describe()
        assert d["bc"] == ["periodic"] * 3
        # same tap schedule, distinct memo entry
        base_plan = compile_plan(base)
        assert compile_plan(spec) is not base_plan
        assert compile_plan(spec).ops == base_plan.ops
    assert get_stencil("stencil3_neumann").bc[2][0].kind == "neumann"
    assert bc_labels(as_boundary(dirichlet(2.0)))[0] == "dirichlet(2)"
    assert bc_labels(as_boundary(("clamp", ("periodic", "periodic"),
                                  "neumann"))) == ("clamp", "periodic",
                                                   "neumann")


def test_bc_validation_errors():
    spec = get_stencil("stencil27")
    with pytest.raises(ValueError, match="periodic must be paired"):
        spec.with_bc((("periodic", "clamp"), "clamp", "clamp"))
    with pytest.raises(ValueError, match="distinct dirichlet values"):
        spec.with_bc((dirichlet(1.0), dirichlet(2.0), "clamp"))
    with pytest.raises(ValueError, match="unknown BC kind"):
        spec.with_bc("warp")
    with pytest.raises(ValueError, match="k-axis"):
        get_stencil("stencil3").with_bc("periodic")
    # nonzero dirichlet ghosts can't meet a radius-2 clamp side
    with pytest.raises(ValueError, match="nonzero ghost value"):
        get_stencil("star13").with_bc((dirichlet(2.0), "clamp", "clamp"))
    # ...but dirichlet(0) can, and radius-1 mixes are fine
    get_stencil("star13").with_bc((dirichlet(0.0), "clamp", "clamp"))
    get_stencil("stencil27").with_bc((dirichlet(2.0), "clamp", "clamp"))
    with pytest.raises((TypeError, ValueError)):
        spec.with_bc(("clamp", "clamp"))          # not 3 axes


def test_bc_default_clamp_unchanged():
    """with_bc("clamp") is the default spec: same results, same plan memo
    entry (the BC refactor must not perturb the engine's historical
    semantics)."""
    from repro.kernels import compile_plan
    spec = get_stencil("stencil27")
    assert spec.with_bc("clamp") == spec
    assert compile_plan(spec.with_bc("clamp")) is compile_plan(spec)
    a = _int_data((8, 12, 16), jnp.float32)
    w = jnp.asarray(RNG.integers(1, 4, (2, 2, 2)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(stencil_apply(a, w, "stencil27", block_i=4)),
        np.asarray(stencil_apply(a, w, "stencil27", block_i=4, bc="clamp")))


def test_bc_periodic_sharded_two_devices_subprocess():
    """Acceptance: the 2-device periodic wrap-around sharded run (the halo
    exchange becomes a ring -- shard 0 trades rows with shard N-1) is
    bit-identical to the single-device periodic run, on both paths, radius
    1 and 2 -- and dirichlet/neumann edge shards stay exact too."""
    code = """
        import jax, numpy as np, jax.numpy as jnp
        assert jax.device_count() == 2, jax.devices()
        from repro.kernels import (dirichlet, stencil_apply, stencil_sharded,
                                   get_stencil)
        from repro.sharding.planner import stencil_halo_sharding
        rng = np.random.default_rng(7)
        a = jnp.asarray(rng.integers(-4, 5, (16, 12, 16)), jnp.float32)
        mesh = jax.make_mesh((2,), ("data",))
        plan = stencil_halo_sharding(16, mesh, sweeps=2, radius=2,
                                     periodic=True)
        assert plan.periodic and "ring" in plan.notes[-1].reason
        for name in ("stencil27", "star13"):
            spec = get_stencil(name)
            w = jnp.asarray(rng.integers(1, 4, spec.w_shape), jnp.float32)
            bcs = ["periodic", "neumann"] + (
                [dirichlet(2.0)] if name == "stencil27" else [])
            for bc in bcs:
                for s in (1, 2):
                    for path in ("stream", "replicate"):
                        sh = stencil_sharded(a, w, name, mesh=mesh, sweeps=s,
                                             path=path, bc=bc)
                        one = stencil_apply(a, w, name, block_i=4, sweeps=s,
                                            path=path, bc=bc)
                        np.testing.assert_array_equal(np.asarray(sh),
                                                      np.asarray(one))
        print("bc sharded ok")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "bc sharded ok" in out.stdout


def test_bc_sharded_single_device_fallback():
    """The unsharded fallback threads the BC override through to
    stencil_apply (no silent clamp regression when the planner declines)."""
    a = _int_data((7, 12, 16), jnp.float32)   # M=7 indivisible -> fallback
    w = jnp.asarray(RNG.integers(1, 4, (2, 2, 2)), jnp.float32)
    got = stencil_sharded(a, w, "stencil27", bc="periodic")
    want = stencil_apply(a, w, "stencil27", bc="periodic")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
