"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # optional dev dep -- property tests skip, rest runs
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.kernels import (attention_ref, flash_attention, mamba_scan,
                           mamba_scan_ref, stencil3, stencil3_ref, stencil7,
                           stencil7_ref, stencil27, stencil27_ref)

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape,bi", [((8, 16, 32), 4), ((16, 8, 128), 8),
                                      ((12, 12, 64), 3), ((8, 24, 32), 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stencil27_sweep(shape, bi, dtype):
    a = jnp.asarray(RNG.standard_normal(shape), dtype)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)
    got = stencil27(a, w, block_i=bi)
    ref = stencil27_ref(a.astype(jnp.float32), w).astype(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape,bi", [((8, 16, 32), 4), ((16, 8, 128), 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stencil7_sweep(shape, bi, dtype):
    a = jnp.asarray(RNG.standard_normal(shape), dtype)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, 4), jnp.float32)
    got = stencil7(a, w, block_i=bi)
    ref = stencil7_ref(a.astype(jnp.float32), w).astype(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("rows,p,br", [(8, 64, 4), (16, 128, 8), (4, 256, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stencil3_sweep(rows, p, br, dtype):
    a = jnp.asarray(RNG.standard_normal((rows, p)), dtype)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, 2), jnp.float32)
    got = stencil3(a, w, block_rows=br)
    ref = stencil3_ref(a.astype(jnp.float32), w).astype(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_stencil27_matches_ppc450_oracle():
    """Cross-layer: the Pallas kernel and the PPC450 virtual-machine kernel
    implement the same operator."""
    from repro.core.synth import StencilConfig
    from repro.core.verify import run_kernel
    r = run_kernel(StencilConfig(27, "mm", 2, 3), t_iters=4, seed=7)
    assert r.ok  # both verified against the same mathematical stencil


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4), st.integers(4, 8), st.integers(1, 3))
def test_stencil27_linearity(b, n, seed):
    """Property: the stencil is a linear operator."""
    rng = np.random.default_rng(seed)
    shape = (2 * b, n, 16)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    y = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)
    lhs = stencil27(x + 2.0 * y, w, block_i=b)
    rhs = stencil27(x, w, block_i=b) + 2.0 * stencil27(y, w, block_i=b)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)


def test_stencil27_superposition_of_3pt():
    """Paper sect. 3.1: 27-pt == sum of nine 3-pt row kernels when the
    transverse weights factor accordingly (w constant across planes)."""
    a = jnp.asarray(RNG.standard_normal((8, 8, 32)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, 2), jnp.float32)  # (edge, center)
    wk = w[::-1]                                  # w27[.,.,dk]: (center, edge)
    w27 = jnp.stack([jnp.stack([wk, wk]), jnp.stack([wk, wk])])  # (2,2,2)
    got = stencil27(a, w27, block_i=4)
    acc = jnp.zeros_like(a)
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            acc = acc.at[1:-1, 1:-1].add(
                stencil3_ref(a, w)[1 + di:a.shape[0] - 1 + di,
                                   1 + dj:a.shape[1] - 1 + dj])
    acc = acc.at[:, :, 0].set(0).at[:, :, -1].set(0)
    acc = acc.at[0].set(0).at[-1].set(0)
    acc = acc.at[:, 0].set(0).at[:, -1].set(0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,l,d,n,chunk", [(2, 64, 8, 4, 16), (1, 32, 16, 8, 32),
                                           (3, 48, 4, 4, 12)])
def test_mamba_scan_sweep(b, l, d, n, chunk):
    x = jnp.asarray(RNG.standard_normal((b, l, d)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, l, d)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.1, 2.0, (d, n)), jnp.float32)
    bm = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
    c = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
    dd = jnp.asarray(RNG.standard_normal((d,)), jnp.float32)
    got = mamba_scan(x, dt, a, bm, c, dd, chunk=chunk)
    ref = mamba_scan_ref(x, dt, a, bm, c, dd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_mamba_scan_chunk_invariance():
    """Property: chunk size is an implementation detail."""
    b, l, d, n = 1, 64, 4, 4
    x = jnp.asarray(RNG.standard_normal((b, l, d)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, l, d)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.1, 2.0, (d, n)), jnp.float32)
    bm = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
    c = jnp.asarray(RNG.standard_normal((b, l, n)), jnp.float32)
    dd = jnp.zeros((d,), jnp.float32)
    outs = [mamba_scan(x, dt, a, bm, c, dd, chunk=cs) for cs in (8, 16, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,h,hkv,lq,lk,dh,bq,bk", [
    (2, 4, 2, 32, 32, 16, 16, 16),
    (1, 8, 2, 16, 64, 32, 8, 16),
    (1, 6, 6, 24, 24, 64, 8, 8),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, hkv, lq, lk, dh, bq, bk, causal):
    q = jnp.asarray(RNG.standard_normal((b, h, lq, dh)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, lk, dh)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, lk, dh)), jnp.float32)
    off = lk - lq if causal else 0
    got = flash_attention(q, k, v, causal=causal, q_offset=off,
                          block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [4, 8, 16])
def test_flash_attention_sliding_window(window):
    q = jnp.asarray(RNG.standard_normal((1, 4, 32, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 32, 16)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=8, block_k=8)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_step():
    """Lq=1 with a long KV cache (the serve_step shape)."""
    q = jnp.asarray(RNG.standard_normal((2, 4, 1, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 2, 128, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, q_offset=127,
                          block_q=1, block_k=32)
    ref = attention_ref(q, k, v, causal=True, q_offset=127)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 4, 32, 32)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 4, 32, 32)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 4, 32, 32)), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("shape,bi", [((8, 16, 32), 4), ((16, 8, 128), 8)])
def test_stencil27_mxu_matches_vpu_form(shape, bi):
    """Beyond-paper MXU banded-matmul form == the VPU stencil == the oracle."""
    from repro.kernels import stencil27_mxu
    a = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)
    got = stencil27_mxu(a, w, block_i=bi)
    ref = stencil27_ref(a, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    vpu = stencil27(a, w, block_i=bi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(vpu),
                               rtol=2e-5, atol=2e-5)
