"""Distribution layer: planner specs, shard_map MoE parity, compressed DP,
elastic restore, and a miniature dry-run -- all on host-platform placeholder
devices in subprocesses (tests in this process must see ONE device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import get_config
from repro.launch.cells import skip_reason
from repro.models.common import SHAPES

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_dev: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_main_process_sees_one_device():
    assert jax.device_count() == 1


def test_planner_specs_on_mesh():
    print(_run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import build_model
        from repro.sharding import param_sharding, plan_summary
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("qwen1.5-0.5b")
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        shard, notes = param_sharding(cfg, shapes, mesh, fsdp=True)
        leaves = jax.tree.leaves(shard)
        assert all(hasattr(s, "spec") for s in leaves)
        n_model = sum("model" in str(n.spec) for n in notes)
        assert n_model > len(notes) // 2, plan_summary(notes)
        print("planner ok", len(notes), "leaves")
    """))


def test_moe_shard_map_matches_local():
    """The shard_map MoE (EP and TP modes) equals the single-device path."""
    print(_run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced_config
        from repro.models.moe import init_moe, moe_ffn
        from repro.sharding.ctx import use_mesh

        for tp, name in ((4, "EP"), (8, "TP-fallback")):
            cfg = dataclasses.replace(get_reduced_config("mixtral-8x7b"),
                                      dtype=jnp.float32, n_experts=4,
                                      top_k=2, capacity_factor=8.0)
            mesh = jax.make_mesh((8 // tp, tp), ("data", "model"))
            p = init_moe(jax.random.PRNGKey(0), cfg)
            x = jnp.asarray(np.random.default_rng(0).standard_normal(
                (8, 16, cfg.d_model)), jnp.float32)
            ref, _ = moe_ffn(p, x, cfg)                 # local path
            with use_mesh(mesh):
                got = jax.jit(lambda p_, x_: moe_ffn(p_, x_, cfg)[0])(p, x)
            err = float(jnp.max(jnp.abs(got - ref)))
            # local capacity differs from global capacity only under
            # pressure; with capacity_factor=8 nothing drops
            assert err < 2e-4, (name, err)
            print(name, "ok", err)
    """))


def test_compressed_dp_training_descends():
    print(_run("""
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced_config
        from repro.data import SyntheticDataset
        from repro.models import build_model
        from repro.models.common import ShapeConfig
        from repro.optim import adamw, warmup_cosine
        from repro.runtime import TrainConfig, Trainer
        cfg = dataclasses.replace(get_reduced_config("qwen1.5-0.5b"),
                                  dtype=jnp.float32)
        model = build_model(cfg)
        mesh = jax.make_mesh((8,), ("data",))
        ds = SyntheticDataset(cfg, ShapeConfig("t", 32, 8, "train"), seed=0)
        tc = TrainConfig(steps=8, compress_grads=True, log_every=1)
        tr = Trainer(model, adamw(), warmup_cosine(1e-3, 2, 8), tc, ds,
                     mesh=mesh)
        tr.run(jax.random.PRNGKey(0))
        losses = [m["loss"] for m in tr.metrics_log]
        assert losses[-1] < losses[0], losses
        print("compressed-DP ok", losses[0], "->", losses[-1])
    """))


def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    """Save sharded on a (2,4) mesh; restore onto (4,2) -- elastic restart."""
    print(_run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        m1 = jax.make_mesh((2, 4), ("data", "model"))
        sh1 = {{"w": NamedSharding(m1, P("data", "model"))}}
        placed = jax.tree.map(jax.device_put, tree, sh1)
        mgr = CheckpointManager({str(tmp_path)!r}, keep=2)
        mgr.save(1, placed)
        m2 = jax.make_mesh((4, 2), ("data", "model"))
        sh2 = {{"w": NamedSharding(m2, P("model", "data"))}}
        restored, _ = mgr.restore(tree, shardings=sh2)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding == sh2["w"]
        print("elastic restore ok")
    """))


def test_mini_dryrun_cell():
    """A reduced-config train cell lowers + compiles on an 8-device mesh."""
    print(_run("""
        import dataclasses
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced_config
        from repro.data import make_batch_specs
        from repro.models import build_model
        from repro.models.common import ShapeConfig
        from repro.optim import build_optimizer
        from repro.runtime import TrainConfig, make_train_step
        from repro.sharding import batch_sharding, param_sharding
        from repro.sharding.ctx import use_mesh
        from repro.launch.hlo_analysis import analyze_hlo

        cfg = dataclasses.replace(get_reduced_config("mixtral-8x7b"),
                                  n_experts=4)
        model = build_model(cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("t", 64, 8, "train")
        opt = build_optimizer("adamw")
        params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        opt_s = jax.eval_shape(opt.init, params_s)
        state_s = {"params": params_s, "opt": opt_s,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        p_sh, _ = param_sharding(cfg, state_s, mesh)
        b_specs = make_batch_specs(cfg, shape)
        b_sh = batch_sharding(shape, b_specs, mesh)
        step = make_train_step(model, opt, lambda s: 1e-3, TrainConfig())
        with mesh, use_mesh(mesh):
            compiled = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(
                state_s, b_specs).compile()
        d = analyze_hlo(compiled.as_text())
        assert d["flops"] > 0 and d["collective_bytes"] > 0
        print("mini dryrun ok", d["flops"], d["collective_bytes"])
    """))


def test_skip_matrix_documented():
    """Exactly the six full-attention archs skip long_500k; nothing else."""
    skipped = [a for a in
               ("internvl2-2b", "arctic-480b", "nemotron-4-15b",
                "qwen2-0.5b", "qwen1.5-0.5b", "seamless-m4t-large-v2")]
    runs = ["mixtral-8x7b", "zamba2-7b", "falcon-mamba-7b", "starcoder2-7b"]
    for a in skipped:
        assert skip_reason(a, "long_500k") is not None, a
        for s in SHAPES:
            if s != "long_500k":
                assert skip_reason(a, s) is None
    for a in runs:
        assert skip_reason(a, "long_500k") is None, a
