"""Pipeline parallelism == sequential composition (subprocess, 4 devices)."""

import os
import subprocess
import sys
import textwrap

from repro.runtime.pipeline import bubble_fraction

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_dev: int = 4, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == 3 / 7
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 28) < 0.1


def test_pipeline_matches_sequential():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime.pipeline import pipeline_forward

        S, B, D = 4, 8, 16
        mesh = jax.make_mesh((S,), ("pipe",))
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.standard_normal((S, D, D)) / np.sqrt(D),
                        jnp.float32)
        b = jnp.asarray(rng.standard_normal((S, D)) * 0.1, jnp.float32)
        x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)

        def stage(p, h):
            return jnp.tanh(h @ p["w"] + p["b"])

        params = {"w": w, "b": b}
        got = pipeline_forward(stage, params, x, mesh, axis="pipe",
                               n_microbatches=4)
        ref = x
        for i in range(S):
            ref = jnp.tanh(ref @ w[i] + b[i])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("pipeline ok", float(jnp.abs(got - ref).max()))
    """))


def test_pipeline_microbatch_count_invariance():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.runtime.pipeline import pipeline_forward
        S, B, D = 2, 8, 8
        mesh = jax.make_mesh((S,), ("pipe",))
        rng = np.random.default_rng(1)
        params = {"w": jnp.asarray(rng.standard_normal((S, D, D)) * 0.3,
                                   jnp.float32)}
        x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
        stage = lambda p, h: jnp.tanh(h @ p["w"])
        outs = [pipeline_forward(stage, params, x, mesh, "pipe", m)
                for m in (2, 4, 8)]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                       rtol=1e-5, atol=1e-5)
        print("microbatch invariance ok")
    """, n_dev=2))
