"""Variable-coefficient acceptance: per-point weight fields are bit-exact
against an independent (non-engine) numpy oracle and against the engine
reference, across data-movement path in {stream, replicate} x fused sweeps
in {1, 3}, j-tiled and untiled, broadcast weights, BC overrides, radius 2,
1-D specs, the autotuner's traffic accounting, and a 2-device halo-exchange
sharded run (subprocess).  Integer-valued data makes every reassociation
exact, so the comparisons are ``assert_array_equal``, not allclose."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import get_stencil, stencil_apply, stencil_ref
from repro.kernels.stencil_engine.autotune import bytes_per_point

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(11)


def _ints(shape, lo=-3, hi=4):
    return RNG.integers(lo, hi, shape).astype(np.float64)


def _wints(shape):
    return RNG.integers(1, 4, shape).astype(np.float64)


def _oracle_var(u, wf, spec, sweeps=1):
    """Independent triple-loop oracle under the engine's clamp semantics:
    reads outside the domain are zero, the one-point output ring is zeroed,
    and coefficients are read at the *output* point."""
    nd = spec.ndim
    shape = u.shape
    cur = np.asarray(u, np.float64)
    wf = np.asarray(wf, np.float64)
    for _ in range(sweeps):
        out = np.zeros_like(cur)
        for idx in np.ndindex(*shape):
            if any(idx[a] in (0, shape[a] - 1) for a in range(nd)):
                continue
            s = 0.0
            for off, wi in zip(spec.offsets, spec.w_index):
                o = off[3 - nd:]
                src = tuple(idx[a] + o[a] for a in range(nd))
                if any(t < 0 or t >= shape[a]
                       for a, t in enumerate(src)):
                    continue
                s += wf[wi][idx] * cur[src]
            out[idx] = s
        cur = out
    return cur


@pytest.mark.parametrize("sweeps", [1, 2])
def test_var27_matches_independent_oracle(sweeps):
    """Non-circular: kernel AND engine ref against a hand-rolled loop."""
    spec = get_stencil("stencil27").with_coef("var")
    shape = (5, 6, 8)
    with jax.experimental.enable_x64():
        a = jnp.asarray(_ints(shape))
        w = jnp.asarray(_wints((spec.n_weights,) + shape))
        want = _oracle_var(a, w, spec, sweeps)
        ref = stencil_ref(a, w, spec, sweeps=sweeps)
        got = stencil_apply(a, w, spec, block_i=None, sweeps=sweeps)
        np.testing.assert_array_equal(np.asarray(ref), want)
        np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("name", ["stencil7", "stencil27"])
@pytest.mark.parametrize("path", ["stream", "replicate"])
@pytest.mark.parametrize("sweeps", [1, 3])
@pytest.mark.parametrize("block_j", [None, 4])
def test_var_paths_sweeps_bitexact_vs_ref(name, path, sweeps, block_j):
    spec = get_stencil(name).with_coef("var")
    shape = (8, 12, 16)
    with jax.experimental.enable_x64():
        a = jnp.asarray(_ints(shape))
        w = jnp.asarray(_wints((spec.n_weights,) + shape))
        got = stencil_apply(a, w, spec, block_i=4, block_j=block_j,
                            sweeps=sweeps, path=path)
        ref = stencil_ref(a, w, spec, sweeps=sweeps)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_var_broadcast_weights_equal_materialized():
    """(nw, 1, 1, P) weights broadcast over the domain == the same weights
    fully materialized to (nw, M, N, P)."""
    spec = get_stencil("stencil27").with_coef("var")
    m, n, p = 8, 10, 16
    with jax.experimental.enable_x64():
        a = jnp.asarray(_ints((m, n, p)))
        wb = jnp.asarray(_wints((spec.n_weights, 1, 1, p)))
        wfull = jnp.broadcast_to(wb, (spec.n_weights, m, n, p))
        for path in ("stream", "replicate"):
            np.testing.assert_array_equal(
                np.asarray(stencil_apply(a, wb, spec, block_i=4, path=path)),
                np.asarray(stencil_apply(a, wfull, spec, block_i=4,
                                         path=path)))


@pytest.mark.parametrize("path", ["stream", "replicate"])
def test_var_radius2_star13(path):
    spec = get_stencil("star13").with_coef("var")
    shape = (10, 12, 16)
    with jax.experimental.enable_x64():
        a = jnp.asarray(_ints(shape))
        w = jnp.asarray(_wints((spec.n_weights,) + shape))
        for bj in (None, 4):
            got = stencil_apply(a, w, spec, block_i=5, block_j=bj,
                                sweeps=2, path=path)
            ref = stencil_ref(a, w, spec, sweeps=2)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("bc", ["periodic", "neumann", "dirichlet"])
@pytest.mark.parametrize("path", ["stream", "replicate"])
def test_var_boundary_conditions(bc, path):
    from repro.kernels import dirichlet
    over = dirichlet(2.0) if bc == "dirichlet" else bc
    spec = get_stencil("stencil27").with_coef("var").with_bc(over)
    shape = (8, 10, 16)
    with jax.experimental.enable_x64():
        a = jnp.asarray(_ints(shape))
        w = jnp.asarray(_wints((spec.n_weights,) + shape))
        got = stencil_apply(a, w, spec, block_i=4, sweeps=2, path=path)
        ref = stencil_ref(a, w, spec, sweeps=2)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_var_1d_stencil3():
    spec = get_stencil("stencil3").with_coef("var")
    with jax.experimental.enable_x64():
        a = jnp.asarray(_ints((6, 32)))
        w = jnp.asarray(_wints((spec.n_weights, 32)))
        got = stencil_apply(a, w, spec, block_i=3)
        ref = stencil_ref(a, w, spec)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        # one row through the independent oracle too
        want = _oracle_var(np.asarray(a[0]), np.asarray(w), spec)
        np.testing.assert_array_equal(np.asarray(got[0]), want)


def test_var_bytes_per_point_accounting():
    """Streaming untiled var traffic = (2 + n_weights) transfers/point
    (paper's ~2/point plus one co-streamed plane per weight field);
    constant coefficients move nothing extra."""
    for nw in (4, 8):
        base = bytes_per_point("stream", 4)
        var = bytes_per_point("stream", 4, coef="var", n_weights=nw)
        assert base == 2 * 4
        assert var == (2 + nw) * 4
        # replicated untiled at radius 1: every one of the 2ri+1 staged
        # views drags its own copy of the nw coefficient planes
        rep = bytes_per_point("replicate", 4, coef="var", n_weights=nw)
        assert rep == (4 + 3 * nw) * 4
        # amortized over fused sweeps like the field traffic
        assert bytes_per_point("stream", 4, sweeps=2, coef="var",
                               n_weights=nw) == var / 2


def test_var_sharded_two_devices_subprocess():
    """2-device halo-exchange with per-point coefficients sharded alongside
    the domain == the single-device engine, bit-exact -- chain topology and
    the periodic ring (which must exchange true wrapped coefficients)."""
    code = """
        import jax, numpy as np, jax.numpy as jnp
        assert jax.device_count() == 2, jax.devices()
        from repro.kernels import (get_stencil, stencil_apply, stencil_ref,
                                   stencil_sharded)
        rng = np.random.default_rng(5)
        mesh = jax.make_mesh((2,), ("data",))
        m, n, p = 16, 10, 16
        for bc in (None, "periodic"):
            spec = get_stencil("stencil27").with_coef("var")
            if bc is not None:
                spec = spec.with_bc(bc)
            a = jnp.asarray(rng.integers(-3, 4, (m, n, p)), jnp.float32)
            w = jnp.asarray(rng.integers(1, 4, (spec.n_weights, m, n, p)),
                            jnp.float32)
            for s in (1, 2):
                got = stencil_sharded(a, w, spec, mesh=mesh, sweeps=s)
                one = stencil_apply(a, w, spec, block_i=4, sweeps=s)
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(one))
                ref = stencil_ref(a, w, spec, sweeps=s)
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(ref))
        print("var sharded ok")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "var sharded ok" in out.stdout
