"""Registry / dispatch error paths and hashing contracts: unknown-name
messages, alias identity, ad-hoc frozen-spec stability through ``jax.jit``
static args, plan-compiler memoization across alias spellings at radius 2,
and ``spec_from_mask`` validation (odd shapes, gapped integer masks)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (compile_plan, get_stencil, list_stencils,
                           spec_from_mask, stencil_apply)
from repro.kernels.stencil_engine.spec import StencilSpec


def test_unknown_stencil_message_lists_registered_names():
    with pytest.raises(KeyError) as ei:
        get_stencil("stencil99")
    msg = str(ei.value)
    for name in ("stencil3", "stencil7", "stencil27", "star13", "box125"):
        assert name in msg
    assert "stencil99" in msg


def test_aliases_resolve_to_identical_spec_object():
    """Aliases are registry entries pointing at the *same* frozen spec, not
    equal copies -- so static-arg jit caches and the plan memo can't split
    on spelling."""
    for alias, name in (("3", "stencil3"), ("7", "stencil7"),
                        ("27", "stencil27"), ("13", "star13"),
                        ("125", "box125")):
        assert get_stencil(alias) is get_stencil(name)
        assert get_stencil(int(alias)) is get_stencil(name)
    regs = list_stencils()
    assert regs["13"] is regs["star13"]
    assert regs["star13"].radius == (2, 2, 2)
    assert regs["box125"].taps == 125 and regs["box125"].n_weights == 27


def test_adhoc_spec_hashes_stably_through_jit_static_args():
    """Two equal-valued spec_from_mask results are distinct objects but must
    hash/compare equal, so a jitted function with the spec as a static
    argument does not retrace per object."""
    mask = np.zeros((5, 5, 5), bool)
    mask[2, 2, 2] = mask[2, 2, 0] = mask[2, 2, 4] = True
    s1 = spec_from_mask("jit-probe", mask)
    s2 = spec_from_mask("jit-probe", mask)
    assert s1 is not s2 and s1 == s2 and hash(s1) == hash(s2)
    assert s1.radius == (2, 2, 2)

    traces = []
    import functools

    @functools.partial(jax.jit, static_argnames=("spec",))
    def run(a, *, spec: StencilSpec):
        traces.append(spec.name)
        return a * spec.taps

    a = jnp.ones((4,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(run(a, spec=s1)),
                                  np.asarray(run(a, spec=s2)))
    assert len(traces) == 1          # second call hit the jit cache


def test_compile_plan_memo_unifies_aliases_at_radius2():
    """String, int, and spec-object spellings -- and auto vs its resolved
    (kind, unroll) -- share one compiled plan entry for the radius-2
    builtins."""
    assert compile_plan("star13") is compile_plan("13")
    assert compile_plan("star13") is compile_plan(13)
    assert compile_plan("star13") is compile_plan(get_stencil("star13"))
    # auto's winner is also the winner of its own kind's unroll ladder, so
    # the explicit spelling of the resolved kind hits the same memo entry
    auto = compile_plan("star13", "auto")
    assert auto is compile_plan("star13", auto.kind)
    assert compile_plan("box125") is compile_plan(125)
    # distinct kinds stay distinct entries
    assert compile_plan("star13", "direct") is not compile_plan("star13")


def test_spec_from_mask_rejects_gapped_integer_indices():
    """An integer mask whose weight indices skip values used to silently
    allocate a dangling unused weight (n_weights = max + 1)."""
    mask = -np.ones((3, 3, 3), np.int64)
    mask[1, 1, 1] = 0
    mask[1, 1, 0] = mask[1, 1, 2] = 2          # skips index 1
    with pytest.raises(ValueError, match="skip"):
        spec_from_mask("gappy", mask)
    # contiguous indices stay fine
    mask[1, 1, 0] = mask[1, 1, 2] = 1
    spec = spec_from_mask("dense", mask)
    assert spec.n_weights == 2


def test_spec_from_mask_shape_validation():
    with pytest.raises(ValueError, match="odd"):
        spec_from_mask("even", np.zeros((4, 3, 3), bool))
    with pytest.raises(ValueError, match="odd"):
        spec_from_mask("flat", np.zeros((3, 3), bool))
    # mixed odd radii are fine: radius derives per axis
    mask = np.zeros((5, 3, 7), bool)
    mask[2, 1, 3] = mask[0, 1, 3] = mask[4, 1, 3] = True
    spec = spec_from_mask("aniso", mask)
    assert spec.radius == (2, 1, 3)
    assert spec.offsets == ((-2, 0, 0), (0, 0, 0), (2, 0, 0))


def test_spec_radius_validation():
    with pytest.raises(ValueError, match="radius"):
        StencilSpec(name="bad-r", ndim=3, offsets=((0, 0, 0),),
                    w_index=(0,), n_weights=1, w_shape=(1,),
                    radius=(1, 1))
    with pytest.raises(ValueError, match="out of range"):
        StencilSpec(name="bad-off", ndim=3, offsets=((-2, 0, 0),),
                    w_index=(0,), n_weights=1, w_shape=(1,),
                    radius=(1, 1, 1))


def test_radius0_axis_mask_runs_both_paths():
    """A (1, 3, 3) mask -- no i-taps, radius (0, 1, 1) -- runs through the
    volumetric engine on both paths: zero halo planes, zero-length scratch
    rotation, single staged view."""
    rng = np.random.default_rng(6)
    mask = np.zeros((1, 3, 3), bool)
    for dj, dk in ((0, 0), (-1, 0), (1, 0), (0, -1), (0, 1)):
        mask[0, 1 + dj, 1 + dk] = True
    spec = spec_from_mask("jk5", mask)
    assert spec.radius == (0, 1, 1)
    from repro.kernels import stencil_ref
    a = jnp.asarray(rng.integers(-4, 5, (8, 9, 16)), jnp.float32)
    w = jnp.asarray(rng.integers(1, 4, 5), jnp.float32)
    ref = np.asarray(stencil_ref(a, w, spec))
    for path in ("stream", "replicate"):
        for bj in (None, 3):
            got = stencil_apply(a, w, spec, block_i=4, block_j=bj,
                                path=path)
            np.testing.assert_array_equal(np.asarray(got), ref)


def test_radius2_mask_spec_runs_end_to_end():
    """An ad-hoc 5x5x5 mask runs through stencil_apply and matches a hand
    check at one interior point (two-away neighbours included)."""
    rng = np.random.default_rng(2)
    mask = np.zeros((5, 5, 5), bool)
    mask[2, 2, 2] = mask[0, 2, 2] = mask[4, 2, 2] = mask[2, 2, 0] = True
    spec = spec_from_mask("i2k2", mask)
    assert spec.radius == (2, 2, 2) and spec.n_weights == 4
    a = jnp.asarray(rng.standard_normal((8, 6, 16)), jnp.float32)
    w = jnp.asarray([1.5, 0.25, 0.5, 2.0], jnp.float32)
    got = stencil_apply(a, w, spec, block_i=4)
    i, j, k = 3, 2, 7
    # lexicographic taps: (-2,0,0)->w0, (0,0,-2)->w1, (0,0,0)->w2, (2,0,0)->w3
    expect = float(1.5 * a[i - 2, j, k] + 0.25 * a[i, j, k - 2]
                   + 0.5 * a[i, j, k] + 2.0 * a[i + 2, j, k])
    assert abs(float(got[i, j, k]) - expect) < 1e-4
