"""Pass-pipeline unit tests: each plan-compiler pass is checked on its own
op-count / liveness / dataflow invariants, and the presets reproduce the
contracted static counts (incl. the radius-2 acceptance numbers)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import compile_plan, get_stencil, spec_from_mask
from repro.kernels.stencil_engine.plan import (PASS_PRESETS, build_direct,
                                               cse, mirror_factor,
                                               mirror_symmetric, order_ops,
                                               peak_live, run_passes)
from repro.kernels.stencil_engine.plan.ir import op_sources

BUILTINS = ("stencil3", "stencil7", "stencil27", "star13", "box125")


def test_presets_and_pass_recording():
    """The former monolithic plan kinds are pass-list presets, and each
    compiled plan records the pipeline that produced it."""
    assert PASS_PRESETS["direct"] == ("build_direct",)
    assert PASS_PRESETS["cse"][0] == "build_direct" and \
        "cse" in PASS_PRESETS["cse"]
    assert "mirror_factor" in PASS_PRESETS["factored"]
    d = compile_plan("stencil27", "direct")
    assert d.passes == ("build_direct",)
    f = compile_plan("stencil27", "factored")
    assert f.passes[0] == "build_direct" and "mirror_factor" in f.passes
    assert f.passes[-1].startswith("order_ops")


def test_run_passes_error_paths():
    spec = get_stencil("stencil7")
    with pytest.raises(ValueError, match="build_direct"):
        run_passes(spec, ("cse",))
    with pytest.raises(ValueError, match="unknown pass"):
        run_passes(spec, ("build_direct", "vectorize"))


def test_build_direct_counts():
    """One shift per nonzero offset component per tap (a radius-2 component
    is one magnitude-2 shift), one multiply-add per tap."""
    for name, shifts, flops in (("stencil27", 54, 53), ("star13", 12, 25),
                                ("box125", 300, 249)):
        p = build_direct(get_stencil(name))
        assert (p.shifts, p.flops) == (shifts, flops), name
        # direct peak liveness is constant: u, the tap chain, the accumulator
        assert peak_live(p) <= 4, name


def test_cse_pass_invariants():
    """cse never emits more shifts than direct and never changes flops."""
    for name in BUILTINS:
        spec = get_stencil(name)
        d = build_direct(spec)
        c = cse(d)
        assert c.kind == "cse" and c.passes[-1] == "cse"
        assert c.shifts <= d.shifts and c.flops == d.flops, name
    assert cse(build_direct(get_stencil("stencil27"))).shifts == 10
    assert cse(build_direct(get_stencil("box125"))).shifts == 28


def test_mirror_factor_radius2_acceptance():
    """Acceptance: the factored radius-2 star plan statically beats its
    direct schedule on shifts+flops (like the stencil27 8+19 check), and
    box125 collapses from 300 shifts to 20."""
    d13 = compile_plan("star13", "direct")
    f13 = compile_plan("star13", "factored")
    assert (d13.shifts, d13.flops) == (12, 25)
    assert (f13.shifts, f13.flops) == (12, 19)
    assert f13.shifts + f13.flops < d13.shifts + d13.flops
    assert f13.shifts <= d13.shifts and f13.flops < d13.flops

    d125 = compile_plan("box125", "direct")
    f125 = compile_plan("box125", "factored")
    assert (f125.shifts, f125.flops) == (20, 63)
    assert f125.shifts * 3 <= d125.shifts
    assert f125.flops <= 0.4 * d125.flops

    # the stencil27 contract is unchanged by the pass restructuring
    f27 = compile_plan("stencil27", "factored")
    assert (f27.shifts, f27.flops) == (8, 19)


def test_mirror_factor_noop_on_asymmetric():
    mask = np.zeros((3, 3, 3), bool)
    mask[1, 1, 1] = mask[1, 1, 2] = True
    spec = spec_from_mask("asym-noop", mask)
    assert not mirror_symmetric(spec)
    d = build_direct(spec)
    assert mirror_factor(d) is d


def test_order_ops_never_increases_liveness_on_builtins():
    """Acceptance: the order_ops pass provably never increases peak SSA
    liveness on the builtin specs, for every preset pipeline stage it can
    follow -- and its reordering preserves the op multiset and the SSA
    topological property."""
    for name in BUILTINS:
        spec = get_stencil(name)
        pres = [build_direct(spec), cse(build_direct(spec))]
        if mirror_symmetric(spec):
            pres.append(mirror_factor(build_direct(spec)))
        for pre in pres:
            post = order_ops(pre)
            assert peak_live(post) <= peak_live(pre), (name, pre.kind)
            assert post.passes[-1].startswith("order_ops")
            # op multiset (kind, off, w_idx) unchanged -- pure reordering
            key = lambda p: sorted((o.kind, o.off, o.w_idx) for o in p.ops)
            assert key(post) == key(pre), (name, pre.kind)
            assert (post.shifts, post.flops) == (pre.shifts, pre.flops)
            # valid SSA numbering: every op only reads earlier values
            for i, op in enumerate(post.ops):
                assert all(v <= i for v in op_sources(op)), (name, i)
            assert 0 <= post.out <= len(post.ops)


def test_order_ops_actually_reduces_pressure_somewhere():
    """Not just 'never worse': on the wide radius-2 box the grouped cse
    schedule's working set shrinks materially under the scheduler order."""
    spec = get_stencil("box125")
    pre = cse(build_direct(spec))
    post = order_ops(pre)
    assert peak_live(post) < peak_live(pre)


def test_peak_live_hand_example():
    """peak_live on a hand-built plan: u shifted twice, summed -- both
    shifts are live together, then the sum replaces them."""
    from repro.kernels.stencil_engine.plan.ir import Builder, StencilPlan
    spec = get_stencil("stencil3")
    b = Builder()
    l = b.shift(0, 2, -1)
    r = b.shift(0, 2, 1)
    s = b.add(l, r)
    plan = StencilPlan(spec=spec, kind="direct", ops=tuple(b.ops), out=s)
    # u + l -> u + l + r (peak: u, l, r) -> s (u dead after r, l/r die at s)
    assert peak_live(plan) == 3


def test_ordered_plans_execute_identically():
    """order_ops is pure reordering: on integer-valued f64 data every
    pipeline stage (before/after ordering) produces bit-identical results
    through the executor."""
    from repro.kernels import stencil_ref
    rng = np.random.default_rng(3)
    with jax.experimental.enable_x64():
        a = jnp.asarray(rng.integers(-4, 5, (8, 10, 16)), jnp.float64)
        for name in ("stencil27", "star13", "box125"):
            spec = get_stencil(name)
            w = jnp.asarray(rng.integers(1, 4, spec.w_shape), jnp.float64)
            base = np.asarray(stencil_ref(a, w, name, plan="direct"))
            for kind in ("cse", "factored"):
                got = np.asarray(stencil_ref(a, w, name, plan=kind))
                np.testing.assert_array_equal(got, base)


def test_describe_reports_radius_and_pass_list():
    d = compile_plan("star13", "factored").describe()
    assert d["radius"] == [2, 2, 2]
    assert d["pass_list"][0] == "build_direct"
    assert "peak_live" in d and d["peak_live"] >= 1
    assert d["taps"] == 13
