"""Cost-model fidelity: the plan compiler's cycles/point estimates ARE the
core PPC450 scheduler + in-order simulator numbers (re-derived here
independently for every enumerated radius-1 candidate), the cost-driven
selection never picks a variant modeled slower than the ``direct`` baseline,
and the plan memo never shares entries across variable- vs
constant-coefficient spellings of one tap set."""

import pytest

from repro.core.dag import build_dag
from repro.core.scheduler import greedy_schedule
from repro.core.simulator import simulate_inorder
from repro.kernels.stencil_engine.plan import compile_plan
from repro.kernels.stencil_engine.plan.cost import (SIM_INSTR_LIMIT,
                                                    SIM_ITERS, estimate_plan,
                                                    lower_plan)
from repro.kernels.stencil_engine.spec import get_stencil

RADIUS1 = ["stencil3", "stencil7", "stencil27"]


def _resimulate(plan) -> float:
    """Independent replay of the cost model's pipeline: lower, greedy
    list-schedule over the RAW-only DAG, in-order simulate, divide by the
    unroll factor (one output point per unrolled copy)."""
    instrs = lower_plan(plan, plan.unroll)
    sched = greedy_schedule(instrs, build_dag(instrs, war=False))
    ordered = [instrs[i] for i in sched.order]
    timing = simulate_inorder(ordered, n_iters=SIM_ITERS)
    return timing.per_iter_cycles / plan.unroll


@pytest.mark.parametrize("name", RADIUS1)
@pytest.mark.parametrize("coef", ["const", "var"])
def test_estimates_are_simulator_cycles(name, coef):
    """Every enumerated (kind, unroll) candidate of a radius-1 builtin fits
    under SIM_INSTR_LIMIT, so its recorded cycles/point must come from the
    in-order simulator -- and must equal an independent re-simulation."""
    spec = get_stencil(name).with_coef(coef)
    auto = compile_plan(spec)
    assert auto.candidates, "cost-driven compiler records its candidates"
    for kind, u, cpp in auto.candidates:
        plan = compile_plan(spec, kind, unroll=u)
        assert plan.modeled is not None
        assert plan.modeled.cycles_per_point == cpp
        assert plan.modeled.n_instrs <= SIM_INSTR_LIMIT
        assert plan.modeled.source == "simulator"
        assert plan.modeled.cycles_per_point == pytest.approx(
            _resimulate(plan)), (name, coef, kind, u)


@pytest.mark.parametrize("name", RADIUS1 + ["star13", "box125"])
@pytest.mark.parametrize("coef", ["const", "var"])
def test_selection_never_slower_than_direct(name, coef):
    """The chosen variant is modeled no slower than the untouched-naive
    ``direct`` baseline (and no slower than any enumerated candidate)."""
    spec = get_stencil(name).with_coef(coef)
    auto = compile_plan(spec)
    chosen = auto.modeled.cycles_per_point
    rows = dict(((k, u), c) for k, u, c in auto.candidates)
    assert ("direct", 1) in rows
    assert chosen <= rows[("direct", 1)] + 1e-6
    assert chosen <= min(rows.values()) + 1e-6
    sel = auto.describe()["selection"]
    assert sel["kind"] == auto.kind and sel["unroll"] == auto.unroll
    assert sel["cycles_per_point"] == chosen
    assert len(sel["candidates"]) == len(auto.candidates)


def test_unroll_estimate_matches_explicit_argument():
    """estimate_plan(plan, u) and the plan's own baked-in unroll agree."""
    plan = compile_plan("stencil27", "factored", unroll=2)
    assert plan.unroll == 2
    assert estimate_plan(plan).cycles_per_point == pytest.approx(
        estimate_plan(plan, 2).cycles_per_point)


def test_memo_not_shared_across_coefficient_kinds():
    """Regression: the compile memo keys on the full spec value including
    ``coef``, so var and const spellings of one tap set never share a plan
    object, a cost table, or a modeled cost."""
    spec = get_stencil("stencil27")
    vspec = spec.with_coef("var")
    pc, pv = compile_plan(spec), compile_plan(vspec)
    # memoized within a spelling...
    assert pc is compile_plan(spec)
    assert pv is compile_plan(vspec)
    assert pc is compile_plan(get_stencil("stencil27"))
    # ...never across coefficient kinds, even at a pinned (kind, unroll)
    assert pc is not pv
    assert pc.spec.coef == "const" and pv.spec.coef == "var"
    k, u = pv.kind, pv.unroll
    same_kind_const = compile_plan(spec, k, unroll=u)
    assert same_kind_const is not compile_plan(vspec, k, unroll=u)
    # the var variant pays per-point weight loads: strictly more instructions
    # and a strictly larger modeled cost at the same (kind, unroll)
    assert pv.modeled.n_instrs > same_kind_const.modeled.n_instrs
    assert (pv.modeled.cycles_per_point
            > same_kind_const.modeled.cycles_per_point)
