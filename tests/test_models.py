"""Model zoo: per-arch smoke tests + cross-path consistency properties."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import build_model, param_count
from repro.models.common import SHAPES

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.family == "vlm":
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_len, cfg.frontend_dim)),
            jnp.float32)
    elif cfg.family == "encdec":
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.frontend_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_smoke_forward_and_train_step(aid):
    """Reduced config: one forward + one gradient step, shapes + no NaNs."""
    cfg = get_reduced_config(aid)
    m = build_model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(m.loss_fn)(params, batch)
    assert jnp.isfinite(loss), aid
    flat = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g).all() for g in flat), aid
    logits = m.apply_fn(params, batch)
    assert logits.shape[0] == batch["tokens"].shape[0]
    assert jnp.isfinite(logits.astype(jnp.float32)).all()


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_smoke_decode_step(aid):
    cfg = get_reduced_config(aid)
    m = build_model(cfg)
    params = m.init(KEY)
    b, ms = 2, 64
    frontend = (jnp.ones((b, 16, cfg.frontend_dim), jnp.float32)
                if cfg.family == "encdec" else None)
    st = m.init_decode_state(params, b, ms, frontend=frontend)
    logits, st2 = m.decode_step(params, st, jnp.zeros((b, 1), jnp.int32),
                                jnp.int32(0))
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), aid
    assert jax.tree.structure(st) == jax.tree.structure(st2)


@pytest.mark.parametrize("aid", ["qwen1.5-0.5b", "falcon-mamba-7b",
                                 "zamba2-7b", "mixtral-8x7b"])
def test_decode_matches_prefill(aid):
    """Stepwise decode must reproduce the full-sequence forward."""
    cfg = dataclasses.replace(get_reduced_config(aid), dtype=jnp.float32)
    m = build_model(cfg)
    params = m.init(KEY)
    b, t = 2, 8
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)))
    full = m.apply_fn(params, {"tokens": tokens})

    st = m.init_decode_state(params, b, t)
    outs = []
    for i in range(t):
        lg, st = m.decode_step(params, st, tokens[:, i:i + 1], jnp.int32(i))
        outs.append(lg[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_moe_identical_experts_equal_dense():
    """Property: with identical experts and ample capacity, routed output ==
    the single expert applied densely (top-k weights are normalized)."""
    from repro.models.moe import init_moe, moe_ffn
    cfg = dataclasses.replace(get_reduced_config("mixtral-8x7b"),
                              dtype=jnp.float32, capacity_factor=8.0)
    p = init_moe(KEY, cfg)
    p["wi"] = jnp.broadcast_to(p["wi"][:1], p["wi"].shape)
    p["wg"] = jnp.broadcast_to(p["wg"][:1], p["wg"].shape)
    p["wo"] = jnp.broadcast_to(p["wo"][:1], p["wo"].shape)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 64)),
                    jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    dense = jax.nn.silu(x @ p["wg"][0]) * (x @ p["wi"][0]) @ p["wo"][0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    from repro.models.moe import moe_ffn, init_moe
    cfg = dataclasses.replace(get_reduced_config("mixtral-8x7b"),
                              dtype=jnp.float32, capacity_factor=0.01)
    p = init_moe(KEY, cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 256, 64)),
                    jnp.float32)
    out, _ = moe_ffn(p, x, cfg)
    assert jnp.isfinite(out).all()


@pytest.mark.parametrize("kind", ["mamba1", "mamba2"])
def test_mamba_chunked_matches_stepwise(kind):
    """The chunked scan equals running the block one token at a time."""
    aid = "falcon-mamba-7b" if kind == "mamba1" else "zamba2-7b"
    cfg = dataclasses.replace(get_reduced_config(aid), dtype=jnp.float32)
    from repro.models.ssm import init_mamba, init_ssm_state, mamba_block
    p = init_mamba(KEY, cfg)
    b, l = 2, 12
    x = jnp.asarray(np.random.default_rng(2).standard_normal(
        (b, l, cfg.d_model)), jnp.float32)
    full, _ = mamba_block(p, x, cfg)
    st = init_ssm_state(cfg, b)
    outs = []
    for i in range(l):
        o, st = mamba_block(p, x[:, i:i + 1], cfg, state=st)
        outs.append(o[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_swa_limits_receptive_field():
    """Sliding-window attention must ignore keys beyond the window."""
    cfg = dataclasses.replace(get_reduced_config("starcoder2-7b"),
                              dtype=jnp.float32, window=4, n_layers=1)
    m = build_model(cfg)
    params = m.init(KEY)
    rng = np.random.default_rng(3)
    t1 = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)))
    t2 = t1.at[0, 0:8].set((t1[0, 0:8] + 1) % cfg.vocab_size)
    l1 = m.apply_fn(params, {"tokens": t1})
    l2 = m.apply_fn(params, {"tokens": t2})
    # last position attends only to the final window=4 tokens (plus itself
    # through the residual stream); identical suffix => identical logits
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_param_counts_match_published_scale():
    """Analytic parameter counts land on the published model scales."""
    expected = {"mixtral-8x7b": 46.7e9, "arctic-480b": 480e9,
                "falcon-mamba-7b": 7.3e9, "starcoder2-7b": 7.2e9,
                "nemotron-4-15b": 15.1e9, "qwen2-0.5b": 0.49e9,
                "zamba2-7b": 7.0e9}
    for aid, exp in expected.items():
        got = param_count(get_config(aid))
        assert abs(got - exp) / exp < 0.12, f"{aid}: {got/1e9:.1f}B vs {exp/1e9:.1f}B"


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    spec = {
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "falcon-mamba-7b": (64, 4096, None, None, 0, 65024),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    for aid, (nl, d, h, kv, ff, v) in spec.items():
        cfg = get_config(aid)
        layers = cfg.enc_layers if cfg.family == "encdec" else cfg.n_layers
        assert layers == nl, aid
        assert cfg.d_model == d, aid
        if h is not None:
            assert cfg.n_heads == h and cfg.n_kv_heads == kv, aid
        assert cfg.d_ff == ff, aid
        assert cfg.vocab_size == v, aid
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["long_500k"].seq_len == 524288
