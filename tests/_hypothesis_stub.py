"""Fallback for the optional ``hypothesis`` dev dependency.

When hypothesis is installed (``pip install -r requirements-dev.txt``) the
test modules use it directly; when it is missing, these stubs turn each
``@given`` property test into a single skipped test instead of killing the
whole module at collection time.
"""

import pytest

_REASON = "hypothesis not installed (pip install -r requirements-dev.txt)"


def given(*_args, **_kwargs):
    def deco(fn):
        @pytest.mark.skip(reason=_REASON)
        def shim():
            pass
        shim.__name__ = fn.__name__
        shim.__doc__ = fn.__doc__
        return shim
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _Strategies:
    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()
