"""Temporal wavefront tiling + red-black ordering: sweep-composition
property tests (s chained calls == one fused sweeps=s call == wavefront
driver, bit-exact on integer f64 across BC x path x radius), the
sweeps-aware autotuner race, the red-black kernel-vs-oracle parity, the
2-device deep-halo sharded run (subprocess), and the regression gate's
new-row semantics."""

import importlib.util
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (SWEEP_MODES, autotune_sweeps, compile_plan,
                           get_stencil, stencil_apply, stencil_ref,
                           stencil_sweep_driver, stencil_wavefront)
from repro.kernels.stencil_engine.autotune import wavefront_block_i
from repro.kernels.stencil_engine.spec import ORDERING_KINDS

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(11)


def _int_field(shape):
    """Integer-valued f64 data: every reassociation/blocking is exact, so
    cross-mode comparisons can be ``assert_array_equal``."""
    return jnp.asarray(RNG.integers(-4, 5, shape).astype(np.float64))


def _int_weights(n):
    return jnp.asarray(RNG.integers(-3, 4, n).astype(np.float64))


SWEEP_SPECS = [
    ("stencil27", 8), ("stencil27_periodic", 8), ("stencil27_neumann", 8),
    ("stencil27_dirichlet", 8), ("star13", 3), ("star13_periodic", 3),
]


@pytest.mark.parametrize("name,nw", SWEEP_SPECS)
@pytest.mark.parametrize("s", [2, 4])
def test_sweep_composition_bit_exact(name, nw, s):
    """s chained calls == one fused sweeps=s call == the wavefront driver
    == the oracle, bit-exact, across BC x radius x s."""
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(nw)
        chained = a
        for _ in range(s):
            chained = stencil_apply(chained, w, name, sweeps=1)
        fused = stencil_apply(a, w, name, sweeps=s)
        wave = stencil_wavefront(a, w, name, sweeps=s)
        ref = stencil_ref(a, w, name, sweeps=s)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(chained))
        np.testing.assert_array_equal(np.asarray(wave), np.asarray(chained))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(chained))


def test_sweep_composition_across_paths():
    """The chained oracle is path-invariant, and the wavefront matches it
    whichever path produced it (stream vs replicate)."""
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(8)
        wave = stencil_wavefront(a, w, "stencil27", sweeps=3)
        for path in ("stream", "replicate"):
            chained = a
            for _ in range(3):
                chained = stencil_apply(chained, w, "stencil27", sweeps=1,
                                        path=path)
            np.testing.assert_array_equal(np.asarray(wave),
                                          np.asarray(chained))


@pytest.mark.parametrize("mode", ["auto", "fused", "wavefront", "chained"])
def test_driver_modes_agree(mode):
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(8)
        ref = stencil_ref(a, w, "stencil27", sweeps=4)
        got = stencil_sweep_driver(a, w, "stencil27", sweeps=4, mode=mode)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_driver_batched_and_block_pins():
    with jax.experimental.enable_x64():
        a = _int_field((2, 12, 8, 32))
        w = _int_weights(8)
        ref = stencil_ref(a, w, "stencil27", sweeps=2)
        got = stencil_sweep_driver(a, w, "stencil27", sweeps=2,
                                   mode="wavefront", block_i=4)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_redblack_kernel_matches_oracle():
    """Red-black Gauss-Seidel: masked half-sweeps in the kernel == the
    NumPy-oracle checkerboard, for 3-D and 1-D specs, all modes."""
    with jax.experimental.enable_x64():
        a = _int_field((12, 8, 32))
        w = _int_weights(8)
        ref = stencil_ref(a, w, "stencil27_redblack", sweeps=2)
        for mode in ("fused", "wavefront", "chained"):
            got = stencil_sweep_driver(a, w, "stencil27_redblack", sweeps=2,
                                       mode=mode)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        # red-black genuinely differs from Jacobi on the same data
        jac = stencil_ref(a, w, "stencil27", sweeps=2)
        assert np.abs(np.asarray(ref) - np.asarray(jac)).max() > 0
        # 1-D (k-only) parity
        a1 = _int_field((6, 32))
        w1 = _int_weights(2)
        got1 = stencil_apply(a1, w1, "stencil3_redblack", sweeps=2)
        ref1 = stencil_ref(a1, w1, "stencil3_redblack", sweeps=2)
        np.testing.assert_array_equal(np.asarray(got1), np.asarray(ref1))


def test_redblack_spec_properties():
    spec = get_stencil("stencil27")
    rb = spec.with_ordering("redblack")
    assert rb.ordering == "redblack" and rb.sweep_apps == 2
    assert spec.sweep_apps == 1 and "redblack" in ORDERING_KINDS
    assert get_stencil("stencil27_redblack").ordering == "redblack"
    assert compile_plan(rb).describe()["ordering"] == "redblack"
    with pytest.raises(ValueError, match="ordering"):
        spec.with_ordering("zebra")


def test_autotune_sweeps_race():
    """The sweeps-aware roofline: wavefront or fused wins at s > 1 (both
    model 2*itemsize/s bytes/point vs 2*itemsize chained), fused wins the
    s=1 tie, and the verdict is recorded in describe()["selection"]."""
    cplan = compile_plan("stencil27")
    sel = autotune_sweeps(16, 24, 128, 4, 4, cplan)
    assert sel.mode in ("wavefront", "fused") and sel.sweeps == 4
    assert sel.bytes_per_point == pytest.approx(2.0)
    d = sel.describe()["selection"]
    assert d["mode"] == sel.mode
    assert {c["mode"] for c in d["candidates"]} == {"fused", "wavefront",
                                                    "chained"}
    chained = next(c for c in d["candidates"] if c["mode"] == "chained")
    assert chained["bytes_per_point"] == pytest.approx(8.0)
    assert autotune_sweeps(16, 24, 128, 4, 1, cplan).mode == "fused"
    # variable coefficients: the wavefront entrant drops out / refuses
    var = compile_plan(get_stencil("stencil27").with_coef("var"))
    assert autotune_sweeps(16, 24, 128, 4, 4, var).mode != "wavefront"
    with pytest.raises(ValueError, match="wavefront"):
        autotune_sweeps(16, 24, 128, 4, 4, var, mode="wavefront")
    with pytest.raises(ValueError, match="mode"):
        autotune_sweeps(16, 24, 128, 4, 2, cplan, mode="sideways")
    assert "auto" in SWEEP_MODES
    bi = wavefront_block_i(16, 24, 128, 4, 4, cplan)
    assert 16 % bi == 0 and bi >= 1


def test_wavefront_input_validation():
    with jax.experimental.enable_x64():
        a1 = _int_field((6, 32))
        with pytest.raises(ValueError, match="volumetric"):
            stencil_wavefront(a1, _int_weights(2), "stencil3", sweeps=2)
        # periodic deep halo must fit the domain
        a = _int_field((4, 8, 32))
        with pytest.raises(ValueError, match="halo"):
            stencil_wavefront(a, _int_weights(8), "stencil27_periodic",
                              sweeps=8)
        with pytest.raises(ValueError, match="mode"):
            stencil_sweep_driver(a, _int_weights(8), "stencil27",
                                 sweeps=2, mode="sideways")


def test_sharded_deep_halo_2dev_subprocess():
    """2 forced host devices: one radius*sweep_apps*s-deep halo exchange +
    redundant boundary recompute (fused and wavefront modes) matches the
    single-device chained oracle bit-exactly on integer f64."""
    code = """
    import jax, numpy as np, jax.numpy as jnp
    from repro.kernels import stencil_apply, stencil_sharded
    assert jax.device_count() == 2
    with jax.experimental.enable_x64():
        rng = np.random.default_rng(5)
        a = jnp.asarray(rng.integers(-4, 5, (16, 8, 32)).astype(np.float64))
        w8 = jnp.asarray(rng.integers(-3, 4, 8).astype(np.float64))
        w3 = jnp.asarray(rng.integers(-3, 4, 3).astype(np.float64))
        mesh = jax.make_mesh((2,), ("data",))
        for name, w, s in (("stencil27", w8, 2), ("stencil27", w8, 4),
                           ("stencil27_periodic", w8, 2),
                           ("star13_neumann", w3, 2),
                           ("stencil27_redblack", w8, 2)):
            chained = a
            for _ in range(s):
                chained = stencil_apply(chained, w, name, sweeps=1)
            for mode in ("fused", "wavefront", "auto"):
                got = stencil_sharded(a, w, name, mesh=mesh, sweeps=s,
                                      mode=mode)
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(chained))
        print("deep-halo ok")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "deep-halo ok" in out.stdout


def _load_check_regression():
    path = os.path.join(REPO, "benchmarks", "check_regression.py")
    mod_spec = importlib.util.spec_from_file_location("check_regression",
                                                      path)
    mod = importlib.util.module_from_spec(mod_spec)
    mod_spec.loader.exec_module(mod)
    return mod


def test_regression_gate_new_rows_are_notes_not_failures():
    """Satellite: fresh-only rows (new wavefront/ordering entries) report
    as 'new, not gated yet'; baseline rows must still be present; an
    unjustified sweep-mode flip fails."""
    cr = _load_check_regression()
    sweeps_entry = {"mode": "wavefront", "bytes_per_point": 2.0,
                    "time_per_point": 1e-11,
                    "candidates": [
                        {"mode": "wavefront", "bytes_per_point": 2.0,
                         "time_per_point": 1e-11},
                        {"mode": "chained", "bytes_per_point": 8.0,
                         "time_per_point": 9e-12}]}
    base = {"schema": "bench_stencil/v5",
            "paths": {"stream": {"bytes_per_point_f32": 8.0}},
            "sweeps": {"stencil27/s4": sweeps_entry}}
    fresh = {"schema": "bench_stencil/v5",
             "paths": {"stream": {"bytes_per_point_f32": 8.0}},
             "sweeps": {"stencil27/s4": sweeps_entry,
                        "box125/s4": dict(sweeps_entry)}}
    failures, notes = cr.compare(base, fresh, 0.05)
    assert not failures
    assert any("box125/s4" in n and "not gated" in n for n in notes)
    # baseline row disappearing is still a failure
    failures, _ = cr.compare(fresh, base, 0.05)
    assert any("box125/s4" in f for f in failures)
    # a flip the fresh race argues against fails
    flipped = {"schema": "bench_stencil/v5",
               "paths": {"stream": {"bytes_per_point_f32": 8.0}},
               "sweeps": {"stencil27/s4": {
                   "mode": "chained", "bytes_per_point": 8.0,
                   "time_per_point": 9e-12,
                   "candidates": sweeps_entry["candidates"]}}}
    failures, _ = cr.compare(base, flipped, 0.05)
    assert any("flipped" in f or "bytes/point" in f for f in failures)
