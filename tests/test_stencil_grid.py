"""Multi-axis (i, j, k) process-grid sharding + compute/communication overlap.

Two kinds of coverage:

* in-process 8-device tests (``@multidevice``) -- the dedicated CI leg runs
  this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, so
  2x2x2 and 4x2 meshes execute real shard_map programs with per-axis
  ppermute exchanges, overlap on and off, bit-exact against the
  single-device oracle on integer-valued data (corner/edge ghosts are where
  a diagonal-heavy stencil27 goes wrong if the transitive j -> k -> i
  exchange mis-fills anything);
* subprocess + pure-planner tests that run on every leg: the thin-shard
  validation raise, plan fallbacks, the per-axis exchange-bytes model, and
  one small end-to-end 2x2x2 parity check so tier-1 keeps multi-axis
  coverage even where the in-process leg is absent.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import stencil_apply, stencil_sharded
from repro.kernels.stencil_engine import exchange_bytes_per_point, get_stencil

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 in-process devices (the multidevice CI leg sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _run(code: str, n_dev: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _field(shape, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-4, 5, size=shape), dtype)


def _weights(spec_name, seed=1, dtype=jnp.float32):
    spec = get_stencil(spec_name)
    rng = np.random.default_rng(seed)
    shape = {"stencil27": (2, 2, 2), "star13": (3,), "stencil7": (4,),
             "box125": (3, 3, 3)}[spec.name.split("_")[0]
                                  if "_" in spec.name else spec.name]
    return jnp.asarray(rng.integers(-3, 4, size=shape), dtype)


# ---------------------------------------------------------------------------
# planner validation (no devices needed beyond what the process has)
# ---------------------------------------------------------------------------

def test_thin_shard_raises_i_axis_subprocess():
    """The satellite bugfix: a mesh axis too wide for the i extent raises
    with the shapes in the message instead of silently planning a halo the
    shards cannot cover."""
    print(_run("""
        import jax, pytest
        from repro.sharding import stencil_halo_sharding
        mesh = jax.make_mesh((8,), ("data",))
        # 16 rows / 8 shards = 2 local rows < radius 1 * sweeps 4
        try:
            stencil_halo_sharding(16, mesh, sweeps=4, radius=1)
        except ValueError as e:
            msg = str(e)
            assert "M=16" in msg and "'data'=8" in msg and "2 local" in msg
            assert "radius * sweeps" in msg
        else:
            raise AssertionError("thin shard did not raise")
        # the graceful fallbacks stay graceful: indivisible extents PlanNote
        plan = stencil_halo_sharding(17, mesh, sweeps=1, radius=1)
        assert plan.n_shards == 1 and plan.notes
        print("thin-shard raise ok")
    """))


def test_thin_shard_raises_grid_axis_subprocess():
    print(_run("""
        import jax
        from repro.sharding import stencil_grid_sharding
        mesh = jax.make_mesh((2, 2, 2), ("x", "y", "z"))
        try:
            stencil_grid_sharding((16, 4, 16), mesh, axes=("x", "y", "z"),
                                  sweeps=3, radius=1)
        except ValueError as e:
            msg = str(e)
            assert "j-extent 4" in msg and "'y'=2" in msg
        else:
            raise AssertionError("thin grid shard did not raise")
        # size-1 / indivisible axes still fall back with a PlanNote
        plan = stencil_grid_sharding((16, 9, 16), mesh, axes=("x", "y", "z"),
                                     sweeps=1, radius=1)
        assert plan.axes == ("x", None, "z")
        assert plan.n_shards == (2, 1, 2)
        assert any("not divisible" in n.reason for n in plan.notes)
        print("grid thin-shard raise ok")
    """))


def test_grid_plan_spec_and_locals():
    """Pure-planner shape arithmetic on a fabricated mesh via subprocess-free
    checks where possible: the exchange-bytes model is deterministic."""
    # j and k faces grow transitively: k slabs carry j ghosts, i slabs both
    b = exchange_bytes_per_point(4, (2, 1, 1), (8, 8, 16), sweeps=1)
    assert b["j"] == 2 * 1 * 8 * 16 * 4 / (8 * 8 * 16)
    assert b["k"] == 2 * 1 * 8 * (8 + 2) * 4 / (8 * 8 * 16)
    assert b["i"] == 2 * 2 * (8 + 2) * (16 + 2) * 4 / (8 * 8 * 16)
    assert b["total"] == pytest.approx(b["i"] + b["j"] + b["k"])
    # unsharded axes cost nothing; sweeps amortize the deep exchange
    assert exchange_bytes_per_point(4, (0, 0, 0), (8, 8, 16))["total"] == 0
    assert exchange_bytes_per_point(4, (2, 0, 0), (8, 8, 16), sweeps=2)[
        "i"] == exchange_bytes_per_point(4, (2, 0, 0), (8, 8, 16))["i"] / 2
    # var coef ships n_weights coefficient slabs with the field
    assert exchange_bytes_per_point(4, (1, 0, 0), (8, 8, 16), n_weights=3)[
        "i"] == 4 * exchange_bytes_per_point(4, (1, 0, 0), (8, 8, 16))["i"]


def test_multi_axis_needs_explicit_mesh():
    a = jnp.zeros((8, 8, 16), jnp.float32)
    w = jnp.zeros((2, 2, 2), jnp.float32)
    with pytest.raises(ValueError, match="explicit mesh"):
        stencil_sharded(a, w, "stencil27", axes=("x", "y", None))


def test_overlap_rejects_wavefront_mode():
    a = jnp.zeros((8, 8, 16), jnp.float32)
    w = jnp.zeros((3,), jnp.float32)
    with pytest.raises(ValueError, match="overlap"):
        stencil_sharded(a, w, "star13", mode="wavefront", overlap="on")


def test_unknown_overlap_rejected():
    a = jnp.zeros((8, 8, 16), jnp.float32)
    w = jnp.zeros((2, 2, 2), jnp.float32)
    with pytest.raises(ValueError, match="overlap"):
        stencil_sharded(a, w, "stencil27", overlap="maybe")


def test_grid_2x2x2_parity_subprocess():
    """One small end-to-end 3-D grid parity check that runs on every leg
    (the in-process @multidevice matrix below is the thorough version)."""
    print(_run("""
        import jax, numpy as np, jax.numpy as jnp
        assert jax.device_count() == 8, jax.devices()
        from repro.kernels import stencil_apply, stencil_sharded
        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.integers(-4, 5, (8, 8, 16)), jnp.float32)
        w = jnp.asarray(rng.integers(-3, 4, (2, 2, 2)), jnp.float32)
        mesh = jax.make_mesh((2, 2, 2), ("x", "y", "z"))
        ref = stencil_apply(a, w, "stencil27", sweeps=2)
        for overlap in ("off", "on"):
            got = stencil_sharded(a, w, "stencil27", mesh=mesh,
                                  axes=("x", "y", "z"), sweeps=2,
                                  overlap=overlap)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        print("grid 2x2x2 ok")
    """))


# ---------------------------------------------------------------------------
# in-process 8-device matrix (the dedicated multidevice CI leg)
# ---------------------------------------------------------------------------

@multidevice
@pytest.mark.parametrize("bc", [None, "periodic"])
@pytest.mark.parametrize("name,shape", [("stencil27", (8, 8, 16)),
                                        ("star13", (16, 16, 16))])
@pytest.mark.parametrize("path", ["stream", "replicate"])
@pytest.mark.parametrize("overlap", ["off", "on"])
def test_grid_3d_bitexact_vs_oracle(bc, name, shape, path, overlap):
    """Corner/edge ghost correctness: a (2,2,2)-sharded run is bit-exact vs
    the single-device oracle on integer data -- BC x radius {1 (the
    diagonal-heavy stencil27, where wrong corners change the answer),
    2 (star13)} x path x overlap."""
    a = _field(shape, seed=7)
    w = _weights(name, seed=8)
    mesh = jax.make_mesh((2, 2, 2), ("x", "y", "z"))
    ref = stencil_apply(a, w, name, bc=bc, sweeps=2)
    got = stencil_sharded(a, w, name, mesh=mesh, axes=("x", "y", "z"),
                          bc=bc, sweeps=2, path=path, overlap=overlap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multidevice
@pytest.mark.parametrize("overlap", ["off", "on"])
def test_grid_4x2_redblack(overlap):
    """A 4x2 (i, j) grid with the red-black ordering: sweep_apps == 2
    doubles every axis's deep halo and the global checkerboard parity must
    stay aligned across both kinds of shard seams."""
    a = _field((16, 8, 16), seed=9)
    w = _weights("stencil7", seed=10)
    mesh = jax.make_mesh((4, 2), ("x", "y"))
    ref = stencil_apply(a, w, "stencil7_redblack", sweeps=2)
    got = stencil_sharded(a, w, "stencil7_redblack", mesh=mesh,
                          axes=("x", "y", None), sweeps=2, overlap=overlap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multidevice
@pytest.mark.parametrize("overlap", ["off", "on"])
def test_grid_var_coef(overlap):
    """Variable-coefficient planes ride the same per-axis exchanges as the
    field (the strip kernel consumes a pre-extended coefficient strip)."""
    spec = get_stencil("stencil27").with_coef("var")
    a = _field((8, 8, 16), seed=11)
    rng = np.random.default_rng(12)
    w = jnp.asarray(rng.integers(-3, 4, (spec.n_weights, 8, 8, 16)),
                    jnp.float32)
    mesh = jax.make_mesh((2, 2, 2), ("x", "y", "z"))
    ref = stencil_apply(a, w, spec, sweeps=2)
    got = stencil_sharded(a, w, spec, mesh=mesh, axes=("x", "y", "z"),
                          sweeps=2, overlap=overlap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multidevice
@pytest.mark.parametrize("bc", [None, "periodic"])
def test_grid_wavefront_mode(bc):
    """The temporal-wavefront pipeline on a 3-D grid: the serialized
    multi-axis deep-halo exchange feeds the pipeline's pre-extended slab."""
    a = _field((16, 24, 16), seed=13)
    w = _weights("star13", seed=14)
    mesh = jax.make_mesh((2, 2, 2), ("x", "y", "z"))
    ref = stencil_apply(a, w, "star13", bc=bc, sweeps=3)
    got = stencil_sharded(a, w, "star13", mesh=mesh, axes=("x", "y", "z"),
                          bc=bc, sweeps=3, mode="wavefront")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multidevice
def test_grid_batched_and_neumann():
    a = _field((2, 16, 8, 16), seed=15)
    w = _weights("stencil27", seed=16)
    mesh = jax.make_mesh((4, 2), ("x", "y"))
    ref = stencil_apply(a, w, "stencil27", bc="neumann", sweeps=2)
    for overlap in ("off", "on"):
        got = stencil_sharded(a, w, "stencil27", mesh=mesh,
                              axes=("x", "y", None), bc="neumann", sweeps=2,
                              overlap=overlap)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multidevice
def test_grid_block_j_rejected_when_j_sharded():
    a = _field((8, 8, 16), seed=17)
    w = _weights("stencil27", seed=18)
    mesh = jax.make_mesh((2, 2, 2), ("x", "y", "z"))
    with pytest.raises(ValueError, match="block_j"):
        stencil_sharded(a, w, "stencil27", mesh=mesh, axes=("x", "y", "z"),
                        block_j=4)


@multidevice
def test_grid_overlap_quietly_serializes_when_i_unsharded():
    """overlap='on' with an unsharded i axis has nothing to hide -- the
    call still runs (serialized) and stays exact."""
    a = _field((8, 8, 16), seed=19)
    w = _weights("stencil27", seed=20)
    mesh = jax.make_mesh((2, 2), ("y", "z"))
    ref = stencil_apply(a, w, "stencil27", sweeps=2)
    got = stencil_sharded(a, w, "stencil27", mesh=mesh,
                          axes=(None, "y", "z"), sweeps=2, overlap="on")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multidevice
def test_grid_corrupt_halo_per_axis_detected_and_recovered():
    """CorruptHalo with an axes filter hits exactly one face's exchange;
    the guard detects it and the ladder recovers off the sharded path."""
    from repro.kernels.stencil_engine import CorruptHalo, inject
    from repro.kernels.stencil_engine import last_guard_report
    a = _field((8, 8, 16), seed=21)
    w = jnp.asarray(np.random.default_rng(22).integers(1, 4, (2, 2, 2)),
                    jnp.float32)
    mesh = jax.make_mesh((2, 2, 2), ("x", "y", "z"))
    ref = stencil_apply(a, w, "stencil27", sweeps=2)
    for axis in ("i", "j", "k"):
        with inject(CorruptHalo(seed=3, mode="garbage", axes=(axis,))):
            got = stencil_sharded(a, w, "stencil27", mesh=mesh,
                                  axes=("x", "y", "z"), sweeps=2,
                                  guard="full")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        rep = last_guard_report().describe()["guard"]
        assert rep["demotions"], (axis, rep)


@multidevice
def test_grid_corrupt_unsharded_axis_is_harmless():
    """A fault filtered to an axis that never exchanges cannot fire: the
    sharded run stays clean with no guard at all."""
    from repro.kernels.stencil_engine import CorruptHalo, inject
    a = _field((16, 8, 16), seed=23)
    w = _weights("stencil27", seed=24)
    mesh = jax.make_mesh((4, 2), ("x", "y"))
    ref = stencil_apply(a, w, "stencil27", sweeps=2)
    with inject(CorruptHalo(seed=3, mode="nan", axes=("k",))):
        got = stencil_sharded(a, w, "stencil27", mesh=mesh,
                              axes=("x", "y", None), sweeps=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
