"""Functional + timing semantics of the PPC450 simulator."""

import numpy as np
import pytest

from repro.core.isa import (fxcpmadd, fxcpmul, fxcpxmadd, fxcsmadd, fxcsmul,
                            fxcsxmadd, fsmr_p, fsmr_s, lfdx, lfpdx, lfsdx,
                            stfpdx)
from repro.core.simulator import Machine, MemoryModel, simulate_inorder


@pytest.fixture
def m():
    mach = Machine(mem_words=256)
    mach.gpr["g_a"] = 0
    mach.gpr["g_r"] = 512
    mach.mem[:8] = np.arange(1.0, 9.0)
    mach.fpr["f_w"] = (2.0, 3.0)
    return mach


def test_quad_load_and_mutates(m):
    m.execute([lfpdx("f_x", "g_a", 16)])
    assert m.fpr["f_x"] == (3.0, 4.0)
    m.execute([lfdx("f_x", "g_a", 32)])
    assert m.fpr["f_x"] == (5.0, 4.0)
    m.execute([lfsdx("f_x", "g_a", 40)])
    assert m.fpr["f_x"] == (5.0, 6.0)


def test_misaligned_quad_raises(m):
    with pytest.raises(ValueError):
        m.execute([lfpdx("f_x", "g_a", 8)])


def test_fpu_semantics(m):
    m.fpr["f_c"] = (10.0, 100.0)
    cases = {
        "fxcpmul": (20.0, 200.0),        # w.p * c
        "fxcsmul": (30.0, 300.0),        # w.s * c
        "fxcpxmadd": (2.0 * 100 + 1, 2.0 * 10 + 1),
        "fxcsxmadd": (3.0 * 100 + 1, 3.0 * 10 + 1),
        "fxcpmadd": (2.0 * 10 + 1, 2.0 * 100 + 1),
        "fxcsmadd": (3.0 * 10 + 1, 3.0 * 100 + 1),
    }
    builders = {"fxcpmul": fxcpmul, "fxcsmul": fxcsmul,
                "fxcpxmadd": fxcpxmadd, "fxcsxmadd": fxcsxmadd,
                "fxcpmadd": fxcpmadd, "fxcsmadd": fxcsmadd}
    for mn, expect in cases.items():
        m.fpr["f_t"] = (1.0, 1.0)
        m.execute([builders[mn]("f_t", "f_w", "f_c")])
        assert m.fpr["f_t"] == expect, mn


def test_half_copies(m):
    m.fpr["f_a"] = (7.0, 8.0)
    m.fpr["f_t"] = (1.0, 2.0)
    m.execute([fsmr_p("f_t", "f_a")])
    assert m.fpr["f_t"] == (7.0, 2.0)
    m.execute([fsmr_s("f_t", "f_a")])
    assert m.fpr["f_t"] == (7.0, 8.0)


def test_store_roundtrip(m):
    m.fpr["f_v"] = (41.0, 42.0)
    m.execute([stfpdx("f_v", "g_r", 16)])
    assert m.mem[66] == 41.0 and m.mem[67] == 42.0


def test_inorder_chain_latency():
    """A chain of dependent FMAs must run at 5 cycles/op."""
    body = [fxcpmadd("f_t", "f_w", "f_t") for _ in range(10)]
    t = simulate_inorder(body, n_iters=1)
    assert t.total_cycles >= 5 * 10


def test_inorder_independent_fpu_throughput():
    """Independent FPU ops issue one per cycle."""
    body = [fxcpmul(f"f_t{i}", "f_w", "f_c") for i in range(10)]
    t = simulate_inorder(body, n_iters=6)
    assert t.per_iter_cycles <= 11


def test_lsu_every_other_cycle():
    body = [lfpdx(f"f_x{i}", "g_a", 16 * i) for i in range(8)]
    t = simulate_inorder(body, n_iters=6)
    assert 15 <= t.per_iter_cycles <= 17


def test_memory_model_stream_latencies():
    mm = MemoryModel(level="L3", max_streams=2)
    # first touch of a line: miss; sequential next lines: prefetched
    lat0 = mm.load_latency(0)
    lat_seq = mm.load_latency(32)
    assert lat0 > lat_seq
    # same line again: L1 hit
    assert mm.load_latency(0) == 4
