"""Optimizers, data pipeline, checkpointing, compression, train loop."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.compression import compress_decompress, init_error_feedback
from repro.configs import get_reduced_config
from repro.data import SyntheticDataset
from repro.models import build_model
from repro.models.common import SHAPES, ShapeConfig
from repro.optim import adafactor, adamw, clip_by_global_norm, warmup_cosine
from repro.runtime import StepTimer, TrainConfig, Trainer, make_train_step
from repro.runtime.loop import init_train_state

KEY = jax.random.PRNGKey(0)


def _quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray([[1.0, 2.0],
                                                             [3.0, 4.0]])}


@pytest.mark.parametrize("make_opt", [adamw, adafactor])
def test_optimizers_descend(make_opt):
    opt = make_opt()
    params = _quadratic_params()
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = loss(params)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.asarray(0.05))
    assert loss(params) < 0.5 * l0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    got = float(jnp.linalg.norm(clipped["a"]))
    assert abs(got - 1.0) < 1e-4


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < float(lr(50)) < float(lr(10)) + 1e-9
    assert abs(float(lr(100)) - 1e-4) < 1e-6


def test_dataset_deterministic_and_sharded():
    cfg = get_reduced_config("qwen1.5-0.5b")
    shp = ShapeConfig("t", 64, 8, "train")
    ds = SyntheticDataset(cfg, shp, seed=1)
    b1 = ds.global_batch(3)
    b2 = ds.global_batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # next-token labels
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    h0 = ds.host_batch(3, 0, 2)
    assert h0["tokens"].shape[0] == 4
    b5 = ds.global_batch(5)
    assert not np.array_equal(b1["tokens"], b5["tokens"])


def test_checkpoint_roundtrip_and_keep(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    for s in (10, 20, 30):
        mgr.save(s, jax.tree.map(lambda t: t * s, tree), meta={"step": s})
    assert mgr.latest_step() == 30
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 30
    np.testing.assert_allclose(np.asarray(restored["a"], np.float32),
                               np.asarray(tree["a"]) * 30)
    assert restored["nested"]["b"].dtype == jnp.bfloat16
    # keep=2 garbage-collected step 10
    assert not os.path.exists(os.path.join(str(tmp_path), "step_00000010"))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.ones((3, 3))})


def test_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(512), jnp.float32) * 1e-3
    ef = jnp.zeros_like(g)
    total_deq = jnp.zeros_like(g)
    for _ in range(64):
        q, scale, ef = compress_decompress(g, ef)
        total_deq = total_deq + q.astype(jnp.float32) * scale
    # time-averaged dequantized signal converges to the true gradient
    np.testing.assert_allclose(np.asarray(total_deq / 64), np.asarray(g),
                               atol=5e-5)


def test_trainer_end_to_end_with_restart(tmp_path):
    cfg = dataclasses.replace(get_reduced_config("qwen1.5-0.5b"),
                              dtype=jnp.float32)
    model = build_model(cfg)
    ds = SyntheticDataset(cfg, ShapeConfig("t", 32, 4, "train"), seed=0)
    tc = TrainConfig(steps=6, ckpt_every=3, ckpt_dir=str(tmp_path), keep=2,
                     log_every=1)
    tr = Trainer(model, adamw(), warmup_cosine(1e-3, 2, 6), tc, ds)
    state = tr.run(KEY)
    assert int(state["step"]) == 6
    losses = [m["loss"] for m in tr.metrics_log]
    assert losses[-1] < losses[0]          # it learns the synthetic stream
    # simulate failure + restart: a fresh Trainer resumes from step 3 or 6
    tr2 = Trainer(model, adamw(), warmup_cosine(1e-3, 2, 6), tc, ds)
    state2 = tr2.run(KEY)
    assert int(state2["step"]) == 6


def test_gradient_accumulation_matches_full_batch():
    cfg = dataclasses.replace(get_reduced_config("qwen1.5-0.5b"),
                              dtype=jnp.float32)
    model = build_model(cfg)
    from repro.optim import adamw as mk
    state = init_train_state(model, mk(), KEY)
    ds = SyntheticDataset(cfg, ShapeConfig("t", 32, 4, "train"), seed=0)
    batch = jax.tree.map(jnp.asarray, ds.global_batch(0))
    lr = lambda s: jnp.asarray(1e-3)
    s1 = make_train_step(model, mk(), lr, TrainConfig(accum=1))
    s2 = make_train_step(model, mk(), lr, TrainConfig(accum=2))
    st1, m1 = jax.jit(s1)(state, batch)
    st2, m2 = jax.jit(s2)(state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    p1 = jax.tree.leaves(st1["params"])[0]
    p2 = jax.tree.leaves(st2["params"])[0]
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=2e-3, atol=2e-4)


def test_straggler_detector():
    import time
    hits = []
    t = StepTimer(window=16, threshold=1.5,
                  on_straggler=lambda s, dt, med: hits.append(s))
    for i in range(10):
        t.start()
        time.sleep(0.002)
        t.stop(i)
    t.start()
    time.sleep(0.05)
    t.stop(99)
    assert 99 in t.flagged and hits == [99]


def test_step_timer_stop_before_start_raises():
    t = StepTimer()
    with pytest.raises(RuntimeError, match="before start"):
        t.stop(0)
    t.start()
    t.stop(1)                       # a completed step consumes the start()
    with pytest.raises(RuntimeError, match="before start"):
        t.stop(2)
