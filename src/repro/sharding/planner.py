"""Partition planner: maps parameter/batch/cache pytrees to PartitionSpecs.

Megatron-style tensor parallelism falls out of a largest-divisible-dim
heuristic (column-parallel in-projections, row-parallel out-projections,
vocab-sharded embeddings); expert weights prefer the expert dim (EP,
arctic-480b 128e) and fall back to d_ff TP when the expert count doesn't
divide the axis (mixtral 8e on a 16-way axis).  ``fsdp=True`` additionally
shards a second dim over the data axis (ZeRO-3; with scan-over-layers GSPMD
inserts the per-layer all-gather inside the loop).  Every fallback decision
is recorded as a PlanNote so the dry-run log shows exactly what sharded and
what replicated -- the paper's Table-2 discipline applied to partitioning.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

if TYPE_CHECKING:   # annotation-only; a runtime import would close the
    # planner -> models -> kernels -> stencil_engine.sharded -> planner cycle
    from ..models.common import ArchConfig, ShapeConfig


@dataclasses.dataclass
class PlanNote:
    path: str
    shape: Tuple[int, ...]
    spec: Any
    reason: str


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _mesh_axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def param_sharding(cfg: ArchConfig, params_shapes: Any, mesh: Mesh,
                   fsdp: bool = False
                   ) -> Tuple[Any, List[PlanNote]]:
    """Assign a NamedSharding to every parameter leaf.

    ``params_shapes``: pytree of ShapeDtypeStruct (from jax.eval_shape).
    """
    tp = mesh.shape["model"]
    dp_axis = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dp = _mesh_axis_size(mesh, tuple(dp_axis))
    notes: List[PlanNote] = []
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        name = _path_str(path)
        shape = tuple(leaf.shape)
        stacked = "layers" in name        # leading scan axis: never shard
        start = 1 if stacked and len(shape) > 1 else 0
        spec: List[Any] = [None] * len(shape)
        reason = "replicated"

        # Megatron-correct dim preference by parameter role: in-projections
        # shard the OUTPUT features (column-parallel), out-projections the
        # INPUT/contraction dim (row-parallel: one activation psum per block
        # instead of per-matmul re-gathers -- EXPERIMENTS.md Perf arctic-H1),
        # embeddings the vocab dim.  Fallback: remaining dims by size.
        from ..flags import flag
        leaf_name = name.rsplit("/", 1)[-1]
        parent = name.split("/")[-2] if "/" in name else ""
        role_row = (flag("megatron_row_parallel")
                    and (parent in ("wo", "out_proj") or leaf_name in ("wo",)))
        role_embed = "embed" in leaf_name or "pos_enc" in leaf_name
        by_size = sorted(range(start, len(shape)), key=lambda i: -shape[i])
        if not flag("megatron_sharding"):
            role_row = role_embed = False
            dims = by_size
        elif role_embed and len(shape) >= 2:
            dims = [start] + [i for i in by_size if i != start]
        elif role_row and len(shape) - start >= 2:
            dims = [len(shape) - 2] + [i for i in by_size
                                       if i != len(shape) - 2]
        elif (len(shape) - start >= 2
              and shape[-1] * 4 >= max(shape[start:])):
            # column-parallel only when the output dim is substantial;
            # sharding a narrow projection head (falcon x_proj: 288 wide)
            # forces per-use re-gathers of everything downstream
            dims = [len(shape) - 1] + [i for i in by_size
                                       if i != len(shape) - 1]
        else:
            dims = by_size
        # Expert weights: prefer expert-parallel over the model axis.
        is_expert = (cfg.n_experts > 0 and len(shape) - start == 3
                     and shape[start] == cfg.n_experts)
        if is_expert and cfg.n_experts % tp == 0:
            spec[start] = "model"
            reason = "expert-parallel (EP)"
        else:
            if is_expert:
                notes.append(PlanNote(
                    name, shape, None,
                    f"EP fallback: {cfg.n_experts} experts not divisible by "
                    f"model={tp}; using d_ff TP"))
            for i in dims:
                if is_expert and i == start:
                    continue
                if shape[i] >= tp and shape[i] % tp == 0:
                    spec[i] = "model"
                    reason = f"TP on dim {i}" + (
                        " (row-parallel)" if role_row and
                        i == len(shape) - 2 else "")
                    break
        if fsdp and len(shape) > 1:
            for i in dims:
                if spec[i] is None and shape[i] >= dp and shape[i] % dp == 0:
                    spec[i] = tuple(dp_axis) if len(dp_axis) > 1 else dp_axis[0]
                    reason += f" + FSDP on dim {i}"
                    break
        notes.append(PlanNote(name, shape, tuple(spec), reason))
        specs.append(NamedSharding(mesh, P(*spec)))
    return jax.tree.unflatten(treedef, specs), notes


def batch_sharding(shape_cfg: ShapeConfig, batch_specs: Dict, mesh: Mesh
                   ) -> Dict[str, NamedSharding]:
    """Batch rows over (pod, data); falls back to sequence sharding (SP)
    when the batch doesn't cover the axis (long-context, batch=1)."""
    dp_axis = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dp = _mesh_axis_size(mesh, tuple(dp_axis))
    out = {}
    b_axis = tuple(dp_axis) if len(dp_axis) > 1 else dp_axis[0]
    for k, v in batch_specs.items():
        if v.shape[0] % dp == 0 and v.shape[0] >= dp:
            out[k] = NamedSharding(mesh, P(b_axis, *([None] * (v.ndim - 1))))
        elif v.ndim > 1 and v.shape[1] % dp == 0:
            out[k] = NamedSharding(mesh, P(None, b_axis,
                                           *([None] * (v.ndim - 2))))
        else:
            out[k] = NamedSharding(mesh, P(*([None] * v.ndim)))
    return out


def decode_state_sharding(cfg: ArchConfig, state_shapes: Any, mesh: Mesh
                          ) -> Any:
    """KV caches / SSM states: batch over data when divisible, else sequence
    (SP for the 500k-context cells); heads or feature dims over model."""
    dp_axis = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dp = _mesh_axis_size(mesh, tuple(dp_axis))
    tp = mesh.shape["model"]
    b_axis = tuple(dp_axis) if len(dp_axis) > 1 else dp_axis[0]
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shapes)
    specs = []
    for path, leaf in flat:
        shape = tuple(leaf.shape)
        spec: List[Any] = [None] * len(shape)
        # dim 0 is the stacked layer/application axis for caches & states
        used_data = False
        for i in range(1, len(shape)):
            if not used_data and shape[i] >= dp and shape[i] % dp == 0:
                spec[i] = b_axis
                used_data = True
                continue
            if shape[i] >= tp and shape[i] % tp == 0:
                spec[i] = "model"
                break
        specs.append(NamedSharding(mesh, P(*spec)))
    return jax.tree.unflatten(treedef, specs)


@dataclasses.dataclass
class StencilShardPlan:
    """How to split a stencil grid's i-axis over a mesh axis.

    ``n_shards == 1`` means "don't shard" (indivisible M or shards too thin
    for the halo) -- callers fall back to single-device execution; the
    reason is recorded as a PlanNote, Table-2 style.  ``periodic`` turns
    the halo exchange into a ring: shard 0's low halo wraps around from
    shard ``n-1`` (and vice versa) instead of arriving as zeros."""
    axis: str
    n_shards: int
    halo: int                 # rows exchanged per side == radius * sweeps
    local_rows: int
    spec: Any                 # PartitionSpec for a (B, M, N, P) operand
    notes: List[PlanNote]
    periodic: bool = False    # i-axis BC is periodic: ring, not chain


def stencil_halo_sharding(m: int, mesh: Mesh, axis: str = "data",
                          sweeps: int = 1, radius: int = 1,
                          periodic: bool = False) -> StencilShardPlan:
    """Plan i-axis halo-exchange sharding for an (..., M, N, P) stencil grid.

    Each shard owns ``M / n`` contiguous i-rows and exchanges ``radius *
    sweeps`` halo rows with each neighbour per fused call (a radius-R
    operator applied ``sweeps`` times needs ``R`` rows per sweep).
    ``periodic=True`` (the i-axis boundary condition is periodic) closes
    the exchange into a ring with wrap-around links between shard 0 and
    shard ``n - 1``; non-periodic edge BCs never travel -- dirichlet /
    neumann ghosts materialize only on the boundary shards, from the
    kernel's global-geometry fill.  Falls back to an unsharded plan -- with
    the reason noted -- when M doesn't divide or local rows couldn't cover
    the halo."""
    n = _mesh_axis_size(mesh, axis)
    halo = radius * sweeps
    notes: List[PlanNote] = []

    def fallback(reason: str) -> StencilShardPlan:
        notes.append(PlanNote("stencil/i-axis", (m,), None, reason))
        return StencilShardPlan(axis, 1, halo, m, P(None, None, None, None),
                                notes, periodic)

    if n <= 1:
        return fallback(f"axis {axis!r} has size {n}; running unsharded")
    if m % n != 0:
        return fallback(f"M={m} not divisible by {axis}={n}; replicating")
    local = m // n
    if local < halo:
        # Too-thin shards are a configuration error, not a graceful
        # degradation: the deep-halo exchange would need rows the owning
        # shard does not hold, so silently replicating here used to hide a
        # mesh that can never shard this problem.
        raise ValueError(
            f"stencil_halo_sharding: M={m} over mesh axis {axis!r}={n} "
            f"leaves {local} local rows/shard, fewer than the "
            f"{halo}-row halo (radius {radius} x sweeps {sweeps}); "
            f"need M // n_shards >= radius * sweeps -- use a smaller "
            f"mesh axis, a larger M, or fewer fused sweeps")
    topo = ("ring (periodic wrap between shard 0 and shard "
            f"{n - 1})" if periodic else
            "chain (edge shards take boundary ghosts locally)")
    notes.append(PlanNote(
        "stencil/i-axis", (m,), P(None, axis, None, None),
        f"i-axis split {n} ways x {local} rows, halo {halo}/side "
        f"(radius {radius} x sweeps {sweeps}), {topo}"))
    return StencilShardPlan(axis, n, halo, local,
                            P(None, axis, None, None), notes, periodic)


@dataclasses.dataclass
class StencilGridPlan:
    """How to split a stencil grid over an (pi, pj, pk) process grid.

    One entry per domain axis (i, j, k): ``axes[d]`` is the mesh axis that
    shards domain axis ``d`` (``None`` = that axis stays whole), and the
    per-axis ``n_shards`` / ``halo`` / ``local`` describe its slab.  An
    axis whose mesh axis has size 1 or whose extent does not divide falls
    back to unsharded with a PlanNote; a shard too thin to cover its own
    halo *raises* (same contract as :func:`stencil_halo_sharding`).
    ``spec`` is the combined ``P(None, ai, aj, ak)`` for a ``(B, M, N, P)``
    operand.  ``periodic[d]`` closes axis ``d``'s exchange into a ring."""
    axes: Tuple[Optional[str], Optional[str], Optional[str]]
    n_shards: Tuple[int, int, int]
    halo: Tuple[int, int, int]
    local: Tuple[int, int, int]
    spec: Any
    notes: List[PlanNote]
    periodic: Tuple[bool, bool, bool] = (False, False, False)

    @property
    def total_shards(self) -> int:
        return int(np.prod(self.n_shards))


def stencil_grid_sharding(shape: Tuple[int, int, int], mesh: Mesh,
                          axes=("data", None, None), sweeps: int = 1,
                          radius=(1, 1, 1),
                          periodic=(False, False, False)) -> StencilGridPlan:
    """Plan multi-axis halo-exchange sharding for an (..., M, N, P) grid.

    ``axes`` names the mesh axis carrying each domain axis (i, j, k) --
    ``None`` leaves that axis whole.  Per sharded axis the shard owns
    ``extent / n`` contiguous planes and exchanges ``radius * sweeps``
    ghost planes per side (callers fold ``sweep_apps`` into ``sweeps``,
    as with :func:`stencil_halo_sharding`).  Corner/edge ghosts need no
    diagonal sends: the executor exchanges one axis at a time on the
    progressively extended slab (j, then k, then i), so each later
    exchange carries the earlier axes' ghost columns and the diagonal
    data arrives transitively.  A per-axis ``periodic`` entry closes that
    axis's exchange into a ring.  Indivisible extents and size-1 mesh
    axes fall back (PlanNote'd) to unsharded on that axis; a shard
    thinner than its own halo raises with the shapes in the message."""
    if isinstance(radius, int):
        radius = (radius, radius, radius)
    if len(shape) != 3 or len(axes) != 3:
        raise ValueError(f"stencil_grid_sharding needs a 3-axis shape and "
                         f"axes triple, got shape={shape}, axes={axes}")
    names = ("i", "j", "k")
    out_axes: List[Optional[str]] = []
    n_shards: List[int] = []
    halos: List[int] = []
    local: List[int] = []
    notes: List[PlanNote] = []
    for d in range(3):
        ext, ax = int(shape[d]), axes[d]
        halo = radius[d] * sweeps
        n = _mesh_axis_size(mesh, ax) if ax is not None else 1

        def keep_whole(reason: str) -> None:
            notes.append(PlanNote(f"stencil/{names[d]}-axis", (ext,), None,
                                  reason))
            out_axes.append(None)
            n_shards.append(1)
            halos.append(halo)
            local.append(ext)

        if ax is None:
            out_axes.append(None)
            n_shards.append(1)
            halos.append(halo)
            local.append(ext)
            continue
        if n <= 1:
            keep_whole(f"axis {ax!r} has size {n}; {names[d]} unsharded")
            continue
        if ext % n != 0:
            keep_whole(f"{names[d]}-extent {ext} not divisible by "
                       f"{ax}={n}; replicating along {names[d]}")
            continue
        loc = ext // n
        if loc < halo:
            raise ValueError(
                f"stencil_grid_sharding: {names[d]}-extent {ext} over mesh "
                f"axis {ax!r}={n} leaves {loc} local planes/shard, fewer "
                f"than the {halo}-plane halo (radius {radius[d]} x sweeps "
                f"{sweeps}); need extent // n_shards >= radius * sweeps")
        topo = (f"ring (periodic wrap between shard 0 and shard {n - 1})"
                if periodic[d] else
                "chain (edge shards take boundary ghosts locally)")
        notes.append(PlanNote(
            f"stencil/{names[d]}-axis", (ext,), ax,
            f"{names[d]}-axis split {n} ways x {loc} planes, halo "
            f"{halo}/side (radius {radius[d]} x sweeps {sweeps}), {topo}"))
        out_axes.append(ax)
        n_shards.append(n)
        halos.append(halo)
        local.append(loc)
    part = P(None, *out_axes)
    return StencilGridPlan(tuple(out_axes), tuple(n_shards), tuple(halos),
                           tuple(local), part, notes, tuple(periodic))


def plan_summary(notes: List[PlanNote], max_rows: int = 12) -> str:
    n_rep = sum(1 for n in notes if n.spec is not None
                and all(s is None for s in n.spec))
    n_tp = sum(1 for n in notes if n.spec is not None and "model" in
               [s for s in n.spec if not isinstance(s, tuple)])
    lines = [f"plan: {len(notes)} leaves, {n_tp} model-sharded, "
             f"{n_rep} replicated"]
    for n in notes[:max_rows]:
        lines.append(f"  {n.path:50s} {str(n.shape):28s} -> {n.spec} "
                     f"[{n.reason}]")
    if len(notes) > max_rows:
        lines.append(f"  ... {len(notes) - max_rows} more")
    return "\n".join(lines)
