"""Mesh context for in-model sharding hints.

Model code stays mesh-agnostic; the launcher installs the active mesh here
and layers call ``shard_hint(x, "model", None, ...)`` at GSPMD-propagation
choke points (fresh scatter buffers in the MoE dispatch, notably, which
otherwise replicate).  Hints are dropped when no mesh is installed (unit
tests) or when the dim isn't divisible by the named axis.
"""

from __future__ import annotations

import contextlib
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def current_mesh() -> Optional[Mesh]:
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def shard_hint(x: jax.Array, *spec: Any) -> jax.Array:
    """with_sharding_constraint(x, P(*spec)) if a mesh is installed and every
    named dim divides; silently drops undivisible/unknown axes."""
    mesh = _MESH
    if mesh is None:
        return x
    cleaned = []
    for i, a in enumerate(spec):
        if a is None:
            cleaned.append(None)
            continue
        names = tuple(n for n in (a if isinstance(a, tuple) else (a,))
                      if n in mesh.axis_names)
        if not names:
            cleaned.append(None)
            continue
        a = names if len(names) > 1 else names[0]
        if i < x.ndim and x.shape[i] % _axis_size(mesh, a) == 0 \
                and x.shape[i] >= _axis_size(mesh, a):
            cleaned.append(a)
        else:
            cleaned.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*cleaned)))
