from .planner import (PlanNote, batch_sharding, decode_state_sharding,  # noqa: F401
                      param_sharding, plan_summary)
