from .planner import (PlanNote, StencilGridPlan, StencilShardPlan,  # noqa: F401
                      batch_sharding, decode_state_sharding, param_sharding,
                      plan_summary, stencil_grid_sharding,
                      stencil_halo_sharding)
