"""nemotron-4-15b [dense]: GQA + squared-ReLU MLP, huge vocab.

[arXiv:2402.16819; unverified].  32L d=6144 48H (GQA kv=8) d_ff=24576
vocab=256000.  Full attention => long_500k skipped.
"""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=24576, vocab_size=256000,
    activation="sq_relu", rope_theta=1e4,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512)
