"""arctic-480b [moe]: 128 experts top-2 alongside a dense residual MLP.

[hf:Snowflake/snowflake-arctic-base; hf].  35L d=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000.  Dense-MoE hybrid: every layer adds a dense residual
MLP in parallel with the routed experts (residual_d_ff documented as 4864,
matching the expert width, where the card is silent).  Full attention =>
long_500k skipped.
"""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168, n_heads=56,
    n_kv_heads=8, d_ff=4864, vocab_size=32000, n_experts=128, top_k=2,
    moe_dense_residual=True, residual_d_ff=4864, activation="swiglu",
    rope_theta=1e6, capacity_factor=1.25,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=512, n_experts=8, top_k=2, residual_d_ff=96)
