"""zamba2-7b [hybrid]: Mamba-2 trunk + shared attention block every 6 layers.

[arXiv:2411.15242; unverified].  81L d=3584 32H (GQA kv=32 => MHA)
d_ff=14336, ssm_state=64.  d_inner = 2*d_model = 7168, Mamba-2 head dim 64.
The shared attn+MLP block re-uses ONE parameter set across applications
(Zamba's parameter sharing).  Sub-quadratic per-token decode => runs
long_500k.
"""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584, n_heads=32,
    n_kv_heads=32, d_ff=14336, vocab_size=32000, ssm_state=64, ssm_conv=4,
    d_inner=7168, ssm_kind="mamba2", ssm_head_dim=64, attn_every=6,
    activation="swiglu", rope_theta=1e4,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, ssm_state=8, d_inner=128, ssm_head_dim=16,
        attn_every=2)
