"""Architecture registry: the 10 assigned configs + the paper's stencils."""

from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.common import ArchConfig

ARCH_IDS: List[str] = [
    "internvl2-2b",
    "mixtral-8x7b",
    "arctic-480b",
    "zamba2-7b",
    "falcon-mamba-7b",
    "starcoder2-7b",
    "nemotron-4-15b",
    "qwen2-0.5b",
    "qwen1.5-0.5b",
    "seamless-m4t-large-v2",
]

_cache: Dict[str, ArchConfig] = {}
_rcache: Dict[str, ArchConfig] = {}


def _module(arch_id: str):
    return importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _cache:
        _cache[arch_id] = _module(arch_id).CONFIG
    return _cache[arch_id]


def get_reduced_config(arch_id: str) -> ArchConfig:
    """Smoke-test config: same family/topology, tiny dims."""
    if arch_id not in _rcache:
        _rcache[arch_id] = _module(arch_id).reduced()
    return _rcache[arch_id]
