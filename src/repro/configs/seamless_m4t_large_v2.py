"""seamless-m4t-large-v2 [audio]: encoder-decoder transformer backbone.

[arXiv:2308.11596; hf].  24L d=1024 16H (kv=16) d_ff=8192 vocab=256206.
Backbone only per the assignment: the speech frontend is a stub supplying
precomputed fbank frame embeddings (dim 160).  24 encoder + 24 decoder
layers; learned absolute positions (documented simplification).  Full
attention, encoder-decoder => long_500k skipped; decode shapes run the
decoder with a 32k self-attention cache + fixed-length cross attention.
"""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=48, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab_size=256206,
    enc_layers=24, dec_layers=24, activation="gelu", use_rope=False,
    frontend="frames", frontend_dim=160,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, enc_layers=2, dec_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512, frontend_dim=16)
