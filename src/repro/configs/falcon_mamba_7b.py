"""falcon-mamba-7b [ssm]: attention-free Mamba-1 LM.

[arXiv:2410.05355; unverified].  64L d=4096 vocab=65024, ssm_state=16,
d_inner = 2*d_model = 8192, conv kernel 4.  The fullest application of the
paper's streaming-kernel technique (DESIGN.md sect. 5); O(1) decode state =>
runs long_500k.
"""

import dataclasses

from repro.models.common import ArchConfig

import jax.numpy as jnp

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=65024, ssm_state=16,
    ssm_conv=4, d_inner=8192, ssm_kind="mamba1",
    # beyond-paper perf: bf16 scan-tensor storage halves the memory-bound
    # (B, Lc, di, N) traffic (EXPERIMENTS.md Perf falcon-H3)
    ssm_scan_dtype=jnp.bfloat16,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, d_inner=128, ssm_state=4,
        vocab_size=512, ssm_scan_dtype=None)
