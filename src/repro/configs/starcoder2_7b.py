"""starcoder2-7b [dense]: GQA + RoPE + sliding-window attention.

[arXiv:2402.19173; hf].  32L d=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
GELU MLP, biases on QKV, SWA window 4096 => runs long_500k.
"""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
    n_heads=36, n_kv_heads=4, d_ff=18432, vocab_size=49152,
    activation="gelu", qkv_bias=True, window=4096, rope_theta=1e5,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, window=16)
