"""qwen1.5-0.5b [dense]: MHA (kv=16), QKV bias, tied embeddings.

[hf:Qwen/Qwen1.5-0.5B; hf].  24L d=1024 16H (kv=16) d_ff=2816 vocab=151936.
"""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=2816, vocab_size=151936,
    activation="swiglu", qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512)
