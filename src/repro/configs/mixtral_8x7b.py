"""mixtral-8x7b [moe]: 8 experts top-2 + sliding-window attention.

[arXiv:2401.04088; hf].  32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
SWA window 4096 => banded (stencil-pattern) attention; runs long_500k.
"""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=14336, vocab_size=32000, n_experts=8, top_k=2,
    window=4096, activation="swiglu", rope_theta=1e6,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, n_experts=4, top_k=2, window=16)
