"""qwen2-0.5b [dense]: GQA kv=2, QKV bias, tied embeddings.

[arXiv:2407.10671; hf].  24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
Note 14 heads does not divide the 16-way model axis: the partition planner
falls back to d_ff/vocab sharding for the attention projections.
"""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b", family="dense", n_layers=24, d_model=896, n_heads=14,
    n_kv_heads=2, d_ff=4864, vocab_size=151936, activation="swiglu",
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, d_ff=128,
        vocab_size=512)
