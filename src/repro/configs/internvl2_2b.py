"""internvl2-2b [vlm]: InternViT frontend stub + InternLM2-1.8B backbone.

[arXiv:2404.16821; hf].  24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
Frontend is a stub per the assignment: precomputed ViT patch embeddings
(InternViT hidden 1024) enter via a linear projection as a 256-token prefix.
"""

import dataclasses

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=8, d_ff=8192, vocab_size=92553, activation="swiglu",
    rope_theta=1e6, frontend="patch", frontend_dim=1024, frontend_len=256,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, frontend_dim=32, frontend_len=8)
