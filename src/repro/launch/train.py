"""End-to-end training driver.

On real hardware this runs the production mesh; on this CPU container it
drives reduced configs (the quickstart / examples path) with the same code:
sharding plan, fault-tolerant loop, checkpointing, straggler monitor.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --reduced --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.data import SyntheticDataset
from repro.models import build_model, param_count
from repro.models.common import ShapeConfig
from repro.optim import build_optimizer, warmup_cosine
from repro.runtime import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--optimizer", default=None,
                    choices=[None, "adamw", "adafactor"])
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    args = ap.parse_args()

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    cfg = dataclasses.replace(cfg, dtype=getattr(jnp, args.dtype))
    model = build_model(cfg)
    opt_name = args.optimizer or (
        "adafactor" if param_count(cfg) > 100e9 else "adamw")
    opt = build_optimizer(opt_name)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    ds = SyntheticDataset(cfg, shape, seed=0)
    tc = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir, accum=args.accum,
                     compress_grads=args.compress_grads, log_every=5)
    lr_fn = warmup_cosine(args.lr, max(2, args.steps // 10), args.steps)
    mesh = None
    if args.compress_grads:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
    trainer = Trainer(model, opt, lr_fn, tc, ds, mesh=mesh)
    print(f"[train] arch={cfg.name} params~{param_count(cfg)/1e6:.1f}M "
          f"opt={opt_name} steps={args.steps}")
    trainer.run(jax.random.PRNGKey(0))
    for m in trainer.metrics_log:
        print(f"[train] step {m['step']:5d} loss {m['loss']:.4f} "
              f"gnorm {m['gnorm']:.3f}")
    if trainer.timer.median:
        print(f"[train] median step time {trainer.timer.median*1e3:.1f} ms; "
              f"stragglers flagged: {trainer.timer.flagged}")


if __name__ == "__main__":
    main()
