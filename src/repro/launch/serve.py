"""Batched serving driver: prefill + decode loop with a KV/SSM cache.

  PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
      --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import build_model


def generate(model, params, prompts: jnp.ndarray, gen: int,
             frontend=None, greedy: bool = True, seed: int = 0):
    """Prefill via repeated decode steps, then sample ``gen`` tokens."""
    b, plen = prompts.shape
    state = model.init_decode_state(params, b, plen + gen, frontend=frontend)
    step = jax.jit(model.decode_step)
    key = jax.random.PRNGKey(seed)
    logits = None
    for i in range(plen):
        logits, state = step(params, state, prompts[:, i:i + 1],
                             jnp.int32(i))
    out = []
    tok = None
    for j in range(gen):
        if greedy:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1])[:, None]
            tok = tok.astype(jnp.int32)
        out.append(tok)
        logits, state = step(params, state, tok, jnp.int32(plen + j))
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size,
                                       (args.batch, args.prompt_len)),
                          jnp.int32)
    frontend = None
    if cfg.family == "encdec":
        frontend = jnp.asarray(rng.standard_normal(
            (args.batch, 64, cfg.frontend_dim)), jnp.float32)
    t0 = time.perf_counter()
    out = generate(model, params, prompts, args.gen, frontend=frontend)
    dt = time.perf_counter() - t0
    tps = args.batch * (args.prompt_len + args.gen) / dt
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"[serve] output tokens:\n{np.asarray(out)}")
    print(f"[serve] {dt:.2f}s total, {tps:.1f} tok/s (CPU interpret)")


if __name__ == "__main__":
    main()
