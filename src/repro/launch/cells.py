"""Cell definitions for the dry-run matrix (import-safe: no env mutation)."""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import make_batch_specs
from repro.models.common import SHAPES


def input_specs(arch: str, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    Weak-type-correct, shardable, no device allocation: the dry-run lowers
    against these.  Training/prefill cells get the token/label/frontend
    batch; decode cells get the one-token request batch (the cache/state
    specs are derived from the model via eval_shape in launch/dryrun.py).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return make_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        specs = make_batch_specs(cfg, shape)
        specs.pop("labels")
        return specs
    return {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1),
                                           jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def skip_reason(arch: str, shape: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return ("full quadratic attention: 500k-token decode infeasible "
                "by design (DESIGN.md sect. 5); arch has no sub-quadratic "
                "path (not SSM/hybrid/SWA)")
    return None
