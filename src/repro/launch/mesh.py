"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A function, not a module-level constant: importing this module never touches
jax device state (device count is locked at first backend init, and tests /
benches must see the single real CPU device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for unit tests on host-platform placeholder devices."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
