"""Trip-count-aware analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts every loop body exactly once
(verified in tests), which under-reports scan-over-layers models by a factor
of n_layers.  This analyzer walks the computation graph from ENTRY,
multiplying through ``known_trip_count`` annotations on while ops, and
accumulates:

* ``flops``      -- 2*M*N*K for every dot (the models' flops are dot-dominated;
                    elementwise flops are counted at 1 per output element);
* ``bytes``      -- an HBM-traffic proxy: result + operand bytes of every
                    top-level op in each computation (fusion internals are
                    VMEM-resident and excluded; parameter/tuple plumbing ops
                    are skipped);
* ``collective_bytes`` / per-kind stats -- result-shape bytes of all-gather /
                    all-reduce / reduce-scatter / all-to-all /
                    collective-permute ops.

All quantities are *per device* (the SPMD module is the per-device program).
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "u64": 8, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|"
    r"c64|c128|s4|u4)\[([0-9,]*)\]")

_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
# first lowercase-token immediately followed by '(' after the type prefix;
# type tokens (f32[..]{..}, tuple parens) are never followed by '('.
_OPCODE_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")

_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "copy", "after-all", "partition-id",
                 "replica-id", "iota"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def normalize_cost_analysis(cost) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    Older JAX returns a single properties dict; newer JAX returns a list of
    per-module dicts (the entry module first, and in practice the only one).
    Either way, callers get one flat ``{property: value}`` dict (empty when
    XLA reports nothing).
    """
    if isinstance(cost, dict):
        return dict(cost)
    if isinstance(cost, (list, tuple)):
        for entry in cost:
            if isinstance(entry, dict):
                return dict(entry)
    return {}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Op:
    __slots__ = ("name", "type_str", "opcode", "rest")

    def __init__(self, name, type_str, opcode, rest):
        self.name = name
        self.type_str = type_str
        self.opcode = opcode
        self.rest = rest


def parse_hlo(text: str) -> Tuple[Dict[str, List[Op]], Optional[str]]:
    comps: Dict[str, List[Op]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        s = line.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", s)
        if header and cur is None:
            cur = header.group(2)
            comps[cur] = []
            if header.group(1):
                entry = cur
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        rest = m.group(2)
        om = _OPCODE_RE.search(rest)
        if not om:
            continue
        comps[cur].append(Op(m.group(1), rest[:om.start()], om.group(1),
                             rest[om.end():]))
    return comps, entry


def _dot_flops(op: Op, types: Dict[str, str]) -> float:
    out_elems = _type_elems(op.type_str)
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    operands = re.findall(r"%([\w\.\-]+)", op.rest.split("),")[0] + ")")
    k = 1
    if cdims and operands:
        lhs_t = types.get(operands[0], "")
        dims = _shape_dims(lhs_t)
        for ci in cdims.group(1).split(","):
            if ci and int(ci) < len(dims):
                k *= dims[int(ci)]
    return 2.0 * out_elems * k


def analyze_hlo(text: str) -> Dict[str, float]:
    comps, entry = parse_hlo(text)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collectives": {}}
    types: Dict[str, str] = {}
    for ops in comps.values():
        for op in ops:
            types[op.name] = op.type_str

    coll: Dict[str, Dict[str, float]] = {c: {"count": 0.0, "bytes": 0.0}
                                         for c in COLLECTIVES}
    totals = {"flops": 0.0, "bytes": 0.0}

    def operand_names(op: Op) -> List[str]:
        # operands are before the first "), " attr separator
        head = op.rest.split("), ")[0]
        return re.findall(r"%([\w\.\-]+)", head)

    def walk(comp: str, mult: float, depth: int = 0) -> None:
        if depth > 50 or comp not in comps:
            return
        for op in comps[comp]:
            oc = op.opcode
            if oc == "while":
                tc = re.search(r'known_trip_count[^0-9]*"n":"(\d+)"', op.rest)
                n = float(tc.group(1)) if tc else 1.0
                body = re.search(r"body=%?([\w\.\-]+)", op.rest)
                if body:
                    walk(body.group(1), mult * n, depth + 1)
                continue
            if oc in ("call", "fusion", "async-start"):
                called = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)",
                                   op.rest)
                if called:
                    # fusion internals: count dot flops only (VMEM-resident)
                    _walk_flops_only(called.group(1), mult, depth + 1)
                if oc == "fusion":
                    # Traffic model for fused regions:
                    #  * slice-read pattern: an operand larger than the
                    #    result is a stacked array being dynamic-sliced --
                    #    cap its contribution at the result size;
                    #  * in-place update pattern (dynamic-update-slice):
                    #    result type == an operand type -- the write is
                    #    slice-sized, not array-sized.
                    rb = _type_bytes(op.type_str)
                    obs = [_type_bytes(types.get(o, ""))
                           for o in operand_names(op)]
                    if rb > (4 << 20) and obs:
                        if rb in obs:            # in-place update
                            obs.remove(rb)
                        if obs and rb > 2 * max(obs):
                            rb = 2 * max(obs)    # broadcast/stack write cap
                    reads = sum(min(o, rb) for o in obs)
                    totals["bytes"] += mult * (rb + min(reads, 4 * rb))
                continue
            if oc == "conditional":
                branches = re.findall(
                    r"(?:branch_computations=\{([^\}]*)\}|"
                    r"(?:true|false)_computation=%?([\w\.\-]+))", op.rest)
                for b in branches:
                    for name in (b[0].split(",") if b[0] else [b[1]]):
                        if name:
                            walk(name.strip().lstrip("%"), mult, depth + 1)
                continue
            base = oc.replace("-start", "") if oc.endswith("-start") else oc
            if base in COLLECTIVES:
                nbytes = _type_bytes(op.type_str)
                coll[base]["count"] += mult
                coll[base]["bytes"] += mult * nbytes
                totals["bytes"] += mult * nbytes
                continue
            if oc.endswith("-done"):
                continue
            if oc == "dot":
                totals["flops"] += mult * _dot_flops(op, types)
                totals["bytes"] += mult * (
                    _type_bytes(op.type_str)
                    + sum(_type_bytes(types.get(o, ""))
                          for o in operand_names(op)))
                continue
            if oc in _SKIP_TRAFFIC:
                continue
            # generic op: elementwise-ish flops; traffic counts the RESULT
            # only -- on the TPU target producer-consumer chains fuse, so an
            # unfused-on-CPU elementwise op contributes one tensor write
            # (operand reads are the producers' writes, already counted).
            totals["flops"] += mult * _type_elems(op.type_str)
            totals["bytes"] += mult * _type_bytes(op.type_str)

    def _walk_flops_only(comp: str, mult: float, depth: int) -> None:
        if depth > 50 or comp not in comps:
            return
        for op in comps[comp]:
            if op.opcode == "dot":
                totals["flops"] += mult * _dot_flops(op, types)
            elif op.opcode in ("call", "fusion"):
                called = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)",
                                   op.rest)
                if called:
                    _walk_flops_only(called.group(1), mult, depth + 1)
            elif op.opcode not in _SKIP_TRAFFIC and op.opcode != "while":
                totals["flops"] += mult * _type_elems(op.type_str)

    walk(entry, 1.0)
    return {"flops": totals["flops"], "bytes": totals["bytes"],
            "collective_bytes": sum(c["bytes"] for c in coll.values()),
            "collectives": coll}
