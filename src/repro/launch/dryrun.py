import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed
on the production meshes, and the compiled artifact yields
``memory_analysis()`` (fits-per-device proof) and ``cost_analysis()``
(FLOPs/bytes for the roofline), plus per-collective byte counts parsed from
the optimized HLO.  Results are written to ``artifacts/dryrun/*.json`` which
``benchmarks/roofline.py`` consumes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --cells all --mesh both
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.data import make_batch_specs  # noqa: E402
from repro.launch.cells import skip_reason  # noqa: E402
from repro.launch.hlo_analysis import (analyze_hlo,  # noqa: E402
                                       normalize_cost_analysis)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.sharding.ctx import use_mesh  # noqa: E402
from repro.models import build_model, model_flops, param_count  # noqa: E402
from repro.models.common import SHAPES  # noqa: E402
from repro.optim import build_optimizer  # noqa: E402
from repro.runtime import TrainConfig, make_train_step  # noqa: E402
from repro.sharding import (batch_sharding, decode_state_sharding,  # noqa: E402
                            param_sharding, plan_summary)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

_MEGATRON_MASTER = None   # captured from flags (after --ablate) on first cell

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|"
                       r"pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result-shape bytes of every collective op in the optimized HLO."""
    stats: Dict[str, Dict[str, float]] = {
        c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w\.\-]+\s*=\s*(\([^)]*\)|[^=]*?)\s*"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start|-done)?\(", line)
        if not m or (m.group(3) == "-done"):
            continue
        kind = m.group(2)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += _shape_bytes(m.group(1))
    return stats


def _choose_optimizer(cfg) -> str:
    # >=100B params: factored second moment or the fp32 moments don't fit.
    return "adafactor" if param_count(cfg) > 100e9 else "adamw"


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, arg_specs(shapes), in_shardings, out_shardings, meta)."""
    from repro.flags import FLAGS
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    fsdp = param_count(cfg) > 20e9
    key = jax.random.PRNGKey(0)
    # Empirically-selected sharding policy (EXPERIMENTS.md Perf, H1d):
    # role-aware Megatron rules win on every inference cell and on
    # MoE/enc-dec training, but lose to the size heuristic on dense/SSM
    # training (backward collective patterns differ); row-parallel
    # out-projections only ever win without a backward pass.  The master
    # switch (possibly --ablate'd) gates the whole policy; per-cell values
    # are derived fresh so cells don't leak state into each other.
    global _MEGATRON_MASTER
    if _MEGATRON_MASTER is None:
        _MEGATRON_MASTER = FLAGS["megatron_sharding"]
    FLAGS["megatron_sharding"] = _MEGATRON_MASTER and (
        shape.kind != "train" or cfg.family in ("moe", "encdec"))
    FLAGS["megatron_row_parallel"] = (_MEGATRON_MASTER
                                      and shape.kind != "train")

    if shape.kind == "train":
        opt = build_optimizer(_choose_optimizer(cfg))
        params_s = jax.eval_shape(model.init, key)
        opt_s = jax.eval_shape(opt.init, params_s)
        state_s = {"params": params_s, "opt": opt_s,
                   "step": jax.ShapeDtypeStruct((), jnp.int32)}
        p_shard, notes = param_sharding(cfg, state_s, mesh, fsdp=fsdp)
        batch_specs = make_batch_specs(cfg, shape)
        b_shard = batch_sharding(shape, batch_specs, mesh)
        # >=100B: accumulation microbatches cut the remat activation stacks
        # below the per-device HBM budget; but each microbatch re-gathers
        # FSDP params, so accum trades HBM for ICI (EXPERIMENTS.md Perf)
        accum = int(os.environ.get("REPRO_ACCUM",
                                   "4" if param_count(cfg) > 100e9 else "1"))
        tc = TrainConfig(accum=accum)
        lr = lambda s: jnp.asarray(1e-4, jnp.float32)
        step = make_train_step(model, opt, lr, tc)
        meta = {"optimizer": opt.name, "fsdp": fsdp, "accum": accum,
                "plan": plan_summary(notes)}
        out_shard = (p_shard, {"loss": NamedSharding(mesh, P()),
                               "gnorm": NamedSharding(mesh, P()),
                               "lr": NamedSharding(mesh, P())})
        return (step, (state_s, batch_specs), (p_shard, b_shard), out_shard,
                meta, model_flops(cfg, shape.seq_len * shape.global_batch,
                                  "train"))

    if shape.kind == "prefill":
        from repro.flags import flag
        params_s = jax.eval_shape(model.init, key)
        # inference: TP-only weights avoid per-layer FSDP gathers; a 47B
        # bf16 model fits a 16-way model axis (mixtral-H2b)
        p_shard, notes = param_sharding(cfg, params_s, mesh,
                                        fsdp=fsdp and flag("inference_fsdp"))
        batch_specs = make_batch_specs(cfg, shape)
        batch_specs.pop("labels")
        b_shard = batch_sharding(shape, batch_specs, mesh)
        fn = model.prefill_fn
        meta = {"fsdp": fsdp, "plan": plan_summary(notes)}
        return (fn, (params_s, batch_specs), (p_shard, b_shard), None, meta,
                model_flops(cfg, shape.seq_len * shape.global_batch,
                            "inference"))

    # decode: one new token against a cache of seq_len
    params_s = jax.eval_shape(model.init, key)
    p_shard, _ = param_sharding(cfg, params_s, mesh, fsdp=False)
    b = shape.global_batch
    frontend_s = None
    if cfg.family == "encdec":
        from repro.models.encdec import ENC_LEN
        frontend_s = jax.ShapeDtypeStruct((b, ENC_LEN, cfg.frontend_dim),
                                          jnp.float32)
    if frontend_s is not None:
        state_s = jax.eval_shape(
            lambda p, f: model.init_decode_state(p, b, shape.seq_len,
                                                 frontend=f),
            params_s, frontend_s)
    else:
        state_s = jax.eval_shape(
            lambda p: model.init_decode_state(p, b, shape.seq_len),
            params_s)
    st_shard = decode_state_sharding(cfg, state_s, mesh)
    tok_s = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_shard = batch_sharding(shape, {"tokens": tok_s}, mesh)["tokens"]
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)
    fn = model.decode_step
    meta = {"cache_len": shape.seq_len}
    return (fn, (params_s, state_s, tok_s, pos_s),
            (p_shard, st_shard, tok_shard, NamedSharding(mesh, P())), None,
            meta, model_flops(cfg, b, "inference"))


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = ARTIFACT_DIR) -> Dict[str, Any]:
    os.makedirs(out_dir, exist_ok=True)
    result: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                              "mesh": mesh_name}
    reason = skip_reason(arch, shape_name)
    tag = f"{arch}__{shape_name}__{mesh_name}"
    if reason:
        result["status"] = "skipped"
        result["reason"] = reason
        _write(out_dir, tag, result)
        print(f"[dryrun] SKIP {tag}: {reason}")
        return result

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    try:
        fn, specs, in_shard, out_shard, meta, mflops = build_cell(
            arch, shape_name, mesh)
        t0 = time.time()
        with mesh, use_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_shard,
                             out_shardings=out_shard)
            lowered = jitted.lower(*specs)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = normalize_cost_analysis(compiled.cost_analysis())
        hlo = compiled.as_text()
        # trip-count-aware per-device totals (cost_analysis counts loop
        # bodies once; analyze_hlo multiplies known_trip_count through)
        deep = analyze_hlo(hlo)
        result.update({
            "status": "ok",
            "n_devices": n_dev,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "meta": {k: v for k, v in meta.items() if k != "plan"},
            "plan": meta.get("plan", ""),
            "model_flops": mflops,
            "hlo_flops_raw": float(cost.get("flops", -1)) if cost else -1,
            "hlo_bytes_raw": (float(cost.get("bytes accessed", -1))
                              if cost else -1),
            "hlo_flops": deep["flops"],
            "hlo_bytes": deep["bytes"],
            "collectives": deep["collectives"],
            "collective_bytes_total": deep["collective_bytes"],
        })
        if mem is not None:
            result["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
                "output_bytes": getattr(mem, "output_size_in_bytes", -1),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
                "generated_code_bytes":
                    getattr(mem, "generated_code_size_in_bytes", -1),
            }
            print(f"[dryrun] {tag}: memory_analysis "
                  f"args={result['memory']['argument_bytes']/1e9:.2f}GB "
                  f"temp={result['memory']['temp_bytes']/1e9:.2f}GB "
                  f"out={result['memory']['output_bytes']/1e9:.2f}GB")
        print(f"[dryrun] {tag}: OK devices={n_dev} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"hlo_flops={result['hlo_flops']:.3e} "
              f"coll_bytes={result['collective_bytes_total']:.3e}")
    except Exception as e:  # noqa: BLE001
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] FAIL {tag}: {result['error']}")
    _write(out_dir, tag, result)
    return result


def _write(out_dir: str, tag: str, result: Dict) -> None:
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(result, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES.keys()) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--cells", default=None,
                    help="'all' or comma list arch:shape")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--ablate", default="",
                    help="comma list of optimization flags to disable "
                         "(A/B baseline runs; see repro.flags)")
    args = ap.parse_args()

    if args.ablate:
        from repro.flags import set_flag
        for name in args.ablate.split(","):
            set_flag(name.strip(), False)
        print(f"[dryrun] ablated: {args.ablate}")

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.cells == "all":
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    elif args.cells:
        cells = [tuple(c.split(":")) for c in args.cells.split(",")]
    else:
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for (a, s) in cells:
        for m in meshes:
            r = run_cell(a, s, m, out_dir=args.out)
            n_fail += r["status"] == "error"
    print(f"[dryrun] done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
