"""Fault-tolerant checkpointing: atomic, keep-K, async, mesh-independent.

Checkpoints are written as flat ``.npz`` archives of the host-gathered pytree
plus a JSON manifest (step, data-pipeline cursor, mesh shape at save time).
Restore is *elastic*: arrays are stored logically (unsharded), so a restart
may re-shard onto a different mesh/device count -- the loader just applies
the new sharding spec.  Writes go to a temp file + atomic rename; a
``keep`` window garbage-collects old steps; ``save_async`` overlaps the
serialization with the next training step (the device->host copy is the only
synchronous part).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Params = Any


def _flatten(tree: Params, prefix: str = "") -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            # numpy can't serialize ml_dtypes (bfloat16 etc.); fp32 is a
            # lossless container for bf16 and restore re-casts per template.
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths ------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> Optional[int]:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_")
                 and os.path.exists(os.path.join(self.dir, d, "MANIFEST.json"))]
        return max(steps) if steps else None

    # -- save -------------------------------------------------------------
    def save(self, step: int, tree: Params,
             meta: Optional[Dict] = None) -> str:
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        return self._write(step, host_tree, meta or {})

    def save_async(self, step: int, tree: Params,
                   meta: Optional[Dict] = None) -> None:
        """Device->host copy happens now; disk write on a worker thread."""
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, meta or {}))
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Params, meta: Dict) -> str:
        final = self._step_dir(step)
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            flat = _flatten(host_tree)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            treedef = jax.tree.structure(host_tree)
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump({"step": step, "meta": meta,
                           "treedef": str(treedef),
                           "n_arrays": len(flat)}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def restore(self, template: Params, step: Optional[int] = None,
                shardings: Optional[Params] = None
                ) -> Tuple[Params, Dict]:
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of jax.sharding.Sharding -- arrays are
        placed per-spec, which is how a checkpoint saved on one mesh resumes
        on another (elastic restart).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        arrays = np.load(os.path.join(d, "arrays.npz"))
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in flat_t:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = arrays[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {leaf.shape}")
            leaves.append(arr.astype(leaf.dtype))
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, manifest
