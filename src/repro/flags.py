"""Optimization feature flags for A/B perf measurement (EXPERIMENTS.md Perf).

Each beyond-baseline optimization is gated so the dry-run can measure a cell
with and without it under identical code + metric:

  H1 megatron_sharding: role-aware TP dims (column-parallel in-projections,
     row-parallel out-projections) instead of largest-divisible-dim.
  H2 banded_attention: sliding-window prefill reads only the reachable key
     band per query chunk (O(L*W) instead of O(L^2)).
  H3 ssm_small_chunk + ssm_bf16_scan: Lc=32 scan chunks (fewer associative
     levels) and bf16 scan-tensor storage for mamba1.
"""

from __future__ import annotations

from typing import Dict

FLAGS: Dict[str, bool] = {
    "megatron_sharding": True,
    # row-parallel out-projections measured WORSE at arctic scale (f32
    # cotangent psums outweigh the removed re-gathers) -- kept off;
    # see EXPERIMENTS.md Perf arctic-H1 (refuted)
    "megatron_row_parallel": False,
    "banded_attention": True,
    # smaller scan chunks measured WORSE (4x more bodies -> more boundary
    # collectives/overhead) -- kept off; falcon-H3a (refuted)
    "ssm_small_chunk": False,
    "ssm_bf16_scan": True,
    # FSDP for inference cells replaced by TP-only weights (no per-layer
    # param gathers at serve time) -- mixtral-H2b
    "inference_fsdp": False,
}


def set_flag(name: str, value: bool) -> None:
    if name not in FLAGS:
        raise KeyError(f"unknown flag {name}; have {sorted(FLAGS)}")
    FLAGS[name] = value


def flag(name: str) -> bool:
    return FLAGS[name]
