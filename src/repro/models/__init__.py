from .api import Model, active_param_count, build_model, model_flops, param_count  # noqa: F401
from .common import SHAPES, ArchConfig, ShapeConfig, pad_vocab  # noqa: F401
