"""GQA attention with RoPE, sliding windows, and a KV cache.

Two execution paths: ``impl="xla"`` (pure jnp; what the multi-pod dry-run
lowers, since Pallas TPU kernels cannot be compiled by the CPU stand-in
backend) and ``impl="pallas"`` (the flash-attention kernel from
``repro.kernels`` for real TPU deployments / interpret-mode tests).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.flash_attention import flash_attention
from .common import ArchConfig, Params, init_linear, linear, rope


def init_attention(key, cfg: ArchConfig, n_heads: Optional[int] = None,
                   n_kv: Optional[int] = None) -> Params:
    nh = n_heads or cfg.n_heads
    nk = n_kv or cfg.n_kv_heads
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], cfg.d_model, nh * hd, cfg.dtype, cfg.qkv_bias),
        "wk": init_linear(ks[1], cfg.d_model, nk * hd, cfg.dtype, cfg.qkv_bias),
        "wv": init_linear(ks[2], cfg.d_model, nk * hd, cfg.dtype, cfg.qkv_bias),
        "wo": init_linear(ks[3], nh * hd, cfg.d_model, cfg.dtype),
    }


def _sdpa_block(q, k, v, causal: bool, window: Optional[int], q_offset,
                k_offset=0) -> jax.Array:
    """q: (B, Lq, H, D); k, v: (B, Lk, Hkv, D) -- one dense attention block.

    ``q_offset``/``k_offset``: global positions of q[:,0]/k[:,0].
    """
    b, lq, h, dh = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    qg = q.reshape(b, lq, hkv, group, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(dh))
    qi = jnp.arange(lq)[:, None] + q_offset
    ki = jnp.arange(lk)[None, :] + k_offset
    mask = jnp.ones((lq, lk), dtype=bool)
    if causal:
        mask = mask & (ki <= qi)
    if window is not None:
        mask = mask & (qi - ki < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, lq, h, dh).astype(q.dtype)


def _sdpa_xla(q, k, v, causal: bool, window: Optional[int], q_offset,
              chunk: int = 1024) -> jax.Array:
    """Query-chunked attention: bounds the live score tensor to
    (B, H, chunk, Lk) -- the flash-attention streaming structure expressed at
    the XLA level so the dry-run lowers with a sane memory footprint.

    With a sliding window, each q chunk reads only the (window + chunk)-long
    key band that can attend -- the paper's banded-stencil access pattern,
    cutting SWA prefill from O(L^2) to O(L*W) flops/bytes
    (EXPERIMENTS.md Perf, mixtral-H2).
    """
    lq = q.shape[1]
    lk = k.shape[1]
    if lq <= chunk or lq % chunk != 0:
        return _sdpa_block(q, k, v, causal, window, q_offset)
    nq = lq // chunk
    qc = jnp.moveaxis(q.reshape(q.shape[0], nq, chunk, *q.shape[2:]), 1, 0)
    offs = q_offset + jnp.arange(nq) * chunk

    from ..flags import flag
    banded = (flag("banded_attention") and window is not None and causal
              and window + chunk < lk and (window + chunk) % chunk == 0)
    klen = min(lk, window + chunk) if window is not None else lk

    def one(args):
        qi, off = args
        if banded:
            # keys in [off + chunk - klen, off + chunk): the reachable band
            k_start = jnp.clip(off + chunk - klen, 0, lk - klen)
            kb = jax.lax.dynamic_slice_in_dim(k, k_start, klen, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, k_start, klen, 1)
            return _sdpa_block(qi, kb, vb, causal, window, off,
                               k_offset=k_start)
        return _sdpa_block(qi, k, v, causal, window, off)

    oc = jax.lax.map(one, (qc, offs))
    return jnp.moveaxis(oc, 0, 1).reshape(q.shape)


def attention(p: Params, x: jax.Array, cfg: ArchConfig,
              positions: jax.Array,
              cache: Optional[Tuple[jax.Array, jax.Array]] = None,
              cache_pos: Optional[jax.Array] = None,
              causal: bool = True,
              window: Optional[int] = None,
              n_heads: Optional[int] = None, n_kv: Optional[int] = None,
              impl: str = "xla",
              ) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """x: (B, L, d).  With a cache (decode): k/v appended at ``cache_pos``.

    cache: (k, v) each (B, S_max, Hkv, D).  Returns (out, new_cache).
    """
    b, l, _ = x.shape
    nh = n_heads or cfg.n_heads
    nk = n_kv or cfg.n_kv_heads
    hd = cfg.hd
    q = linear(p["wq"], x).reshape(b, l, nh, hd)
    k = linear(p["wk"], x).reshape(b, l, nk, hd)
    v = linear(p["wv"], x).reshape(b, l, nk, hd)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        ck, cv = cache
        # start indices must share one dtype; literal zeros weak-type to
        # int64 under JAX_ENABLE_X64, so mint them in cache_pos's dtype
        pos = jnp.asarray(cache_pos)
        zero = jnp.zeros((), pos.dtype)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (zero, pos, zero, zero))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (zero, pos, zero, zero))
        new_cache = (ck, cv)
        k_all, v_all = ck, cv
        q_offset = cache_pos
    else:
        k_all, v_all = k, v
        q_offset = 0

    if impl == "pallas" and cache is None:
        o = flash_attention(q.transpose(0, 2, 1, 3), k_all.transpose(0, 2, 1, 3),
                            v_all.transpose(0, 2, 1, 3), causal=causal,
                            window=window, q_offset=0)
        o = o.transpose(0, 2, 1, 3)
    else:
        o = _sdpa_xla(q, k_all, v_all, causal, window, q_offset)
    out = linear(p["wo"], o.reshape(b, l, nh * hd))
    return out, new_cache
