"""Mamba-1 and Mamba-2 blocks (chunked streaming scans, pure JAX).

These are the framework's flagship *streaming numerical kernels* in the
paper's sense (DESIGN.md sect. 4): O(L) flops over sequentially streamed
activations with a small carried state.

Memory discipline mirrors the paper's register-resident streaming: the
sequence is processed in chunks by ``lax.scan`` carrying only the SSM state,
so the materialized per-chunk tensors stay VMEM/HBM-bounded at 500k-token
contexts.  Mamba-2 uses the SSD chunked form -- intra-chunk work becomes
(Lc x Lc) matmuls (MXU-native, the TPU answer to the paper's FMA-saturation
goal), inter-chunk state passes through the scan carry.  The decode path is
the single-step recurrence on an explicit (conv window, ssm state) cache.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, Params, init_linear, linear

CHUNK = 128


def init_mamba(key, cfg: ArchConfig) -> Params:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    ks = jax.random.split(key, 8)
    p: Params = {
        "in_proj": init_linear(ks[0], d, 2 * di, cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   * 0.2).astype(cfg.dtype),
        "conv_b": jnp.zeros((di,), cfg.dtype),
        "out_proj": init_linear(ks[2], di, d, cfg.dtype),
    }
    if cfg.ssm_kind == "mamba1":
        dt_rank = max(1, d // 16)
        p["a_log"] = jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                      (di, 1)))                 # (di, N)
        p["d_skip"] = jnp.ones((di,), jnp.float32)
        p["x_proj"] = init_linear(ks[3], di, dt_rank + 2 * n, cfg.dtype)
        p["dt_proj"] = init_linear(ks[4], dt_rank, di, cfg.dtype, bias=True)
    else:  # mamba2 (SSD): scalar decay per head; B/C projected from x
        nh = di // cfg.ssm_head_dim
        p["bc_proj"] = init_linear(ks[3], di, 2 * n, cfg.dtype)
        p["dt_bias"] = jnp.zeros((nh,), jnp.float32)
        p["a_log"] = jnp.zeros((nh,), jnp.float32)              # scalar/head
        p["d_skip"] = jnp.ones((nh,), jnp.float32)
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: (B, L, di); w: (K, di); state (B, K-1, di)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return jax.nn.silu(out), new_state


def _assoc_scan(decay: jax.Array, u: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = decay_t * h_{t-1} + u_t over axis 1, seeded with h0."""
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, a2 * b1 + b2

    cum_a, cum_b = jax.lax.associative_scan(combine, (decay, u), axis=1)
    return cum_a * h0[:, None] + cum_b


def _chunked(l: int, cap: int = CHUNK) -> int:
    c = min(cap, l)
    while l % c:
        c //= 2
    return max(c, 1)


def mamba_block(p: Params, x: jax.Array, cfg: ArchConfig,
                state: Optional[Params] = None
                ) -> Tuple[jax.Array, Optional[Params]]:
    """x: (B, L, d).  ``state`` = {"conv", "ssm"} for stepwise decode."""
    if cfg.ssm_kind == "mamba1":
        return _mamba1(p, x, cfg, state)
    return _mamba2(p, x, cfg, state)


# ---------------------------------------------------------------------------
# Mamba-1: diagonal per-(channel, state) recurrence, chunked associative scan.
# ---------------------------------------------------------------------------

def _mamba1(p, x, cfg, state):
    b, l, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = linear(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                    # (B, L, di)
    conv_state = state["conv"] if state is not None else None
    xi, new_conv = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)

    dt_rank = p["dt_proj"]["w"].shape[0]
    proj = linear(p["x_proj"], xi)
    dt, bm, c = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(linear(p["dt_proj"], dt)).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])                             # (di, N)
    bm32 = bm.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    xi32 = xi.astype(jnp.float32)

    h0 = (state["ssm"] if state is not None
          else jnp.zeros((b, di, n), jnp.float32))

    # Smaller chunks than mamba2: the (B, Lc, di, N) scan tensors are the
    # memory-bound core; log2(Lc) associative-scan levels each materialize
    # tensor pairs, so Lc=32 (5 levels) moves ~30% less than Lc=128 (7).
    from ..flags import flag
    lc = _chunked(l, cap=32 if flag("ssm_small_chunk") else CHUNK)
    nchunk = l // lc
    sd = (cfg.ssm_scan_dtype if flag("ssm_bf16_scan") else None) \
        or jnp.float32

    def chunk_fn(h_prev, inp):
        xt, dtt, bt, ct = inp                            # (B, Lc, ...)
        decay = jnp.exp(dtt[..., None] * a[None, None])  # (B, Lc, di, N)
        u = (dtt * xt)[..., None] * bt[:, :, None, :]
        h = _assoc_scan(decay.astype(sd), u.astype(sd),
                        h_prev.astype(sd))               # (B, Lc, di, N)
        y = jnp.einsum("bldn,bln->bld", h, ct,
                       preferred_element_type=jnp.float32)
        return h[:, -1].astype(jnp.float32), y

    def split(t):
        return jnp.moveaxis(t.reshape(b, nchunk, lc, *t.shape[2:]), 1, 0)

    h_last, ys = jax.lax.scan(chunk_fn, h0,
                              (split(xi32), split(dt), split(bm32),
                               split(c32)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, di)
    y = y + p["d_skip"][None, None] * xi32
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = linear(p["out_proj"], y)
    new_state = ({"conv": new_conv, "ssm": h_last}
                 if state is not None else None)
    return out, new_state


# ---------------------------------------------------------------------------
# Mamba-2 (SSD): scalar-per-head decay; chunked matmul (MXU) formulation.
# ---------------------------------------------------------------------------

def _mamba2(p, x, cfg, state):
    b, l, _ = x.shape
    di, n, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = di // hd
    xz = linear(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xi, new_conv = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)

    bc = linear(p["bc_proj"], xi)
    bm, c = jnp.split(bc, 2, axis=-1)                    # (B, L, N)
    bm32, c32 = bm.astype(jnp.float32), c.astype(jnp.float32)
    xh = xi.reshape(b, l, nh, hd).astype(jnp.float32)
    dt = jax.nn.softplus(xh.mean(-1) + p["dt_bias"][None, None])  # (B, L, nh)
    a = -jnp.exp(p["a_log"])                                      # (nh,)
    g_step = dt * a[None, None]                                   # (B, L, nh) <= 0
    dtx = dt[..., None] * xh                                      # (B, L, nh, hd)

    h0 = (state["ssm"] if state is not None
          else jnp.zeros((b, nh, hd, n), jnp.float32))

    lc = _chunked(l)
    nchunk = l // lc

    def chunk_fn(h_prev, inp):
        gs, u, bt, ct = inp        # (B,Lc,nh), (B,Lc,nh,hd), (B,Lc,N), (B,Lc,N)
        g = jnp.cumsum(gs, axis=1)                       # (B, Lc, nh)
        # intra-chunk: S[b,h,i,j] = (C_i . B_j) exp(g_i - g_j) for i >= j
        cb = jnp.einsum("bin,bjn->bij", ct, bt)          # (B, Lc, Lc)
        dmat = jnp.exp(g[:, :, None, :] - g[:, None, :, :])  # (B, i, j, nh)
        tri = jnp.tril(jnp.ones((lc, lc), jnp.float32))
        s = cb[..., None] * dmat * tri[None, :, :, None]
        y_intra = jnp.einsum("bijh,bjhd->bihd", s, u)
        # inter-chunk: contribution of carried state
        y_inter = jnp.exp(g)[..., None] * jnp.einsum(
            "bin,bhdn->bihd", ct, h_prev)
        # new carried state
        g_last = g[:, -1]                                # (B, nh)
        w_j = jnp.exp(g_last[:, None] - g)               # (B, Lc, nh)
        h_new = (jnp.exp(g_last)[..., None, None] * h_prev
                 + jnp.einsum("bjh,bjhd,bjn->bhdn", w_j, u, bt))
        return h_new, y_intra + y_inter

    def split(t):
        return jnp.moveaxis(t.reshape(b, nchunk, lc, *t.shape[2:]), 1, 0)

    h_last, ys = jax.lax.scan(chunk_fn, h0,
                              (split(g_step), split(dtx), split(bm32),
                               split(c32)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, nh, hd)
    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(b, l, di).astype(x.dtype) * jax.nn.silu(z)
    out = linear(p["out_proj"], y)
    new_state = ({"conv": new_conv, "ssm": h_last}
                 if state is not None else None)
    return out, new_state


def init_ssm_state(cfg: ArchConfig, batch: int) -> Params:
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    conv = jnp.zeros((batch, k - 1, di), cfg.dtype)
    if cfg.ssm_kind == "mamba1":
        ssm = jnp.zeros((batch, di, n), jnp.float32)
    else:
        nh = di // cfg.ssm_head_dim
        ssm = jnp.zeros((batch, nh, cfg.ssm_head_dim, n), jnp.float32)
    return {"conv": conv, "ssm": ssm}
