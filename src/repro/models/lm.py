"""Decoder-only language model: dense / GQA / MoE / VLM-backbone variants.

Layers are stacked (leading layer axis) and applied with ``lax.scan`` so the
compiled HLO is depth-independent (critical for 480B-scale dry-run compiles);
each scan body is rematerialized (activation checkpointing).  The VLM/audio
modality frontend is a stub per the assignment: precomputed patch/frame
embeddings enter through a linear projection and occupy the sequence prefix.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention, init_attention
from .common import (ArchConfig, Params, chunked_ce_loss, cross_entropy,
                     init_linear, init_mlp, linear, mlp, pad_vocab, rms_norm)
from .moe import init_moe, moe_ffn


def init_layer(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {
        "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
        "attn": init_attention(ks[0], cfg),
        "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if cfg.n_experts:
        p["ffn"] = init_moe(ks[1], cfg)
    else:
        p["ffn"] = init_mlp(ks[1], cfg)
    return p


def init_lm(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 4 + cfg.n_layers)
    vpad = pad_vocab(cfg.vocab_size)
    p: Params = {
        "embed": (jax.random.normal(ks[0], (vpad, cfg.d_model), jnp.float32)
                  * 0.02).astype(cfg.dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(
            jnp.stack(ks[4:4 + cfg.n_layers])),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(ks[1], cfg.d_model, vpad, cfg.dtype)
    if cfg.frontend:
        p["frontend_proj"] = init_linear(ks[2], cfg.frontend_dim, cfg.d_model,
                                         cfg.dtype)
    return p


def _ffn_apply(lp: Params, x: jax.Array, cfg: ArchConfig
               ) -> Tuple[jax.Array, jax.Array]:
    if cfg.n_experts:
        return moe_ffn(lp["ffn"], x, cfg)
    return mlp(lp["ffn"], x, cfg), jnp.zeros((), jnp.float32)


def _layer_apply(lp: Params, x: jax.Array, cfg: ArchConfig,
                 positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    h, _ = attention(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
                     positions, window=cfg.window)
    x = x + h
    f, aux = _ffn_apply(lp, rms_norm(x, lp["ln2"], cfg.norm_eps), cfg)
    return x + f, aux


def embed_inputs(params: Params, cfg: ArchConfig, tokens: jax.Array,
                 frontend: Optional[jax.Array] = None) -> jax.Array:
    x = params["embed"][tokens]
    if frontend is not None:
        fx = linear(params["frontend_proj"], frontend.astype(cfg.dtype))
        x = jnp.concatenate([fx, x], axis=1)
    return x


def lm_logits(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    return (x @ params["embed"].T if cfg.tie_embeddings
            else linear(params["lm_head"], x))


def lm_hidden(params: Params, cfg: ArchConfig, tokens: jax.Array,
              frontend: Optional[jax.Array] = None,
              remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Trunk forward.  Returns (final hidden states, aux_loss)."""
    x = embed_inputs(params, cfg, tokens, frontend)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(carry, lp):
        h, aux = carry
        h2, aux2 = _layer_apply(lp, h, cfg, positions)
        return (h2, aux + aux2), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def lm_apply(params: Params, cfg: ArchConfig, tokens: jax.Array,
             frontend: Optional[jax.Array] = None, remat: bool = True,
             last_only: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Forward to logits; ``last_only`` = prefill mode (final position only,
    so a 32k prefill never materializes (B, 32k, V) logits)."""
    x, aux = lm_hidden(params, cfg, tokens, frontend, remat)
    if last_only:
        x = x[:, -1:]
    return lm_logits(params, cfg, x), aux


def lm_loss(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]
            ) -> jax.Array:
    x, aux = lm_hidden(params, cfg, batch["tokens"], batch.get("frontend"))
    labels = batch["labels"]
    npad = x.shape[1] - labels.shape[1]
    if npad:                       # frontend prefix carries no labels
        x = x[:, npad:]
    mask = (labels >= 0).astype(jnp.float32)
    loss = chunked_ce_loss(x, jnp.maximum(labels, 0), mask,
                           lambda xc: lm_logits(params, cfg, xc))
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Decode path (KV cache)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Params:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def lm_decode_step(params: Params, cfg: ArchConfig, cache: Params,
                   tokens: jax.Array, pos: jax.Array
                   ) -> Tuple[jax.Array, Params]:
    """One-token decode. tokens: (B, 1); pos: scalar int32 position."""
    x = params["embed"][tokens]
    positions = jnp.full((1, 1), pos, dtype=jnp.int32)

    def body(h, inp):
        lp, ck, cv = inp
        a, new_cache = attention(lp["attn"],
                                 rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
                                 positions, cache=(ck, cv), cache_pos=pos,
                                 window=cfg.window)
        h = h + a
        f, _ = _ffn_apply(lp, rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
        return h + f, new_cache

    x, (nk, nv) = jax.lax.scan(body, x,
                               (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T if cfg.tie_embeddings
              else linear(params["lm_head"], x))
    return logits, {"k": nk, "v": nv}
