"""Encoder-decoder transformer backbone (seamless-m4t family).

The audio frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings through a linear projection.  Positions use
learned absolute embeddings (documented simplification of Seamless's
relative-position scheme).  Decode caches decoder self-attention KV at the
full cache length and precomputes encoder cross KV once.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .attention import attention, init_attention
from .common import (ArchConfig, Params, chunked_ce_loss, init_linear,
                     init_mlp, linear, mlp, pad_vocab, rms_norm)

ENC_LEN = 1024     # stub frontend frames fed to the encoder at decode time


def _enc_len(cfg: ArchConfig, seq_len: int) -> int:
    return min(seq_len, 4096)


def init_cross_attention(key, cfg: ArchConfig) -> Params:
    return init_attention(key, cfg)


def cross_attention(p: Params, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                    cfg: ArchConfig) -> jax.Array:
    """x: (B, Lq, d); enc_kv: precomputed (k, v) each (B, Lk, H, hd)."""
    b, lq, _ = x.shape
    nh, hd = cfg.n_heads, cfg.hd
    q = linear(p["wq"], x).reshape(b, lq, nh, hd)
    k, v = enc_kv
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(hd))
    o = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, axis=-1),
                   v.astype(jnp.float32)).astype(x.dtype)
    return linear(p["wo"], o.reshape(b, lq, nh * hd))


def enc_kv(p: Params, enc_out: jax.Array, cfg: ArchConfig
           ) -> Tuple[jax.Array, jax.Array]:
    b, lk, _ = enc_out.shape
    k = linear(p["wk"], enc_out).reshape(b, lk, cfg.n_kv_heads, cfg.hd)
    v = linear(p["wv"], enc_out).reshape(b, lk, cfg.n_kv_heads, cfg.hd)
    rep = cfg.n_heads // cfg.n_kv_heads
    return jnp.repeat(k, rep, axis=2), jnp.repeat(v, rep, axis=2)


def init_encdec(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 8 + cfg.enc_layers + cfg.dec_layers)
    vpad = pad_vocab(cfg.vocab_size)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), cfg.dtype),
                "attn": init_attention(k1, cfg),
                "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
                "mlp": init_mlp(k2, cfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": jnp.ones((cfg.d_model,), cfg.dtype),
                "attn": init_attention(k1, cfg),
                "lnx": jnp.ones((cfg.d_model,), cfg.dtype),
                "xattn": init_cross_attention(k2, cfg),
                "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
                "mlp": init_mlp(k3, cfg)}

    ne, nd = cfg.enc_layers, cfg.dec_layers
    return {
        "frontend_proj": init_linear(ks[0], cfg.frontend_dim, cfg.d_model,
                                     cfg.dtype),
        "embed": (jax.random.normal(ks[1], (vpad, cfg.d_model), jnp.float32)
                  * 0.02).astype(cfg.dtype),
        "pos_enc": (jax.random.normal(ks[2], (65536, cfg.d_model),
                                      jnp.float32) * 0.02).astype(cfg.dtype),
        "enc_layers": jax.vmap(enc_layer)(jnp.stack(ks[8:8 + ne])),
        "enc_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "dec_layers": jax.vmap(dec_layer)(jnp.stack(ks[8 + ne:8 + ne + nd])),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "lm_head": init_linear(ks[3], cfg.d_model, vpad, cfg.dtype),
    }


def encode(params: Params, cfg: ArchConfig, frames: jax.Array,
           remat: bool = True) -> jax.Array:
    x = linear(params["frontend_proj"], frames.astype(cfg.dtype))
    x = x + params["pos_enc"][: x.shape[1]][None]
    positions = jnp.arange(x.shape[1])[None]

    def body(h, lp):
        a, _ = attention(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                         cfg, positions, causal=False)
        h = h + a
        return h + mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps), cfg), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params: Params, cfg: ArchConfig, tokens: jax.Array,
                 enc_out: jax.Array, remat: bool = True,
                 return_hidden: bool = False,
                 last_only: bool = False) -> jax.Array:
    x = params["embed"][tokens] + params["pos_enc"][: tokens.shape[1]][None]
    positions = jnp.arange(x.shape[1])[None]

    def body(h, lp):
        a, _ = attention(lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps),
                         cfg, positions, causal=True)
        h = h + a
        kv = enc_kv(lp["xattn"], enc_out, cfg)
        h = h + cross_attention(lp["xattn"],
                                rms_norm(h, lp["lnx"], cfg.norm_eps), kv, cfg)
        return h + mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps), cfg), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    if last_only:
        x = x[:, -1:]
    return linear(params["lm_head"], x)


def encdec_loss(params: Params, cfg: ArchConfig, batch: Dict) -> jax.Array:
    enc_out = encode(params, cfg, batch["frontend"])
    x = decode_train(params, cfg, batch["tokens"], enc_out,
                     return_hidden=True)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    return chunked_ce_loss(x, jnp.maximum(labels, 0), mask,
                           lambda xc: linear(params["lm_head"], xc))


def init_encdec_state(params: Params, cfg: ArchConfig, batch: int,
                      max_seq: int, frames: jax.Array) -> Params:
    """Precompute encoder output + cross KV; allocate decoder self cache."""
    enc_out = encode(params, cfg, frames, remat=False)
    kvs = jax.vmap(lambda lp: jnp.stack(enc_kv(lp["xattn"], enc_out, cfg)))(
        params["dec_layers"])
    return {
        "cross_kv": kvs,        # (L_dec, 2, B, enc_len, H, hd)
        "k": jnp.zeros((cfg.dec_layers, batch, max_seq, cfg.n_kv_heads,
                        cfg.hd), cfg.dtype),
        "v": jnp.zeros((cfg.dec_layers, batch, max_seq, cfg.n_kv_heads,
                        cfg.hd), cfg.dtype),
    }


def encdec_decode_step(params: Params, cfg: ArchConfig, state: Params,
                       tokens: jax.Array, pos: jax.Array
                       ) -> Tuple[jax.Array, Params]:
    x = params["embed"][tokens] + jax.lax.dynamic_slice_in_dim(
        params["pos_enc"], pos, 1, 0)[None]
    positions = jnp.full((1, 1), pos, dtype=jnp.int32)

    def body(h, inp):
        lp, ck, cv, xkv = inp
        a, new_cache = attention(lp["attn"],
                                 rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
                                 positions, cache=(ck, cv), cache_pos=pos)
        h = h + a
        h = h + cross_attention(lp["xattn"],
                                rms_norm(h, lp["lnx"], cfg.norm_eps),
                                (xkv[0], xkv[1]), cfg)
        h = h + mlp(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
        return h, new_cache

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], state["k"], state["v"],
                  state["cross_kv"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = linear(params["lm_head"], x)
    return logits, {"cross_kv": state["cross_kv"], "k": nk, "v": nv}
