"""Attention-free SSM language model (falcon-mamba-7b family)."""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (ArchConfig, Params, chunked_ce_loss, init_linear,
                     linear, pad_vocab, rms_norm)
from .ssm import init_mamba, init_ssm_state, mamba_block


def init_ssm_lm(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 2 + cfg.n_layers)
    vpad = pad_vocab(cfg.vocab_size)
    layer_keys = jnp.stack(ks[2:])

    def one(k):
        return {"ln": jnp.ones((cfg.d_model,), cfg.dtype),
                "mamba": init_mamba(k, cfg)}

    p: Params = {
        "embed": (jax.random.normal(ks[0], (vpad, cfg.d_model), jnp.float32)
                  * 0.02).astype(cfg.dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "layers": jax.vmap(one)(layer_keys),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(ks[1], cfg.d_model, vpad, cfg.dtype)
    return p


def ssm_lm_logits(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    return (x @ params["embed"].T if cfg.tie_embeddings
            else linear(params["lm_head"], x))


def ssm_lm_hidden(params: Params, cfg: ArchConfig, tokens: jax.Array,
                  remat: bool = True) -> jax.Array:
    x = params["embed"][tokens]

    def body(h, lp):
        m, _ = mamba_block(lp["mamba"], rms_norm(h, lp["ln"], cfg.norm_eps),
                           cfg)
        return h + m, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def ssm_lm_apply(params: Params, cfg: ArchConfig, tokens: jax.Array,
                 frontend=None, remat: bool = True, last_only: bool = False
                 ) -> Tuple[jax.Array, jax.Array]:
    x = ssm_lm_hidden(params, cfg, tokens, remat)
    if last_only:
        x = x[:, -1:]
    return ssm_lm_logits(params, cfg, x), jnp.zeros((), jnp.float32)


def ssm_lm_loss(params: Params, cfg: ArchConfig, batch: Dict) -> jax.Array:
    x = ssm_lm_hidden(params, cfg, batch["tokens"])
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    return chunked_ce_loss(x, jnp.maximum(labels, 0), mask,
                           lambda xc: ssm_lm_logits(params, cfg, xc))


def init_ssm_lm_state(cfg: ArchConfig, batch: int) -> Params:
    one = init_ssm_state(cfg, batch)
    return jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (cfg.n_layers,) + t.shape), one)


def ssm_lm_decode_step(params: Params, cfg: ArchConfig, state: Params,
                       tokens: jax.Array, pos: jax.Array
                       ) -> Tuple[jax.Array, Params]:
    """SSM decode: O(1) per token in the context length -- the reason this
    family runs the long_500k cell."""
    x = params["embed"][tokens]

    def body(h, inp):
        lp, st = inp
        m, new_st = mamba_block(lp["mamba"],
                                rms_norm(h, lp["ln"], cfg.norm_eps), cfg,
                                state=st)
        return h + m, new_st

    x, new_state = jax.lax.scan(body, x, (params["layers"], state))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T if cfg.tie_embeddings
              else linear(params["lm_head"], x))
    return logits, new_state
