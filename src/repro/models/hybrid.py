"""Zamba2-style hybrid: Mamba-2 trunk + a *shared* attention/MLP block.

One set of attention+MLP parameters is re-applied every ``attn_every``
layers (Zamba's parameter-sharing trick); each application owns a slot in a
stacked KV cache during decode.  Mixing full attention at a sparse cadence
keeps the arch sub-quadratic enough for the long_500k cell: the KV cost is
(n_layers / attn_every) caches instead of n_layers.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .attention import attention, init_attention
from .common import (ArchConfig, Params, chunked_ce_loss, init_linear,
                     init_mlp, linear, mlp, pad_vocab, rms_norm)
from .ssm import init_mamba, init_ssm_state, mamba_block


def n_shared_applications(cfg: ArchConfig) -> int:
    return (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every


def init_hybrid(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 4 + cfg.n_layers)
    vpad = pad_vocab(cfg.vocab_size)

    def one(k):
        return {"ln": jnp.ones((cfg.d_model,), cfg.dtype),
                "mamba": init_mamba(k, cfg)}

    return {
        "embed": (jax.random.normal(ks[0], (vpad, cfg.d_model), jnp.float32)
                  * 0.02).astype(cfg.dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "layers": jax.vmap(one)(jnp.stack(ks[4:4 + cfg.n_layers])),
        "shared": {
            "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
            "attn": init_attention(ks[1], cfg),
            "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
            "mlp": init_mlp(ks[2], cfg),
        },
        "lm_head": init_linear(ks[3], cfg.d_model, vpad, cfg.dtype),
    }


def _shared_block(sp: Params, x: jax.Array, cfg: ArchConfig, positions,
                  cache=None, cache_pos=None):
    a, new_cache = attention(sp["attn"], rms_norm(x, sp["ln1"], cfg.norm_eps),
                             cfg, positions, cache=cache, cache_pos=cache_pos)
    x = x + a
    x = x + mlp(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps), cfg)
    return x, new_cache


def hybrid_hidden(params: Params, cfg: ArchConfig, tokens: jax.Array,
                  remat: bool = True) -> jax.Array:
    x = params["embed"][tokens]
    positions = jnp.arange(x.shape[1])[None, :]
    idxs = jnp.arange(cfg.n_layers)

    def body(h, inp):
        lp, idx = inp
        m, _ = mamba_block(lp["mamba"], rms_norm(h, lp["ln"], cfg.norm_eps),
                           cfg)
        h = h + m
        h = jax.lax.cond(
            idx % cfg.attn_every == 0,
            lambda hh: _shared_block(params["shared"], hh, cfg, positions)[0],
            lambda hh: hh, h)
        return h, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["layers"], idxs))
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def hybrid_apply(params: Params, cfg: ArchConfig, tokens: jax.Array,
                 frontend=None, remat: bool = True, last_only: bool = False
                 ) -> Tuple[jax.Array, jax.Array]:
    x = hybrid_hidden(params, cfg, tokens, remat)
    if last_only:
        x = x[:, -1:]
    return linear(params["lm_head"], x), jnp.zeros((), jnp.float32)


def hybrid_loss(params: Params, cfg: ArchConfig, batch: Dict) -> jax.Array:
    x = hybrid_hidden(params, cfg, batch["tokens"])
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    return chunked_ce_loss(
        x, jnp.maximum(labels, 0), mask,
        lambda xc: linear(params["lm_head"], xc))


def init_hybrid_state(cfg: ArchConfig, batch: int, max_seq: int) -> Params:
    napp = n_shared_applications(cfg)
    ssm = init_ssm_state(cfg, batch)
    return {
        "ssm": jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (cfg.n_layers,) + t.shape),
            ssm),
        "k": jnp.zeros((napp, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                       cfg.dtype),
        "v": jnp.zeros((napp, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                       cfg.dtype),
    }


def hybrid_decode_step(params: Params, cfg: ArchConfig, state: Params,
                       tokens: jax.Array, pos: jax.Array
                       ) -> Tuple[jax.Array, Params]:
    x = params["embed"][tokens]
    positions = jnp.full((1, 1), pos, dtype=jnp.int32)
    idxs = jnp.arange(cfg.n_layers)

    def body(carry, inp):
        h, kc, vc = carry
        lp, st, idx = inp
        m, new_st = mamba_block(lp["mamba"],
                                rms_norm(h, lp["ln"], cfg.norm_eps), cfg,
                                state=st)
        h = h + m

        def with_attn(args):
            hh, kcc, vcc = args
            app = idx // cfg.attn_every
            ck = jax.lax.dynamic_index_in_dim(kcc, app, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(vcc, app, 0, keepdims=False)
            hh, (nk, nv) = _shared_block(params["shared"], hh, cfg, positions,
                                         cache=(ck, cv), cache_pos=pos)
            kcc = jax.lax.dynamic_update_index_in_dim(kcc, nk, app, 0)
            vcc = jax.lax.dynamic_update_index_in_dim(vcc, nv, app, 0)
            return hh, kcc, vcc

        h, kc, vc = jax.lax.cond(idx % cfg.attn_every == 0, with_attn,
                                 lambda a: a, (h, kc, vc))
        return (h, kc, vc), new_st

    (x, kc, vc), new_ssm = jax.lax.scan(
        body, (x, state["k"], state["v"]),
        (params["layers"], state["ssm"], idxs))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = linear(params["lm_head"], x)
    return logits, {"ssm": new_ssm, "k": kc, "v": vc}
