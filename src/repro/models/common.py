"""Architecture/shape configs and shared pure-JAX layers (pytree params)."""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // n_heads
    # attention
    window: Optional[int] = None   # sliding-window size (SWA archs)
    qkv_bias: bool = False
    rope_theta: float = 1e6
    use_rope: bool = True          # enc-dec uses learned absolute positions
    # mlp
    activation: str = "swiglu"     # swiglu | gelu | sq_relu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False
    residual_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner: int = 0
    ssm_kind: str = ""             # mamba1 | mamba2
    ssm_head_dim: int = 64
    # dtype of the materialized (B, Lc, d_inner, N) scan tensors -- the
    # memory-bound core of mamba1 (EXPERIMENTS.md Perf falcon-H3); combine
    # math upcasts per level, einsums accumulate f32.
    ssm_scan_dtype: Any = None     # None => float32
    # hybrid
    attn_every: int = 0            # shared attn+MLP block cadence (zamba2)
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # modality frontend stub
    frontend: str = ""             # "" | "patch" | "frames"
    frontend_dim: int = 0
    frontend_len: int = 256
    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM / SWA archs.)"""
        return self.family in ("ssm", "hybrid") or self.window is not None


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    """Pad the embedding table so it shards evenly over the model axis."""
    return ((vocab + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
    w = (w / math.sqrt(d_in)).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., L, n_heads, head_dim); positions: (..., L)."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., L, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def activation_fn(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "sq_relu":            # Nemotron-4 squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {"wi": init_linear(ks[0], cfg.d_model, d_ff, cfg.dtype),
                "wg": init_linear(ks[1], cfg.d_model, d_ff, cfg.dtype),
                "wo": init_linear(ks[2], d_ff, cfg.d_model, cfg.dtype)}
    return {"wi": init_linear(ks[0], cfg.d_model, d_ff, cfg.dtype),
            "wo": init_linear(ks[2], d_ff, cfg.d_model, cfg.dtype)}


def mlp(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.activation == "swiglu":
        return linear(p["wo"], jax.nn.silu(linear(p["wg"], x))
                      * linear(p["wi"], x))
    act = activation_fn(cfg.activation)
    return linear(p["wo"], act(linear(p["wi"], x)))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def chunked_ce_loss(x: jax.Array, labels: jax.Array, mask: jax.Array,
                    logits_fn, chunk: int = 1024) -> jax.Array:
    """Cross-entropy without materializing full-sequence logits.

    ``x``: (B, S, d) final hidden states; ``logits_fn(xc) -> (B, c, V)``.
    Scans over sequence-chunk *indices*, dynamic-slicing x in place (a
    stacked xs copy would replicate the hidden states; see dry-run notes),
    so the live logits tensor is (B, chunk, V) -- at 256k vocab x 4k seq the
    full tensor would be TBs.
    """
    from ..sharding.ctx import shard_hint
    b, s, _ = x.shape
    if s <= chunk or s % chunk:
        logits = logits_fn(x)
        return cross_entropy(logits, labels, mask)
    n = s // chunk
    x = shard_hint(x, ("pod", "data"), None, None)

    def body(carry, i):
        xc = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, 1)
        lc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, 1)
        mc = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, 1)
        logp = jax.nn.log_softmax(logits_fn(xc).astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0]
        num, den = carry
        return (num + (nll * mc).sum(), den + mc.sum()), None

    (num, den), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n))
    return num / jnp.maximum(den, 1)
