"""Unified model API: one protocol across dense/MoE/SSM/hybrid/enc-dec/VLM."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import encdec, hybrid, lm, ssm_lm
from .common import ArchConfig, Params, pad_vocab


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Params]
    loss_fn: Callable[[Params, Dict], jax.Array]
    apply_fn: Callable[[Params, Dict], jax.Array]          # full logits
    init_decode_state: Callable[..., Params]
    decode_step: Callable[..., Any]
    prefill_fn: Callable[[Params, Dict], jax.Array] = None  # last-token logits


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            init=lambda key: lm.init_lm(key, cfg),
            loss_fn=lambda p, b: lm.lm_loss(p, cfg, b),
            apply_fn=lambda p, b: lm.lm_apply(p, cfg, b["tokens"],
                                              b.get("frontend"),
                                              remat=False)[0],
            prefill_fn=lambda p, b: lm.lm_apply(p, cfg, b["tokens"],
                                                b.get("frontend"), remat=True,
                                                last_only=True)[0],
            init_decode_state=lambda p, bs, ms, frontend=None:
                lm.init_kv_cache(cfg, bs, ms),
            decode_step=lambda p, st, tok, pos:
                lm.lm_decode_step(p, cfg, st, tok, pos),
        )
    if cfg.family == "ssm":
        return Model(
            cfg=cfg,
            init=lambda key: ssm_lm.init_ssm_lm(key, cfg),
            loss_fn=lambda p, b: ssm_lm.ssm_lm_loss(p, cfg, b),
            apply_fn=lambda p, b: ssm_lm.ssm_lm_apply(p, cfg, b["tokens"],
                                                      remat=False)[0],
            prefill_fn=lambda p, b: ssm_lm.ssm_lm_apply(
                p, cfg, b["tokens"], remat=True, last_only=True)[0],
            init_decode_state=lambda p, bs, ms, frontend=None:
                ssm_lm.init_ssm_lm_state(cfg, bs),
            decode_step=lambda p, st, tok, pos:
                ssm_lm.ssm_lm_decode_step(p, cfg, st, tok, pos),
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key: hybrid.init_hybrid(key, cfg),
            loss_fn=lambda p, b: hybrid.hybrid_loss(p, cfg, b),
            apply_fn=lambda p, b: hybrid.hybrid_apply(p, cfg, b["tokens"],
                                                      remat=False)[0],
            prefill_fn=lambda p, b: hybrid.hybrid_apply(
                p, cfg, b["tokens"], remat=True, last_only=True)[0],
            init_decode_state=lambda p, bs, ms, frontend=None:
                hybrid.init_hybrid_state(cfg, bs, ms),
            decode_step=lambda p, st, tok, pos:
                hybrid.hybrid_decode_step(p, cfg, st, tok, pos),
        )
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            loss_fn=lambda p, b: encdec.encdec_loss(p, cfg, b),
            apply_fn=lambda p, b: encdec.decode_train(
                p, cfg, b["tokens"], encdec.encode(p, cfg, b["frontend"],
                                                   remat=False), remat=False),
            prefill_fn=lambda p, b: encdec.decode_train(
                p, cfg, b["tokens"],
                encdec.encode(p, cfg, b["frontend"], remat=True),
                remat=True, last_only=True),
            init_decode_state=lambda p, bs, ms, frontend=None:
                encdec.init_encdec_state(p, cfg, bs, ms, frontend),
            decode_step=lambda p, st, tok, pos:
                encdec.encdec_decode_step(p, cfg, st, tok, pos),
        )
    raise ValueError(cfg.family)


def param_count(cfg: ArchConfig) -> int:
    """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
    d, f, v = cfg.d_model, cfg.d_ff, pad_vocab(cfg.vocab_size)
    hd = cfg.hd
    attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
    mlp_p = d * f * (3 if cfg.activation == "swiglu" else 2)
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family in ("dense", "vlm"):
        per_layer = attn + mlp_p + 2 * d
        return cfg.n_layers * per_layer + emb
    if cfg.family == "moe":
        experts = cfg.n_experts * 3 * d * f + d * cfg.n_experts
        res = (3 * d * (cfg.residual_d_ff or f)
               if cfg.moe_dense_residual else 0)
        return cfg.n_layers * (attn + experts + res + 2 * d) + emb
    di, n = cfg.d_inner, cfg.ssm_state
    if cfg.family == "ssm":
        dt_rank = max(1, d // 16)
        per = (d * 2 * di + di * d + cfg.ssm_conv * di
               + di * (dt_rank + 2 * n) + dt_rank * di + di * n)
        return cfg.n_layers * per + emb
    if cfg.family == "hybrid":
        per = d * 2 * di + di * d + cfg.ssm_conv * di + di * 2 * n
        shared = attn + mlp_p
        return cfg.n_layers * per + shared + emb
    if cfg.family == "encdec":
        enc = cfg.enc_layers * (attn + mlp_p + 2 * d)
        dec = cfg.dec_layers * (2 * attn + mlp_p + 3 * d)
        return enc + dec + emb
    raise ValueError(cfg.family)


def active_param_count(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: top-k of experts)."""
    if cfg.family != "moe":
        return param_count(cfg)
    d, f = cfg.d_model, cfg.d_ff
    v = pad_vocab(cfg.vocab_size)
    hd = cfg.hd
    attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
    act_experts = cfg.top_k * 3 * d * f + d * cfg.n_experts
    res = 3 * d * (cfg.residual_d_ff or f) if cfg.moe_dense_residual else 0
    return cfg.n_layers * (attn + act_experts + res + 2 * d) + 2 * v * d


def model_flops(cfg: ArchConfig, tokens: int, kind: str = "train") -> float:
    """6*N_active*D (trains) or 2*N_active*D (inference) -- the roofline's
    MODEL_FLOPS numerator."""
    n = active_param_count(cfg)
    return (6.0 if kind == "train" else 2.0) * n * tokens
