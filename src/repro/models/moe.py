"""Top-k token-choice MoE with capacity-based local dispatch.

Two execution paths:

* **shard_map path** (active when a mesh is installed): tokens stay local to
  their data shard and dispatch into a *local* (E, C_loc, d) capacity buffer
  -- zero dispatch communication, because activations are replicated over the
  model axis.  Expert compute is expert-parallel over the model axis when E
  divides it (arctic-480b, 128e) and d_ff-tensor-parallel otherwise
  (mixtral-8x7b, 8e on a 16-way axis); both variants finish with ONE psum
  over the model axis that simultaneously sums expert-group contributions and
  completes the TP contraction.  This exists because GSPMD's scatter
  partitioner replicates the (T*k, d) dispatch gradient -- 60 GB/device at
  arctic scale -- no matter how the operands are hinted (EXPERIMENTS.md
  sect. Perf, iteration moe-1).

* **local path** (no mesh: unit tests, single device): the same dispatch
  arithmetic without collectives.

Dropped tokens (beyond per-shard capacity) fall into a discard row -- the
standard capacity-factor trade-off, surfaced by the Switch-style aux loss.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..sharding.ctx import current_mesh
from .common import ArchConfig, Params, init_linear, init_mlp, linear, mlp


def init_moe(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = d ** -0.5
    p: Params = {
        "router": init_linear(ks[0], d, e, jnp.float32),
        "wi": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale
               ).astype(cfg.dtype),
        "wg": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale
               ).astype(cfg.dtype),
        "wo": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
               * f ** -0.5).astype(cfg.dtype),
    }
    if cfg.moe_dense_residual:                        # arctic-480b
        p["residual"] = init_mlp(ks[4], cfg, cfg.residual_d_ff or cfg.d_ff)
    return p


def _capacity(tokens: int, cfg: ArchConfig) -> int:
    cap = int(cfg.capacity_factor * tokens * cfg.top_k
              / max(cfg.n_experts, 1))
    return max(8, ((cap + 127) // 128) * 128)


def _dispatch_compute_combine(xf, router_w, wi, wg, wo, cfg: ArchConfig,
                              shard_idx=None) -> Tuple[jax.Array, jax.Array]:
    """Local dispatch -> expert einsums -> local combine.

    xf: (T_loc, d); wi/wg: (E_any, d, f_any); wo: (E_any, f_any, d).
    Returns (partial out (T_loc, d), aux numerator) -- caller completes any
    cross-shard reduction.
    """
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, cfg)

    logits = xf.astype(jnp.float32) @ router_w                   # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss (local fractions).
    me = gates.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    eid = topi.reshape(-1)                                       # (T*k,)
    onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)
    pos = ((jnp.cumsum(onehot, axis=0) - 1) * onehot).max(axis=-1)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)

    buf = jnp.zeros((e, cap + 1, d), dtype=xf.dtype)
    src = jnp.repeat(xf, k, axis=0) * keep[:, None].astype(xf.dtype)
    buf = buf.at[eid, pos_c].add(src)[:, :cap]                   # (E, C, d)

    e_loc = wi.shape[0]
    if e_loc != e:               # expert-parallel: this shard's expert slice
        shard = shard_idx if shard_idx is not None else 0
        buf = jax.lax.dynamic_slice_in_dim(buf, shard * e_loc, e_loc, 0)

    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo)

    if e_loc != e:               # scatter expert-group results back to E rows
        full = jnp.zeros((e, cap, d), out_buf.dtype)
        shard = shard_idx if shard_idx is not None else 0
        out_buf = jax.lax.dynamic_update_slice_in_dim(
            full, out_buf, shard * e_loc, 0)

    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((e, 1, d), out_buf.dtype)], axis=1)
    gathered = out_buf[eid, pos_c]                               # (T*k, d)
    gathered = gathered * (topw.reshape(-1, 1).astype(xf.dtype)
                           * keep[:, None].astype(xf.dtype))
    return gathered.reshape(t, k, d).sum(axis=1), aux


def moe_ffn(p: Params, x: jax.Array, cfg: ArchConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    mesh = current_mesh()

    dp_size = 1
    if mesh is not None:
        dp_size = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                               if a in mesh.axis_names]))
    if (mesh is not None and "model" in mesh.axis_names
            and (b * s) % dp_size == 0 and (b * s) >= dp_size):
        from jax.experimental.shard_map import shard_map
        tp = mesh.shape["model"]
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        ep_mode = cfg.n_experts % tp == 0 and cfg.n_experts >= tp
        if ep_mode:
            wi_s = wg_s = P("model", None, None)
            wo_s = P("model", None, None)
        else:
            wi_s = wg_s = P(None, None, "model")
            wo_s = P(None, "model", None)

        def local(xl, rw, wi, wg, wo):
            shard = jax.lax.axis_index("model") if ep_mode else None
            out, aux = _dispatch_compute_combine(xl, rw, wi, wg, wo, cfg,
                                                 shard_idx=shard)
            # one psum finishes both the expert-group sum (EP) and the
            # d_ff-TP contraction (non-EP)
            out = jax.lax.psum(out, "model")
            aux = jax.lax.pmean(jax.lax.pmean(aux, "model"), dp)
            return out, aux

        out, aux = shard_map(
            local, mesh=mesh,
            in_specs=(P(dp, None), P(None, None), wi_s, wg_s, wo_s),
            out_specs=(P(dp, None), P()),
            check_rep=False,
        )(xf, p["router"]["w"], p["wi"], p["wg"], p["wo"])
    else:
        out, aux = _dispatch_compute_combine(
            xf, p["router"]["w"], p["wi"], p["wg"], p["wo"], cfg)

    out = out.reshape(b, s, d)
    if cfg.moe_dense_residual:
        out = out + mlp(p["residual"], x, cfg)
    return out, aux
