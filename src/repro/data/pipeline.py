"""Deterministic synthetic data pipeline with sharded, resumable batches.

Production framing without external data deps: batches are generated from a
counter-based PRNG (stateless -- batch i is a pure function of (seed, i)), so
(a) every data-parallel host materializes only its shard, (b) restart/resume
is exact (the checkpoint stores just the step counter), and (c) elastic
re-sharding onto a different mesh replays identical global batches.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.common import ArchConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticDataset:
    cfg: ArchConfig
    shape: ShapeConfig
    seed: int = 0

    def __post_init__(self):
        # Zipf-skewed unigram stream: entropy < ln(V), so the LM has a
        # learnable signal (uniform tokens would pin CE at its init value).
        v = self.cfg.vocab_size
        p = 1.0 / (np.arange(1, v, dtype=np.float64) + 8.0)
        self._probs = p / p.sum()

    def _zipf_tokens(self, rng: np.random.Generator, shape) -> np.ndarray:
        return (rng.choice(len(self._probs), size=shape, p=self._probs)
                .astype(np.int32) + 1)

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        """The full logical batch for ``step`` (host-sharded in practice)."""
        return self.host_batch(step, 0, 1)

    def host_batch(self, step: int, host_id: int, n_hosts: int
                   ) -> Dict[str, np.ndarray]:
        """This host's shard of batch ``step`` -- rows are split evenly."""
        cfg, shp = self.cfg, self.shape
        b = shp.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_id]))
        front_len = cfg.frontend_len if cfg.family == "vlm" else 0
        seq = shp.seq_len - front_len
        toks = self._zipf_tokens(rng, (b, seq))
        batch: Dict[str, np.ndarray] = {
            "tokens": toks,
            # next-token prediction labels; final position masked
            "labels": np.concatenate(
                [toks[:, 1:], np.full((b, 1), -1, np.int32)], axis=1),
        }
        if cfg.family == "vlm":
            batch["frontend"] = rng.standard_normal(
                (b, cfg.frontend_len, cfg.frontend_dim)).astype(np.float32)
        elif cfg.family == "encdec":
            batch["frontend"] = rng.standard_normal(
                (b, shp.seq_len, cfg.frontend_dim)).astype(np.float32)
        return batch


def make_batch_specs(cfg: ArchConfig, shape: ShapeConfig
                     ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs of the training batch (dry-run input_specs)."""
    front_len = cfg.frontend_len if cfg.family == "vlm" else 0
    seq = shape.seq_len - front_len
    b = shape.global_batch
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, seq), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    elif cfg.family == "encdec":
        specs["frontend"] = jax.ShapeDtypeStruct(
            (b, shape.seq_len, cfg.frontend_dim), jnp.float32)
    return specs
