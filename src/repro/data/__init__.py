from .pipeline import SyntheticDataset, make_batch_specs  # noqa: F401
