"""JAX-version aliases and the parametrized legacy-shim machinery.

Two kinds of compatibility live here:

* **JAX-version aliases** -- ``pltpu.TPUCompilerParams`` was renamed
  ``pltpu.CompilerParams``, and ``jax.experimental.shard_map`` graduated to
  ``jax.shard_map``, in newer JAX; kernels import the aliases from here so
  they run on both.

* **Legacy per-stencil entry points** -- the seed-era
  ``stencil{3,7,27}`` / ``stencil{3,7,27}_ref`` wrappers, built once by the
  ``_make_entry`` / ``_make_ref`` factories below (one parametrized body
  instead of three copy-pasted shim packages).  The historical import paths
  (``repro.kernels.stencil3`` etc., ``repro.kernels.stencil_engine.compat``,
  ``repro.kernels._stencil_common``) all re-export from this module.  The
  one deliberate behavior change (inherited from the engine migration):
  ``interpret`` defaults to ``None`` ("interpret only when no compiled
  Pallas backend exists"), so the same call site runs compiled on TPU and
  interpreted on CPU/GPU/CI.

The wrappers import the engine lazily (inside the traced body) so this
module stays import-cycle-free: ``stencil_engine.sharded`` imports
``shard_map`` from here while ``stencil_engine.compat`` imports the entry
points, and both directions must work whichever module loads first.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map  # noqa: F401


# One row per legacy entry point: registry name -> (name of the static
# block-size keyword the seed API used, weights-layout docstring).
_SHIMS = {
    "stencil3": ("block_rows", "Symmetric 3-point stencil along the last "
                               "axis; ``w = (w_edge, w_center)``."),
    "stencil7": ("block_i", "Symmetric 7-point stencil; "
                            "``w = (wc, wk, wj, wi)``."),
    "stencil27": ("block_i", "Symmetric 27-point stencil; ``w`` has shape "
                             "(2, 2, 2)."),
}

# exec template so each wrapper's *signature* carries the historical block
# keyword name (``block_rows`` vs ``block_i``) -- jax.jit resolves
# ``static_argnames`` against the inspected signature, so a generic
# ``**kwargs`` body would not preserve the seed API.
_ENTRY_SRC = '''\
def {name}(a, w, {blk}=None, interpret=None):
    """{doc}"""
    from .stencil_engine.ops import stencil_apply
    return stencil_apply(a, w, "{name}", block_i={blk}, interpret=interpret)
'''


def _make_entry(name: str, blk: str, doc: str):
    """Build the jitted legacy entry point ``name(a, w, <blk>=None,
    interpret=None)`` over the engine's ``stencil_apply``."""
    ns = {"__name__": __name__}
    exec(compile(_ENTRY_SRC.format(name=name, blk=blk, doc=doc),
                 f"<shim {name}>", "exec"), ns)
    fn = ns[name]
    fn.__module__ = __name__
    return functools.partial(jax.jit,
                             static_argnames=(blk, "interpret"))(fn)


def _make_ref(name: str):
    """Build the legacy oracle ``name_ref(a, w)`` over ``stencil_ref``."""
    def ref(a, w):
        from .stencil_engine.ref import stencil_ref
        return stencil_ref(a, w, name)
    ref.__name__ = ref.__qualname__ = f"{name}_ref"
    ref.__doc__ = (f"Pure-jnp oracle for the {name[len('stencil'):]}-point "
                   f"stencil (engine-backed).")
    return ref


stencil3 = _make_entry("stencil3", *_SHIMS["stencil3"])
stencil7 = _make_entry("stencil7", *_SHIMS["stencil7"])
stencil27 = _make_entry("stencil27", *_SHIMS["stencil27"])
stencil3_ref = _make_ref("stencil3")
stencil7_ref = _make_ref("stencil7")
stencil27_ref = _make_ref("stencil27")


# ``repro.kernels._stencil_common`` re-exports: resolved lazily (PEP 562)
# so importing this module never drags in -- or cycles with -- the engine.
_COMMON_REEXPORTS = {
    "pick_block_i": "repro.kernels.stencil_engine.autotune",
    "interior_mask": "repro.kernels.stencil_engine.common",
    "shifted_planes": "repro.kernels.stencil_engine.common",
    "stencil_pallas_call": "repro.kernels.stencil_engine.common",
}


def __getattr__(name: str):
    mod = _COMMON_REEXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
