"""JAX-version compatibility aliases.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams``, and
``jax.experimental.shard_map`` graduated to ``jax.shard_map``, in newer JAX;
kernels import the aliases from here so they run on both.
"""

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map  # noqa: F401
