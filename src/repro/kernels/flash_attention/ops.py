"""Jitted public entry point for the Pallas flash-attention kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams
from .kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jax.Array:
    """q: (B, H, Lq, D); k, v: (B, Hkv, Lk, D); returns (B, H, Lq, D)."""
    b, h, lq, dh = q.shape
    _, hkv, lk, _ = k.shape
    assert h % hkv == 0, "query heads must be a multiple of kv heads"
    group = h // hkv
    bq = min(block_q, lq)
    bk = min(block_k, lk)
    if lq % bq or lk % bk:
        raise ValueError(f"blocks ({bq},{bk}) must divide (Lq,Lk)=({lq},{lk})")
    nk = lk // bk
    grid = (b, h, lq // bq, nk)
    kernel = functools.partial(
        flash_attention_kernel, scale=1.0 / (dh ** 0.5), causal=causal,
        window=window, q_offset=q_offset, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
