"""Pallas TPU flash-attention kernel (blocked online softmax).

Streaming form of attention in the paper's sense: the key/value sequence is
streamed through VMEM in blocks along a sequential grid axis while the
(m, l, acc) running statistics persist in VMEM scratch -- the same
persistent-state steady-state loop as the PPC450 stream kernels.  Supports
GQA (kv-head block selected by query head in the index map), causal masking
and sliding windows (banded attention: the 1-D stencil access pattern).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                           *, scale: float, causal: bool, window: int | None,
                           q_offset: int, bq: int, bk: int, nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (Bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (Bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (Bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (Bq, Bk)

    iq = pl.program_id(2)
    qi = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    ki = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= qi - ki < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                          # (Bq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finish():
        # fully-masked rows (outside the window) produce l == 0; emit zeros
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)
