"""Pure-jnp oracle for (GQA / causal / sliding-window) attention."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True,
                  window: Optional[int] = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """q: (B, H, Lq, D); k, v: (B, Hkv, Lk, D).  H % Hkv == 0.

    ``q_offset``: global position of q[.., 0, .] relative to k (decode step:
    q_offset = Lk - Lq).  ``window``: only attend to keys within the last
    ``window`` positions (Mistral/StarCoder2-style sliding window).
    """
    b, h, lq, dh = q.shape
    hkv = k.shape[1]
    group = h // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / jnp.sqrt(float(dh))
    qi = jnp.arange(lq)[:, None] + q_offset
    ki = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((lq, k.shape[2]), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= qi - ki < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)
