"""Shared stencil-engine plumbing: budgets, divisors, legacy Pallas helpers.

Engine-wide constants and small helpers live here so the cost model, the
block pickers, and the benchmarks agree on one source of truth:

* :data:`DEFAULT_VMEM_BUDGET` -- the single VMEM residency budget every
  block/tile chooser defaults to (previously ``8 << 20`` in
  ``autotune_blocks`` and a stray ``4 << 20`` in ``pick_block_rows``).
* :func:`divisors` -- sorted divisors of an int (block-size candidates).

The rest are the original halo/tiling utilities the MXU banded-matmul
kernel still imports (``shifted_planes``, ``interior_mask``,
``stencil_pallas_call``), re-exported by ``repro.kernels._stencil_common``
for backward compatibility; the engine's own kernels live in
:mod:`.kernel`/:mod:`.ops`.
"""

from __future__ import annotations

import functools
from typing import Callable, List

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One documented VMEM residency budget (bytes) for every engine block/tile
# chooser: staged IO tiles + working strips + streaming scratch must fit
# inside it.  ~half a TPU core's VMEM, leaving headroom for Pallas's own
# double-buffering of the staged operands.
DEFAULT_VMEM_BUDGET = 8 << 20


def divisors(x: int) -> List[int]:
    """All divisors of ``x`` in ascending order (block-size candidates)."""
    small, large = [], []
    d = 1
    while d * d <= x:
        if x % d == 0:
            small.append(d)
            if d != x // d:
                large.append(x // d)
        d += 1
    return small + large[::-1]


def shifted_planes(prev_blk: jax.Array, cur: jax.Array, nxt_blk: jax.Array):
    """Rows (i-1, i, i+1) for every row i of the current block."""
    up = jnp.concatenate([prev_blk[-1:], cur[:-1]], axis=0)
    down = jnp.concatenate([cur[1:], nxt_blk[:1]], axis=0)
    return up, cur, down


def interior_mask(bi: int, n: int, p: int, i_blk, m_total: int) -> jax.Array:
    """True on interior points of the global (M, N, P) grid for this block."""
    gi = i_blk * bi + jax.lax.broadcasted_iota(jnp.int32, (bi, n, p), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (bi, n, p), 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, (bi, n, p), 2)
    return ((gi > 0) & (gi < m_total - 1)
            & (jj > 0) & (jj < n - 1)
            & (kk > 0) & (kk < p - 1))


def stencil_pallas_call(kernel_body: Callable, a: jax.Array, weights: jax.Array,
                        bi: int, interpret: bool) -> jax.Array:
    """Common pallas_call wiring: 3 shifted views of ``a`` + weights in SMEM."""
    m, n, p = a.shape
    if m % bi != 0:
        raise ValueError(f"block size {bi} must divide M={m}")
    nblk = m // bi
    block = (bi, n, p)
    grid = (nblk,)
    in_specs = [
        pl.BlockSpec(block, lambda i: (jnp.maximum(i - 1, 0), 0, 0)),
        pl.BlockSpec(block, lambda i: (i, 0, 0)),
        pl.BlockSpec(block, functools.partial(
            lambda i, top: (jnp.minimum(i + 1, top), 0, 0), top=nblk - 1)),
        pl.BlockSpec(weights.shape, lambda i: tuple(0 for _ in weights.shape)),
    ]
    return pl.pallas_call(
        functools.partial(kernel_body, bi=bi, m_total=m),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(block, lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, a, a, weights)
