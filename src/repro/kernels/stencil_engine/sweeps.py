"""Time integration: run ``s`` stencil sweeps with temporal wavefront tiling.

A time integration of ``s`` sweeps has three executions with identical
results (bit-exact on integer-valued data -- every mode runs the same
per-application op walk, only the blocking through time differs):

* **chained** -- ``s`` single-sweep :func:`~.ops.stencil_apply` calls: one
  full HBM round-trip per sweep, ``2 * itemsize`` modeled bytes/point each
  (the bit-exact baseline and the only option for shapes no fused window
  fits);
* **fused** -- one call with ``sweeps=s``: ``2 * itemsize / s`` bytes/point,
  but the rotating window and the VPU-redundant strip both deepen with the
  ``radius * s * sweep_apps`` halo, which is what stops large ``s``;
* **wavefront** (this module's tentpole) -- ``s`` pipelined sweep stages
  ride *one* pass over the i-blocks, stage ``t`` consuming planes stage
  ``t-1`` produced one block earlier, so each input plane is fetched from
  HBM once per ``s`` sweeps (``2 * itemsize / s`` bytes/point like fused)
  while every stage carries only the *single-sweep* halo
  ``radius * sweep_apps``.

:func:`stencil_wavefront` is the jitted wavefront entry point;
:func:`stencil_sweep_driver` is the mode dispatcher, racing the three
executions per ``(spec, shape, s)`` on the sweeps-aware roofline
(:func:`~.autotune.autotune_sweeps`) when ``mode="auto"``.

A periodic i axis is handled by caller-side pre-extension: the wavefront
kernel walks i-blocks monotonically and cannot wrap, so the driver
materializes the ``radius * sweep_apps * s`` wrapped rows on each side in
HBM, runs the pipeline with external-halo geometry, and crops -- the same
contract the sharded deep-halo exchange provides via ppermute.
"""

from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .autotune import SWEEP_MODES, autotune_sweeps, wavefront_block_i
from .kernel import acc_dtype_for
from .ops import call_3d_wavefront, resolve_interpret, stencil_apply
from .plan import compile_plan
from .spec import StencilSpec, get_stencil


@functools.partial(jax.jit,
                   static_argnames=("stencil", "block_i", "sweeps", "plan",
                                    "bc", "interpret"))
def stencil_wavefront(a: jax.Array, w: jax.Array,
                      stencil: Union[str, int, StencilSpec] = "stencil27",
                      block_i: Optional[int] = None, sweeps: int = 1,
                      plan: str = "auto", bc=None,
                      interpret: Optional[bool] = None) -> jax.Array:
    """``sweeps`` applications through the temporal-wavefront pipeline.

    Bit-exact vs ``sweeps`` chained :func:`~.ops.stencil_apply` calls (and
    the fused ``sweeps=s`` call) on integer-valued data: each pipeline
    stage runs the same compiled plan at single-sweep halo depth, so the
    op walk per application is identical -- only the HBM schedule changes.

    Volumetric constant-coefficient specs only, untiled (full-N) blocks;
    ``block_i`` defaults to the wavefront cost model
    (:func:`~.autotune.wavefront_block_i`) and must divide M (the
    periodic-extended M for a periodic i axis).  ``bc``/``plan``/
    ``interpret`` as in :func:`~.ops.stencil_apply`.
    """
    if sweeps < 1:
        raise ValueError(f"sweeps must be >= 1, got {sweeps}")
    spec = get_stencil(stencil)
    if spec.guard != "off":
        spec = spec.with_guard("off")   # guards never reach the trace
    if bc is not None:
        spec = spec.with_bc(bc)
    if spec.ndim != 3:
        raise ValueError(f"{spec.name}: the wavefront pipeline is "
                         f"volumetric (ndim=3); use the fused or chained "
                         f"mode for k-only specs")
    cplan = compile_plan(spec, plan)
    acc = acc_dtype_for(a.dtype)
    if a.ndim < 3:
        raise ValueError(f"{spec.name}: need (..., M, N, P), got {a.shape}")
    m, n, p = a.shape[-3:]
    wf = spec.canon_weights(w).astype(acc)
    batch = int(np.prod(a.shape[:-3])) if a.ndim > 3 else 1
    a4 = a.reshape(batch, m, n, p)
    interp = resolve_interpret(interpret)

    # Periodic i: materialize the wrapped deep halo in HBM once per call
    # (the pipeline walks i monotonically), run with external-halo
    # geometry, crop the interior back out.
    h = spec.radius[0] * spec.sweep_apps * sweeps
    periodic_i = spec.bc[0][0].kind == "periodic"
    if periodic_i and h:
        if h > m:
            raise ValueError(
                f"{spec.name}: periodic wavefront needs the deep halo "
                f"radius*sweep_apps*sweeps = {h} <= M = {m}; use the "
                f"fused or chained mode")
        a4 = jnp.concatenate([a4[:, m - h:], a4, a4[:, :h]], axis=1)
        geom = jnp.array([-h, m], jnp.int32)
    else:
        geom = jnp.array([0, m], jnp.int32)
    m_run = a4.shape[1]
    bi = block_i
    if bi is None:
        bi = wavefront_block_i(m_run, n, p, a.dtype.itemsize, sweeps, cplan)
    out = call_3d_wavefront(a4, wf, geom, cplan, bi, sweeps, interp)
    if periodic_i and h:
        out = out[:, h:h + m]
    return out.reshape(a.shape)


def stencil_sweep_driver(a: jax.Array, w: jax.Array,
                         stencil: Union[str, int, StencilSpec] = "stencil27",
                         sweeps: int = 1, mode: str = "auto",
                         block_i: Optional[int] = None,
                         block_j: Optional[int] = None, plan: str = "auto",
                         path: str = "auto", bc=None,
                         interpret: Optional[bool] = None,
                         guard=None) -> jax.Array:
    """Run ``sweeps`` applications under the modeled-best execution mode.

    ``mode="auto"`` races (fused, wavefront, chained) per
    ``(spec, shape, s)`` via :func:`~.autotune.autotune_sweeps` --
    feasibility first, then fewest modeled HBM bytes/point, then modeled
    time -- and dispatches; ``"fused"``/``"wavefront"``/``"chained"`` pin
    the mode (fused is the bit-exact escape hatch, chained the per-sweep
    round-trip baseline).  All modes agree bit-exactly on integer-valued
    data.  Not itself jitted (the dispatch is static per shape); the
    jitted executors underneath carry the usual caching.

    ``guard`` selects runtime verification + the degradation ladder exactly
    as in :func:`~.ops.stencil_apply`: ``None`` defers to the spec's own
    ``guard`` field, ``"off"`` (the default everywhere) dispatches to the
    historical byte-identical executors, anything else checks the selected
    mode's result and walks the ladder (wavefront -> fused -> chained ->
    stream -> replicate -> oracle) on failure, blacklisting rungs whose
    kernels raise (see :mod:`.guard` and ``last_guard_report()``).
    """
    if mode not in SWEEP_MODES:
        raise ValueError(f"unknown sweep mode {mode!r}; expected one of "
                         f"{SWEEP_MODES}")
    if sweeps < 1:
        raise ValueError(f"sweeps must be >= 1, got {sweeps}")
    spec = get_stencil(stencil)
    policy_src = spec.guard if guard is None else guard
    if policy_src is not None and policy_src != "off":
        from .guard import as_guard, guarded_driver
        policy = as_guard(policy_src)
        if policy is not None:
            gspec = spec.with_bc(bc) if bc is not None else spec
            return guarded_driver(a, w, gspec, policy, sweeps=sweeps,
                                  mode=mode, block_i=block_i,
                                  block_j=block_j, plan=plan, path=path,
                                  interpret=interpret)
    if bc is not None:
        spec = spec.with_bc(bc)

    def fused():
        return stencil_apply(a, w, spec, block_i=block_i, block_j=block_j,
                             plan=plan, sweeps=sweeps, path=path,
                             interpret=interpret)

    def chained():
        u = a
        for _ in range(sweeps):
            u = stencil_apply(u, w, spec, block_i=block_i, block_j=block_j,
                              plan=plan, sweeps=1, path=path,
                              interpret=interpret)
        return u

    def wavefront(bi):
        return stencil_wavefront(a, w, spec, block_i=bi, sweeps=sweeps,
                                 plan=plan, interpret=interpret)

    if mode == "fused" or sweeps == 1 and mode == "auto":
        return fused()
    if mode == "chained":
        return chained()
    if mode == "wavefront":
        return wavefront(block_i)

    # mode == "auto", sweeps > 1: race on the sweeps-aware roofline.
    if spec.ndim != 3:
        return fused()
    if a.ndim < 3:
        raise ValueError(f"{spec.name}: need (..., M, N, P), got {a.shape}")
    m, n, p = a.shape[-3:]
    cplan = compile_plan(spec, plan)
    sel = autotune_sweeps(m, n, p, a.dtype.itemsize, sweeps, cplan,
                          block_j=block_j, path=path)
    if sel.mode == "wavefront":
        return wavefront(block_i if block_i is not None else sel.block_i)
    if sel.mode == "chained":
        return chained()
    return fused()
