"""Seedable fault injection: prove every guard detector against a real fault.

A guard nobody has ever seen fire is a guard that does not work.  This
module manufactures the fault classes the PPC450 paper's era worried about
(memory bit flips, stale/corrupt exchange buffers, miscompiled variants)
inside the engine's own execution machinery, so the tests can demonstrate
each :mod:`.guard` detector catching -- and the degradation ladder
recovering from -- the exact failure it claims to cover:

========================  =======================================  ==========
injector                  where the fault lives                    detector
========================  =======================================  ==========
:class:`BitFlipPlane`     an exponent bit XOR'd across one output  invariant
                          i-plane (huge-but-finite drift)
:class:`NaNWindow`        a NaN window written into the output     nan
:class:`NaNScratchWindow` a NaN plane poisoned *inside* the        nan
                          stream kernel's VMEM rotating window
                          (via the static ``_fault`` argument)
:class:`CorruptHalo`      the ppermute'd halo slabs of the         invariant /
                          sharded exchange (garbage / truncation   nan / oracle
                          -to-zeros / NaN), or the edge planes of
                          an unsharded output
:class:`RaisingCandidate` an exception raised from the rung        exception
                          runner (a candidate that dies at         ladder +
                          compile/run time)                        blacklist
========================  =======================================  ==========

Injectors are seeded (:class:`numpy.random.Generator`), rung-filtered
(default: every rung but the oracle -- the verifier itself stays honest),
and budgeted (``fires``), so a test can let the fault hit the fast path and
then watch the ladder recover on a clean lower rung.  Install them with
:func:`inject`::

    with inject(NaNWindow(seed=7)) as (inj,):
        out = stencil_apply(a, w, "stencil27", guard="full")
    assert inj.fired == 1                      # the fault really happened
    report = last_guard_report()               # ...and the guard saw it

Nothing here is imported by the engine's hot paths; installing zero
injectors leaves every hook list empty and the traced programs untouched.
"""

from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import guard as _guard
from . import sharded as _sharded
from .kernel import KernelFault

# Every rung a fault may target; the oracle is deliberately absent from the
# default so the ladder's last resort stays trustworthy.
FAULT_RUNGS = ("wavefront", "fused", "chained", "stream", "replicate")


class FaultInjector:
    """Base class: seeded RNG, rung filter, fire budget, and a log.

    Subclasses override one of the three hook slots: ``apply_out(out, ctx)``
    (corrupt a produced output), ``on_run(ctx)`` (raise before a rung runs),
    or ``kernel_fault(ctx)`` (return a :class:`~.kernel.KernelFault` to bake
    into the rung's traced kernel)."""

    def __init__(self, seed: int = 0,
                 rungs: Sequence[str] = FAULT_RUNGS,
                 fires: int = 1):
        unknown = set(rungs) - set(_guard.LADDER)
        if unknown:
            raise ValueError(f"unknown rungs {sorted(unknown)}; expected a "
                             f"subset of {_guard.LADDER}")
        self.rng = np.random.default_rng(seed)
        self.rungs = tuple(rungs)
        self.fires = int(fires)
        self.fired = 0
        self.log: list = []

    def _arm(self, ctx) -> bool:
        return ctx.rung in self.rungs and self.fired < self.fires

    def _record(self, ctx, **extra) -> None:
        self.fired += 1
        self.log.append({"injector": type(self).__name__, "rung": ctx.rung,
                         "attempt": ctx.attempt, "entry": ctx.entry,
                         **extra})

    # Hook slots -- default no-ops.
    def apply_out(self, out, ctx):
        return out

    def on_run(self, ctx) -> None:
        return None

    def kernel_fault(self, ctx) -> Optional[KernelFault]:
        return None


class BitFlipPlane(FaultInjector):
    """XOR one exponent bit across one output i-plane: every value on the
    plane scales by a power of two -- large, *finite* drift that sails
    through the NaN screen and trips the weight-sum invariant.

    ``bit=None`` picks a mid-exponent bit for the dtype (mantissa + 3), so
    small integer-valued fields never flip into Inf/NaN territory."""

    def __init__(self, seed: int = 0, plane: Optional[int] = None,
                 bit: Optional[int] = None, **kw):
        super().__init__(seed=seed, **kw)
        self.plane = plane
        self.bit = bit

    def apply_out(self, out, ctx):
        if not self._arm(ctx) or out.ndim < 3:
            return out
        arr = np.array(out)
        if not np.issubdtype(arr.dtype, np.floating):
            return out
        mant = np.finfo(arr.dtype).nmant
        bit = self.bit if self.bit is not None else mant + 3
        m = arr.shape[-3]
        pi = (int(self.plane) % m if self.plane is not None
              else int(self.rng.integers(m)))
        u = arr.view(np.dtype(f"uint{arr.dtype.itemsize * 8}"))
        u[..., pi, :, :] ^= np.asarray(1 << bit, u.dtype)
        self._record(ctx, plane=pi, bit=bit)
        return jnp.asarray(arr)


class NaNWindow(FaultInjector):
    """Write a NaN window into the output (a poisoned store): the NaN/Inf
    screen's canonical prey."""

    def __init__(self, seed: int = 0, plane: Optional[int] = None,
                 width: int = 2, **kw):
        super().__init__(seed=seed, **kw)
        self.plane = plane
        self.width = width

    def apply_out(self, out, ctx):
        if not self._arm(ctx) or out.ndim < 3:
            return out
        arr = np.array(out)
        if not np.issubdtype(arr.dtype, np.floating):
            return out
        m = arr.shape[-3]
        pi = (int(self.plane) % m if self.plane is not None
              else int(self.rng.integers(m)))
        w = max(1, self.width)
        arr[..., pi, :w, :w] = np.nan
        self._record(ctx, plane=pi, width=w)
        return jnp.asarray(arr)


class NaNScratchWindow(FaultInjector):
    """Poison a plane of the stream kernel's rotating VMEM scratch window
    *inside* the traced kernel (see ``stencil3d_stream_kernel``'s ``fault``
    hook): the NaN is manufactured where a real SEU in kernel-resident
    state would live, then propagates through the sweeps into the output,
    where the NaN screen catches it.  Only the streaming path has the
    scratch window -- the replicate rung runs clean, which is exactly the
    recovery the ladder demonstrates."""

    def __init__(self, seed: int = 0, plane: Optional[int] = None, **kw):
        super().__init__(seed=seed, **kw)
        self.plane = plane

    def kernel_fault(self, ctx) -> Optional[KernelFault]:
        if not self._arm(ctx) or ctx.rung == "replicate":
            return None
        pi = (int(self.plane) if self.plane is not None
              else int(self.rng.integers(1 << 16)))
        self._record(ctx, plane=pi)
        return KernelFault(kind="nan_scratch", plane=pi)


class CorruptHalo(FaultInjector):
    """Corrupt the halo data a rung consumes.

    Sharded (``sharded=True``, the default): installs the
    :func:`~.sharded.set_halo_fault` hook, so the ppermute'd lo/hi slabs are
    corrupted inside the traced shard_map body -- the fault is in the
    exchanged bytes themselves, covering the deep-halo ring/chain exchange.
    ``mode``: ``"garbage"`` scales the slabs by a huge finite factor
    (invariant detector), ``"truncate"`` zeroes them as a short/stale
    message would (invariant / oracle detector -- the wrap rows silently
    vanish), ``"nan"`` poisons them (NaN screen).  ``axes`` filters which
    domain axes' exchanges are corrupted (default: all three) -- the
    multi-axis grid executor labels every exchange ``"i"`` / ``"j"`` /
    ``"k"``, so ``axes=("j",)`` poisons only the j-face ppermutes and
    leaves the i/k exchanges clean.  The traced hook fires on
    every sharded rung while installed; the ladder recovers by leaving the
    sharded path for the single-device rungs, which never touch the
    exchange.

    Unsharded: an output hook corrupting the ``halo`` edge i-planes, the
    single-device analogue of a bad exchange."""

    MODES = ("garbage", "truncate", "nan")
    AXES = ("i", "j", "k")

    def __init__(self, seed: int = 0, mode: str = "garbage",
                 sharded: bool = True, halo: int = 1,
                 axes: Sequence[str] = AXES, **kw):
        super().__init__(seed=seed, **kw)
        if mode not in self.MODES:
            raise ValueError(f"unknown CorruptHalo mode {mode!r}; expected "
                             f"one of {self.MODES}")
        bad_axes = set(axes) - set(self.AXES)
        if bad_axes:
            raise ValueError(f"unknown CorruptHalo axes {sorted(bad_axes)}; "
                             f"expected a subset of {self.AXES}")
        self.mode = mode
        self.sharded = sharded
        self.halo = max(1, halo)
        self.axes = tuple(axes)

    def _corrupt(self, x):
        if self.mode == "garbage":
            return x * jnp.asarray(2.0 ** 60, x.dtype) + jnp.asarray(
                1.0, x.dtype)
        if self.mode == "truncate":
            return jnp.zeros_like(x)
        return jnp.full_like(x, jnp.nan)

    def halo_fault(self, lo, hi, axis: str = "i") -> Tuple:
        # Traced once into the cached shard_map program; count the install,
        # not the (untraceable) per-call executions.  ``axis`` names the
        # domain axis whose exchange carried the slabs ("i"/"j"/"k").
        if axis not in self.axes:
            return lo, hi
        return self._corrupt(lo), self._corrupt(hi)

    def apply_out(self, out, ctx):
        if self.sharded or not self._arm(ctx) or out.ndim < 3:
            return out
        arr = np.array(out)
        if not np.issubdtype(arr.dtype, np.floating):
            return out
        h = min(self.halo, arr.shape[-3])
        bad = {"garbage": np.asarray(2.0 ** 60, arr.dtype),
               "truncate": np.asarray(0.0, arr.dtype),
               "nan": np.asarray(np.nan, arr.dtype)}[self.mode]
        arr[..., :h, :, :] = (arr[..., :h, :, :] * bad + 1.0
                              if self.mode == "garbage" else bad)
        self._record(ctx, mode=self.mode, halo=h)
        return jnp.asarray(arr)


class RaisingCandidate(FaultInjector):
    """A candidate that dies at compile/run time: raises from the rung
    runner, driving the exception arm of the ladder -- retry, demote,
    and blacklist the rung in :mod:`.autotune`."""

    def __init__(self, seed: int = 0, exc: type = RuntimeError,
                 message: str = "injected candidate failure", **kw):
        kw.setdefault("fires", 10 ** 9)   # raise on retry too, by default
        super().__init__(seed=seed, **kw)
        self.exc = exc
        self.message = message

    def on_run(self, ctx) -> None:
        if not self._arm(ctx):
            return
        self._record(ctx)
        raise self.exc(f"{self.message} [rung={ctx.rung}, "
                       f"attempt={ctx.attempt}]")


@contextlib.contextmanager
def inject(*injectors: FaultInjector):
    """Install ``injectors`` into the guard's fault hooks (and the sharded
    halo-exchange hook for sharded :class:`CorruptHalo`) for the dynamic
    extent of the block; always uninstalls, even on error.  Yields the
    injectors so tests can assert on ``fired`` / ``log``."""
    out_hooks = [inj.apply_out for inj in injectors]
    run_hooks = [inj.on_run for inj in injectors]
    kern_hooks = [inj.kernel_fault for inj in injectors]
    halo = [inj for inj in injectors
            if isinstance(inj, CorruptHalo) and inj.sharded]
    if len(halo) > 1:
        raise ValueError("at most one sharded CorruptHalo at a time")
    _guard._OUT_HOOKS.extend(out_hooks)
    _guard._RUN_HOOKS.extend(run_hooks)
    _guard._KERNEL_HOOKS.extend(kern_hooks)
    if halo:
        _sharded.set_halo_fault(halo[0].halo_fault)
        halo[0].fired += 1
        halo[0].log.append({"injector": "CorruptHalo", "mode": halo[0].mode,
                            "installed": True})
    try:
        yield injectors
    finally:
        for h in out_hooks:
            _guard._OUT_HOOKS.remove(h)
        for h in run_hooks:
            _guard._RUN_HOOKS.remove(h)
        for h in kern_hooks:
            _guard._KERNEL_HOOKS.remove(h)
        if halo:
            _sharded.set_halo_fault(None)
