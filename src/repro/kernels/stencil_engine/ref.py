"""jnp oracle for every engine stencil (f64-capable reference path).

Executes the same compiled plan (:mod:`.plan`) as the Pallas kernel, with
the same shift primitive and the same accumulation dtype rules -- so for any
given ``plan`` kind the kernel and this reference are bit-identical in f64
(whatever the blocking, j-tiled or not), and in f32/bf16 they differ only by
block-boundary-free rounding noise.  Different plan kinds reassociate the
tap sum and therefore agree only to rounding in floating point (exactly, on
integer-valued data).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import acc_dtype_for
from .plan import StencilPlan, compile_plan, execute_plan
from .spec import StencilSpec, get_stencil


def _interior_mask(shape, ndim: int) -> jax.Array:
    mask = jnp.ones(shape, bool)
    axes = range(-ndim, 0)
    for ax in axes:
        idx = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) + ax)
        mask = mask & (idx > 0) & (idx < shape[ax] - 1)
    return mask


def apply_plan_once(u: jax.Array, w: jax.Array,
                    cplan: StencilPlan) -> jax.Array:
    """One Dirichlet-masked application of the planned operator, in
    ``u.dtype``."""
    mask = _interior_mask(u.shape, cplan.spec.ndim)
    return jnp.where(mask, execute_plan(cplan, u, w), 0)


def apply_spec_once(u: jax.Array, w: jax.Array, spec: StencilSpec,
                    plan: str = "auto") -> jax.Array:
    """One Dirichlet-masked application of the operator, in ``u.dtype``."""
    return apply_plan_once(u, w, compile_plan(spec, plan))


@functools.partial(jax.jit, static_argnames=("stencil", "sweeps", "plan"))
def stencil_ref(a: jax.Array, w: jax.Array, stencil="stencil27",
                sweeps: int = 1, plan: str = "auto") -> jax.Array:
    """Reference for ``stencil_apply``: ``sweeps`` Jacobi applications of the
    named (or ad-hoc) spec, Dirichlet boundary zeroed each sweep, under the
    same compiled ``plan`` as the kernel.

    Jitted so eager callers see the same XLA rounding (FMA contraction) as
    the Pallas kernel -- that's what makes the f64 parity bit-exact."""
    spec = get_stencil(stencil)
    if a.ndim < spec.ndim:
        raise ValueError(f"{spec.name}: input rank {a.ndim} < {spec.ndim}")
    cplan = compile_plan(spec, plan)
    acc = acc_dtype_for(a.dtype)
    u = a.astype(acc)
    wf = spec.canon_weights(w).astype(acc)
    for _ in range(sweeps):
        u = apply_plan_once(u, wf, cplan)
    return u.astype(a.dtype)
