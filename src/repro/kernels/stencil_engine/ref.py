"""jnp oracle for every engine stencil (f64-capable reference path).

Executes the same compiled plan (:mod:`.plan`) as the Pallas kernel, with
the same shift primitive and the same accumulation dtype rules -- so for any
given ``plan`` kind the kernel and this reference are bit-identical in f64
(whatever the blocking, j-tiled or not), and in f32/bf16 they differ only by
block-boundary-free rounding noise.  Different plan kinds reassociate the
tap sum and therefore agree only to rounding in floating point (exactly, on
integer-valued data).

Boundary conditions are realized ``np.pad``-style: each sweep pads the
field by ``radius`` per axis under the per-axis-side pad mode (``clamp`` ->
zeros, ``periodic`` -> ``wrap``, ``dirichlet`` -> ``constant`` at the ghost
value, ``neumann`` -> ``symmetric``), axes in i, j, k order (so at ghost
*corners* the later-padded axis wins -- the same convention the kernel's
fill order and in-shift fills produce), runs the plan with plain zero-fill
shifts on the padded field, crops the centre, and zeroes the one-point ring
of any remaining ``clamp`` sides.  The all-clamp default skips the pad
entirely and keeps the historical masked-execution graph byte-for-byte.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import acc_dtype_for, bc_all_clamp
from .plan import StencilPlan, compile_plan, execute_plan
from .spec import StencilSpec, get_stencil


def _interior_mask(shape, ndim: int) -> jax.Array:
    mask = jnp.ones(shape, bool)
    axes = range(-ndim, 0)
    for ax in axes:
        idx = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) + ax)
        mask = mask & (idx > 0) & (idx < shape[ax] - 1)
    return mask


_PAD_MODE = {"clamp": "constant", "periodic": "wrap",
             "neumann": "symmetric"}


def _pad_side(u: jax.Array, axis: int, lo_w: int, hi_w: int, bc) -> jax.Array:
    if lo_w == 0 and hi_w == 0:
        return u
    pw = [(0, 0)] * u.ndim
    pw[axis] = (lo_w, hi_w)
    if bc.kind == "dirichlet":
        return jnp.pad(u, pw, mode="constant",
                       constant_values=jnp.asarray(bc.value, u.dtype))
    return jnp.pad(u, pw, mode=_PAD_MODE[bc.kind])


def pad_bc(u: jax.Array, spec: StencilSpec) -> jax.Array:
    """One ``np.pad``-equivalent ghost extension of the trailing ``ndim``
    axes by ``radius`` per side, per-axis-side modes, axes in i, j, k order
    (a periodic pair pads in one ``wrap`` call; mixed-mode axes pad lo then
    hi -- each one-sided pad reads only its own edge, so the order within
    an axis doesn't matter)."""
    for ax in range(3 - spec.ndim, 3):
        r = spec.radius[ax]
        if r == 0:
            continue
        axis = u.ndim - 3 + ax
        lo, hi = spec.bc[ax]
        if lo.kind == "periodic":           # validated paired
            u = _pad_side(u, axis, r, r, lo)
        else:
            u = _pad_side(u, axis, r, 0, lo)
            u = _pad_side(u, axis, 0, r, hi)
    return u


def _clamp_ring_mask(shape, spec: StencilSpec, axes=None):
    """Boolean mask zeroing the one-point output ring of every clamp side
    (restricted to ``axes`` -- spec axis indices -- when given); ``None``
    when no selected side is clamp."""
    mask = None
    for ax in (range(3 - spec.ndim, 3) if axes is None else axes):
        axis = len(shape) - 3 + ax
        lo, hi = spec.bc[ax]
        if lo.kind != "clamp" and hi.kind != "clamp":
            continue
        idx = jax.lax.broadcasted_iota(jnp.int32, shape, axis)
        if lo.kind == "clamp":
            t = idx > 0
            mask = t if mask is None else mask & t
        if hi.kind == "clamp":
            t = idx < shape[axis] - 1
            mask = t if mask is None else mask & t
    return mask


def apply_plan_once(u: jax.Array, w: jax.Array,
                    cplan: StencilPlan) -> jax.Array:
    """One BC-padded application of the planned operator, in ``u.dtype``.

    Variable-coefficient specs (``w`` canonicalized to ``(n_weights,
    *domain)``) have their coefficient planes zero-extended to the padded
    field's shape: coefficients are evaluated at the *output* point, and
    every ghost-position output is cropped (and re-padded from fresh ghosts
    next sweep), so the extension value is never observed."""
    spec = cplan.spec
    if bc_all_clamp(spec.bc):
        # historical semantics, historical graph: masked execution on the
        # unpadded field (zero-fill shifts ARE the clamp ghosts)
        mask = _interior_mask(u.shape, spec.ndim)
        return jnp.where(mask, execute_plan(cplan, u, w), 0)
    up = pad_bc(u, spec)
    wp = w
    if spec.coef == "var":
        pw = [(0, 0)] + [(spec.radius[ax], spec.radius[ax])
                         for ax in range(3 - spec.ndim, 3)]
        wp = jnp.pad(w, pw)
    v = execute_plan(cplan, up, wp)
    crop = [slice(None)] * u.ndim
    for ax in range(3 - spec.ndim, 3):
        axis = u.ndim - 3 + ax
        r = spec.radius[ax]
        crop[axis] = slice(r, r + u.shape[axis])
    v = v[tuple(crop)]
    mask = _clamp_ring_mask(u.shape, spec)
    return v if mask is None else jnp.where(mask, v, 0)


def apply_spec_once(u: jax.Array, w: jax.Array, spec: StencilSpec,
                    plan: str = "auto") -> jax.Array:
    """One BC-padded application of the operator, in ``u.dtype``."""
    return apply_plan_once(u, w, compile_plan(spec, plan))


def apply_plan_once_free_i(u: jax.Array, w: jax.Array,
                           cplan: StencilPlan) -> jax.Array:
    """One application of the planned operator on an i-*strip* of genuine
    rows: the j/k ghosts are realized per the spec's boundary conditions
    (pad + crop + clamp-ring, exactly like :func:`apply_plan_once`), while
    the i axis is left un-padded -- zero-fill shifts, so output rows within
    ``radius_i`` of either strip edge are free-space-invalid and must be
    discarded by the caller.  This is the strip-oracle contract the guard's
    sampled-plane spot check builds on: an interior plane gathered with its
    ``radius * sweep_apps * sweeps`` i-neighbourhood never observes the
    i-boundary condition, so the strip prediction is exact there.
    Volumetric constant-coefficient specs only."""
    spec = cplan.spec
    if spec.ndim != 3 or spec.coef != "const":
        raise ValueError(f"{spec.name}: the strip oracle needs a volumetric "
                         f"constant-coefficient spec")
    up = u
    for ax in (1, 2):
        r = spec.radius[ax]
        if r == 0:
            continue
        axis = u.ndim - 3 + ax
        lo, hi = spec.bc[ax]
        if lo.kind == "periodic":           # validated paired
            up = _pad_side(up, axis, r, r, lo)
        else:
            up = _pad_side(up, axis, r, 0, lo)
            up = _pad_side(up, axis, 0, r, hi)
    v = execute_plan(cplan, up, w)
    crop = [slice(None)] * u.ndim
    for ax in (1, 2):
        axis = u.ndim - 3 + ax
        r = spec.radius[ax]
        crop[axis] = slice(r, r + u.shape[axis])
    v = v[tuple(crop)]
    mask = _clamp_ring_mask(u.shape, spec, axes=(1, 2))
    return v if mask is None else jnp.where(mask, v, 0)


def _parity_mask_rows(shape, rows: jax.Array) -> jax.Array:
    """Red checkerboard parity of an i-strip whose rows sit at the *global*
    i-coordinates ``rows`` (what keeps red-black strip oracles exact under
    periodic wrap-around gathering, even at odd M)."""
    ii = rows.astype(jnp.int32).reshape((len(shape) - 3) * (1,)
                                        + (shape[-3], 1, 1))
    jj = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 2)
    kk = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)
    return ((ii + jj + kk) % 2) == 0


def stencil_ref_planes(a: jax.Array, w: jax.Array, stencil,
                       planes, sweeps: int = 1,
                       plan: str = "auto") -> jax.Array:
    """Exact expected output i-planes, from thin gathered strips.

    For each global plane index in ``planes``, gathers the
    ``radius_i * sweep_apps * sweeps``-deep i-neighbourhood (wrapping for a
    periodic i axis), runs ``sweeps`` applications with free-space i
    (:func:`apply_plan_once_free_i`) and full j/k boundary handling, and
    returns the predicted centre planes stacked along i -- shape
    ``(..., len(planes), N, P)`` in ``a.dtype``.  A non-periodic i axis
    requires every plane to lie at least the halo depth from both i edges
    (the interior, where the i BC is unobservable).  This costs
    ``len(planes) * (2 * halo + 1)`` plane-reads instead of a full oracle
    run -- the sampled spot check's entire budget."""
    spec = get_stencil(stencil)
    cplan = compile_plan(spec, plan)
    if a.ndim < 3:
        raise ValueError(f"{spec.name}: need (..., M, N, P), got {a.shape}")
    m = a.shape[-3]
    axis = a.ndim - 3
    h = spec.radius[0] * spec.sweep_apps * sweeps
    periodic_i = spec.bc[0][0].kind == "periodic"
    acc = acc_dtype_for(a.dtype)
    wf = spec.canon_weights(w).astype(acc)
    preds = []
    for i in planes:
        i = int(i)
        offs = np.arange(i - h, i + h + 1)
        if periodic_i:
            offs = offs % m
        elif offs[0] < 0 or offs[-1] >= m:
            raise ValueError(
                f"{spec.name}: plane {i} is within the halo depth {h} of a "
                f"non-periodic i edge (M={m}); sample interior planes")
        rows = jnp.asarray(offs, jnp.int32)
        u = jnp.take(a, rows, axis=axis).astype(acc)
        if spec.ordering == "redblack":
            red = _parity_mask_rows(u.shape, rows)
            for _ in range(sweeps):
                u = jnp.where(red, apply_plan_once_free_i(u, wf, cplan), u)
                u = jnp.where(red, u, apply_plan_once_free_i(u, wf, cplan))
        else:
            for _ in range(sweeps):
                u = apply_plan_once_free_i(u, wf, cplan)
        preds.append(jnp.take(u, jnp.asarray([h]), axis=axis))
    return jnp.concatenate(preds, axis=axis).astype(a.dtype)


def _parity_mask(shape, ndim: int) -> jax.Array:
    """The *red* checkerboard half: global domain coordinates summing to an
    even number over the trailing ``ndim`` axes (batch axes excluded) --
    the same parity the kernel builds per strip from its global geometry."""
    tot = None
    for ax in range(-ndim, 0):
        idx = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) + ax)
        tot = idx if tot is None else tot + idx
    return (tot % 2) == 0


@functools.partial(jax.jit, static_argnames=("stencil", "sweeps", "plan",
                                             "bc"))
def stencil_ref(a: jax.Array, w: jax.Array, stencil="stencil27",
                sweeps: int = 1, plan: str = "auto", bc=None) -> jax.Array:
    """Reference for ``stencil_apply``: ``sweeps`` Jacobi applications of the
    named (or ad-hoc) spec, re-padded per sweep under the spec's (or the
    ``bc`` override's) per-axis-side boundary conditions, under the same
    compiled ``plan`` as the kernel.

    Jitted so eager callers see the same XLA rounding (FMA contraction) as
    the Pallas kernel -- that's what makes the f64 parity bit-exact."""
    spec = get_stencil(stencil)
    if bc is not None:
        spec = spec.with_bc(bc)
    if a.ndim < spec.ndim:
        raise ValueError(f"{spec.name}: input rank {a.ndim} < {spec.ndim}")
    cplan = compile_plan(spec, plan)
    acc = acc_dtype_for(a.dtype)
    u = a.astype(acc)
    dom = a.shape[-spec.ndim:] if spec.coef == "var" else None
    wf = spec.canon_weights(w, dom).astype(acc)
    if spec.ordering == "redblack":
        # Gauss-Seidel halves: update the red checkerboard in place, then
        # the black half reading the fresh red values -- matching the
        # kernel's masked run_sweeps order.
        red = _parity_mask(u.shape, spec.ndim)
        for _ in range(sweeps):
            u = jnp.where(red, apply_plan_once(u, wf, cplan), u)
            u = jnp.where(red, u, apply_plan_once(u, wf, cplan))
    else:
        for _ in range(sweeps):
            u = apply_plan_once(u, wf, cplan)
    return u.astype(a.dtype)
