"""jnp oracle for every engine stencil (f64-capable reference path).

Expands the same tap list as the Pallas kernel, in the same order, with the
same accumulation dtype rules -- so in f64 the kernel and this reference are
bit-identical, and in f32/bf16 they differ only by block-boundary-free
rounding noise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import acc_dtype_for, accumulate_taps
from .spec import StencilSpec, get_stencil


def _interior_mask(shape, ndim: int) -> jax.Array:
    mask = jnp.ones(shape, bool)
    axes = range(-ndim, 0)
    for ax in axes:
        idx = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) + ax)
        mask = mask & (idx > 0) & (idx < shape[ax] - 1)
    return mask


def apply_spec_once(u: jax.Array, w: jax.Array, spec: StencilSpec) -> jax.Array:
    """One Dirichlet-masked application of the operator, in ``u.dtype``."""
    mask = _interior_mask(u.shape, spec.ndim)
    return jnp.where(mask, accumulate_taps(u, w, spec, u.dtype), 0)


@functools.partial(jax.jit, static_argnames=("stencil", "sweeps"))
def stencil_ref(a: jax.Array, w: jax.Array, stencil="stencil27",
                sweeps: int = 1) -> jax.Array:
    """Reference for ``stencil_apply``: ``sweeps`` Jacobi applications of the
    named (or ad-hoc) spec, Dirichlet boundary zeroed each sweep.

    Jitted so eager callers see the same XLA rounding (FMA contraction) as
    the Pallas kernel -- that's what makes the f64 parity bit-exact."""
    spec = get_stencil(stencil)
    if a.ndim < spec.ndim:
        raise ValueError(f"{spec.name}: input rank {a.ndim} < {spec.ndim}")
    acc = acc_dtype_for(a.dtype)
    u = a.astype(acc)
    wf = spec.canon_weights(w).astype(acc)
    for _ in range(sweeps):
        u = apply_spec_once(u, wf, spec)
    return u.astype(a.dtype)
