"""Unified Pallas stencil engine: one kernel body, every radius-1 stencil.

The paper's central artifact is a synthesis framework that emits many stencil
variants (3/7/27-point, mm/lc register strategies, any jam factor) from one
kernel description.  This package is that idea applied to the repo's Pallas
layer: the former ``stencil3``/``stencil7``/``stencil27`` kernel/ops/ref
triples are now *one* tap-list-parameterized kernel body plus a spec
registry.

Mask registry
    :func:`get_stencil` / :func:`register_stencil` /
    :func:`list_stencils` / :func:`spec_from_mask`.  Built-ins:
    ``"stencil3"`` (k-only, ``w=(w_edge, w_center)``), ``"stencil7"``
    (``w=(wc, wk, wj, wi)``), ``"stencil27"`` (``w[|di|,|dj|,|dk|]``, shape
    ``(2,2,2)``).  ``spec_from_mask`` turns any ``(3,3,3)``
    coefficient-index mask into a runnable spec.

Execution -- :func:`stencil_apply`
    Batched (arbitrary leading dims) and multi-dtype: bf16/f32 inputs
    accumulate in f32; f64 inputs stay f64 and are bit-identical to
    :func:`stencil_ref` (same tap order, same arithmetic).  ``block_i``
    defaults to a roofline cost model (:func:`autotune_block_i`) instead of
    the old fits-in-VMEM heuristic.

Fused sweeps -- ``stencil_apply(..., sweeps=s)``
    Runs ``s`` Jacobi applications inside one ``pallas_call``: blocks are
    widened by ``s`` halo rows from the +-1 neighbour blocks and only the
    central rows are written back, cutting HBM round-trips from ``s`` to 1 --
    the Pallas analogue of the paper's register-resident steady-state
    stream.  Equivalent to ``s`` separate applications (requires
    ``block_i >= sweeps``).

Sharded execution -- :func:`stencil_sharded`
    ``shard_map`` over the i-axis: the partition plan (divisibility, halo
    depth, PlanNotes) comes from
    ``repro.sharding.planner.stencil_halo_sharding``; shards exchange
    ``sweeps`` halo rows via ``lax.ppermute`` and run the same fused kernel,
    with global-geometry masking keeping shard seams exact.

Tier-1 verify: ``PYTHONPATH=src python -m pytest -x -q``
(engine parity lives in ``tests/test_stencil_engine.py``).
"""

from .autotune import autotune_block_i, pick_block_i, pick_block_rows  # noqa: F401
from .compat import (stencil3, stencil3_ref, stencil7, stencil7_ref,  # noqa: F401
                     stencil27, stencil27_ref)
from .ops import stencil_apply  # noqa: F401
from .ref import stencil_ref  # noqa: F401
from .sharded import stencil_sharded  # noqa: F401
from .spec import (StencilSpec, get_stencil, list_stencils,  # noqa: F401
                   register_stencil, spec_from_mask)
