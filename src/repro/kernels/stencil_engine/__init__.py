"""Unified Pallas stencil engine: one kernel body, every radius-R stencil.

The paper's central artifact is a synthesis framework that emits many stencil
variants (3/7/27-point, mm/lc register strategies, any jam factor) from one
kernel description.  This package is that idea applied to the repo's Pallas
layer: the former ``stencil3``/``stencil7``/``stencil27`` kernel/ops/ref
triples are now *one* spec registry, compiled to an explicit execution plan
by a pass pipeline (the paper's synthesis step) and run by one kernel body,
at any per-axis radius.

Mask registry
    :func:`get_stencil` / :func:`register_stencil` /
    :func:`list_stencils` / :func:`spec_from_mask`.  Built-ins:
    ``"stencil3"`` (k-only, ``w=(w_edge, w_center)``), ``"stencil7"``
    (``w=(wc, wk, wj, wi)``), ``"stencil27"`` (``w[|di|,|dj|,|dk|]``, shape
    ``(2,2,2)``), and the radius-2 ``"star13"`` (the 4th-order Laplacian
    star, ``w=(wc, w1, w2)``) and ``"box125"`` (5x5x5 box,
    ``w[|di|,|dj|,|dk|]``, shape ``(3,3,3)``).  ``spec_from_mask`` turns
    any odd-shaped coefficient-index mask (``(2r+1)`` per axis) into a
    runnable spec.

Pass-pipeline plan compiler -- :func:`compile_plan` (paper sect. 4)
    A spec compiles to a :class:`StencilPlan` -- a tiny SSA schedule of
    shift/scale/add/fma ops interpreted at trace time by both the kernel
    and the reference.  ``compile_plan`` runs an ordered pass list
    (``build_direct`` -> ``cse`` / ``mirror_factor`` -> ``order_ops``; the
    plan kinds are presets in ``PASS_PRESETS``): ``mirror_factor``
    (per-axis ``|d|``-symmetric specs, any radius) shares k-pair partial
    sums per distance across j then i -- stencil27 drops from 54 shifts +
    53 flop-ops (``direct``, the naive escape hatch) to 8 + 19, the
    radius-2 star13 from 12 + 25 to 12 + 19, box125 from 300 + 249 to
    20 + 63; ``cse`` (arbitrary masks) builds each ``(dj, dk)`` plane shift
    once and reuses it across ``di``; ``order_ops`` re-sequences the
    schedule with the core list scheduler's longest-path-to-sink priority
    and provably never increases peak SSA liveness (:func:`peak_live` --
    the paper's register-pressure constraint as the executor's working
    set).  Shifts are static slices with zero fill on the halo-extended
    block -- no wrap-around values are ever computed then masked.  The
    plan's static op counts drive the cost model.

Execution -- :func:`stencil_apply`
    Batched (arbitrary leading dims) and multi-dtype: bf16/f32 inputs
    accumulate in f32; f64 inputs stay f64 and are bit-identical to
    :func:`stencil_ref` under the same ``plan`` on the reference
    configurations (same op walk, same arithmetic; blocking-invariance is
    exact on integer-valued data -- see :mod:`.plan` on fma contraction).
    ``block_i``/``block_j`` default to the plan- and path-aware roofline
    cost model (:func:`autotune_engine` / :func:`autotune_blocks`), which
    charges the plan's actual ``shifts + flops`` instead of ``2 * taps``
    and the path's real HBM bytes per point.

Plane streaming -- ``stencil_apply(..., path="stream")`` (default via auto)
    The paper's central optimization as the volumetric hot path: the grid
    walks i-blocks in order with a single input operand, and a VMEM
    ``scratch_shapes`` window of ``block_i + radius * sweeps`` planes is
    carried across grid steps (``pl.when``-guarded prime/rotate), so each
    input plane is fetched from HBM exactly once per call and written once
    -- ~2 transfers per point at any radius (:func:`bytes_per_point`), vs
    ``2r + 2`` (untiled) / ``(2r+1)^2 + 1`` (j-tiled) on the
    halo-replicated path, which survives as the ``path="replicate"``
    parity escape hatch (f64 runs of the two paths are bit-identical).

j-tiled blocking -- ``stencil_apply(..., block_j=bj)``
    Blocks become ``(1, bi, bj, P)`` with a j-halo assembled from the 3x3
    neighbour tiles, so grids whose full N x P slab exceeds the VMEM budget
    -- previously a hard wall -- run at all; the autotuner engages it only
    when no full-N block fits.

Fused sweeps -- ``stencil_apply(..., sweeps=s)``
    Runs ``s`` Jacobi applications inside one ``pallas_call``: blocks are
    widened by ``s`` halo rows (and columns, when j-tiled) from the
    neighbour blocks and only the central rows are written back, cutting
    HBM round-trips from ``s`` to 1 -- the Pallas analogue of the paper's
    register-resident steady-state stream.  Equivalent to ``s`` separate
    applications (requires ``block_i >= sweeps`` and, when j-tiled,
    ``block_j >= sweeps``).

Boundary conditions -- ``spec.with_bc`` / ``stencil_apply(..., bc=...)``
    Per-axis-side :class:`BC`: ``clamp`` (the historical default -- zero
    ghosts + one-point output ring zeroed per sweep), ``periodic`` (wrap;
    paired per axis), ``dirichlet(v)`` (constant ghosts, realized by the
    linearity identity ``stencil(u) = stencil(u - v) + v * sum(w)``), and
    ``neumann`` (zero-flux symmetric mirror).  BC-suffixed builtins
    (``stencil27_periodic``, ...) live in the registry, plans memoize and
    ``describe()`` per variant, the reference is the per-sweep
    ``np.pad``-mode oracle, and every BC runs on both data-movement paths
    at any radius -- the streaming path wraps its lead-in for periodic
    (re-fetching only the first ``radius * sweeps`` planes), the sharded
    path turns the halo exchange into a ring.

Temporal wavefront tiling -- :func:`stencil_sweep_driver` (:mod:`.sweeps`)
    The streaming ideal extended through *time*: ``s`` pipelined sweep
    stages ride one pass over the i-blocks (stage ``t`` consuming planes
    stage ``t-1`` produced one block earlier), so each input plane is
    fetched from HBM once per ``s`` sweeps -- modeled ``2 * itemsize / s``
    bytes/point like the fused call, but every stage carries only the
    *single-sweep* halo ``radius * sweep_apps`` instead of the fused
    ``radius * s * sweep_apps`` window and matching VPU-redundant strip.
    :func:`autotune_sweeps` races (fused, wavefront, chained) per
    ``(spec, shape, s)`` -- feasibility, then fewest modeled bytes/point,
    then modeled time -- and records the verdict in
    ``SweepSelection.describe()["selection"]``; all three modes are
    bit-exact on integer-valued data.  A periodic i axis runs via a
    caller-side HBM pre-extension of the ``radius * sweep_apps * s`` deep
    halo.

Red-black Gauss-Seidel -- ``spec.with_ordering("redblack")``
    Plan-level ordering property: each sweep updates the red checkerboard
    half (global ``(i + j + k)`` parity) in place, then the black half
    reading the fresh red values -- masked in ``run_sweeps`` from the
    kernel's global geometry, mirrored exactly in the NumPy oracle, and
    registered as ``*_redblack`` builtins.  The effective halo per sweep
    doubles (``sweep_apps == 2``), which the cost model, the fused/
    wavefront kernels, and the sharded halo depth all account for.

Sharded execution -- :func:`stencil_sharded`
    ``shard_map`` over the i-axis: the partition plan (divisibility, halo
    depth, PlanNotes) comes from
    ``repro.sharding.planner.stencil_halo_sharding``; shards exchange
    ``radius * sweep_apps * sweeps`` halo rows via ``lax.ppermute`` --
    a chain whose edge shards take their boundary ghosts locally, or a
    closed ring when the i axis is periodic -- and run the same fused
    kernel (or, with ``mode="wavefront"``, the temporal-wavefront
    pipeline) *once*: ``s`` sweeps cost one exchange round, shard-edge
    strips redundantly recomputed from the deep halo, with
    global-geometry masking keeping shard seams exact.  Compiled
    shard_map programs are memoized keyed on device ids + axis names (not
    ``Mesh`` objects) in a bounded cache.

Multi-axis process grids + overlap -- ``stencil_sharded(axes=..., overlap=...)``
    ``axes=(ai, aj, ak)`` shards the domain over an (pi, pj, pk) process
    grid (plan: ``repro.sharding.planner.stencil_grid_sharding``).  Face
    ghosts are exchanged one axis at a time on the progressively extended
    slab -- j, then k, then i -- so corner/edge ghosts arrive
    *transitively* and no diagonal messages exist
    (:func:`exchange_bytes_per_point` is the per-axis traffic model).
    ``overlap="on"`` hides the i exchange behind compute: the ghost-slab
    ppermutes are issued first, the interior planes (needing no ghosts)
    are swept while the collectives are in flight, and the boundary
    strips are finished from the arrived slabs by a dedicated strip
    kernel; ``overlap="off"`` stays the serialized bit-exact escape
    hatch.  :class:`CorruptHalo` targets any single axis's exchange via
    ``axes=("j",)``-style filters.

Guarded execution -- ``guard=`` on every entry point (:mod:`.guard`)
    Runtime verification + graceful degradation: a :class:`GuardPolicy`
    (or a :data:`GUARD_KINDS` preset string) screens the output for
    NaN/Inf, checks the weight-sum invariant (global under all-periodic
    BCs, per-sampled-plane marginals otherwise/interior residual for
    non-periodic), and optionally spot-checks sampled planes against an
    exact thin-strip oracle (:func:`stencil_ref_planes`); on a detected
    failure or a raised kernel the call retries once, then walks
    ``wavefront -> fused -> chained -> stream -> replicate -> oracle``,
    blacklisting raising candidates in the autotuner and recording every
    demotion in ``last_guard_report().describe()["guard"]``.  The default
    ``guard="off"`` dispatches to the historical byte-identical jitted
    programs.  :mod:`.faults` is the seedable injection harness (bit-flip
    planes, NaN scratch windows, corrupted ppermute halos, raising
    candidates) that proves each detector in ``tests/test_stencil_guard``.

Tier-1 verify: ``PYTHONPATH=src python -m pytest -x -q``
(engine parity lives in ``tests/test_stencil_engine.py``; plan-correctness
property tests in ``tests/test_stencil_plan.py``).
"""

from .autotune import (PATH_KINDS, SWEEP_MODES, SweepSelection,  # noqa: F401
                       autotune_block_i, autotune_blocks, autotune_engine,
                       autotune_sweeps, blacklist_candidate, bytes_per_point,
                       clear_blacklist, exchange_bytes_per_point,
                       is_blacklisted, list_blacklist, pick_block_i,
                       pick_block_rows, wavefront_block_i)
from .compat import (stencil3, stencil3_ref, stencil7, stencil7_ref,  # noqa: F401
                     stencil27, stencil27_ref)
from .common import DEFAULT_VMEM_BUDGET  # noqa: F401
from .faults import (BitFlipPlane, CorruptHalo, FaultInjector,  # noqa: F401
                     NaNScratchWindow, NaNWindow, RaisingCandidate, inject)
from .guard import (LADDER, GuardError, GuardPolicy,  # noqa: F401
                    GuardReport, as_guard, guard_bytes_per_point,
                    last_guard_report, run_guard_checks)
from .kernel import KernelFault  # noqa: F401
from .ops import default_interpret, stencil_apply  # noqa: F401
from .plan import (PASS_PRESETS, PLAN_KINDS, PlanOp,  # noqa: F401
                   StencilPlan, compile_plan, execute_plan,
                   mirror_symmetric, peak_live, run_passes, shift_slice,
                   shift_slice_bc)
from .ref import stencil_ref, stencil_ref_planes  # noqa: F401
from .sharded import stencil_sharded  # noqa: F401
from .spec import (BC, BC_KINDS, CLAMP, GUARD_KINDS, NEUMANN,  # noqa: F401
                   ORDERING_KINDS, PERIODIC, StencilSpec, as_boundary,
                   bc_labels, dirichlet, get_stencil, list_stencils,
                   register_stencil, spec_from_mask)
from .sweeps import stencil_sweep_driver, stencil_wavefront  # noqa: F401
