"""Legacy per-stencil entry points, now thin wrappers over the engine.

``repro.kernels.stencil{3,7,27}`` re-export these so seed-era call sites
(benchmarks, examples, tests) keep their signatures and semantics.  The
wrapper bodies themselves are built by the parametrized factories in
:mod:`repro.kernels._compat` (one shim generator instead of three
copy-pasted packages); see there for the one deliberate behavior change
(``interpret`` defaults to ``None``).
"""

from __future__ import annotations

from .._compat import (stencil3, stencil3_ref, stencil7,  # noqa: F401
                       stencil7_ref, stencil27, stencil27_ref)
