"""Legacy per-stencil entry points, now thin wrappers over the engine.

``repro.kernels.stencil{3,7,27}`` re-export these so seed-era call sites
(benchmarks, examples, tests) keep their signatures and semantics.  The one
deliberate change: ``interpret`` now defaults to ``None`` ("interpret only
when no compiled Pallas backend exists"), so the same call site runs
compiled on TPU and interpreted on CPU/GPU/CI (the engine's VMEM scratch
windows are Mosaic-TPU-only).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from .ops import stencil_apply
from .ref import stencil_ref


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stencil3(a: jax.Array, w: jax.Array, block_rows: Optional[int] = None,
             interpret: Optional[bool] = None) -> jax.Array:
    """Symmetric 3-point stencil along the last axis; ``w = (w_edge, w_center)``."""
    return stencil_apply(a, w, "stencil3", block_i=block_rows,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_i", "interpret"))
def stencil7(a: jax.Array, w: jax.Array, block_i: Optional[int] = None,
             interpret: Optional[bool] = None) -> jax.Array:
    """Symmetric 7-point stencil; ``w = (wc, wk, wj, wi)``."""
    return stencil_apply(a, w, "stencil7", block_i=block_i,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_i", "interpret"))
def stencil27(a: jax.Array, w: jax.Array, block_i: Optional[int] = None,
              interpret: Optional[bool] = None) -> jax.Array:
    """Symmetric 27-point stencil; ``w`` has shape (2, 2, 2)."""
    return stencil_apply(a, w, "stencil27", block_i=block_i,
                         interpret=interpret)


def stencil3_ref(a, w):
    """Pure-jnp oracle for the 3-point stencil (engine-backed)."""
    return stencil_ref(a, w, "stencil3")


def stencil7_ref(a, w):
    """Pure-jnp oracle for the 7-point stencil (engine-backed)."""
    return stencil_ref(a, w, "stencil7")


def stencil27_ref(a, w):
    """Pure-jnp oracle for the 27-point stencil (engine-backed)."""
    return stencil_ref(a, w, "stencil27")
