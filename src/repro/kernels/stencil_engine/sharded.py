"""Multi-device stencil execution: shard_map over the i-axis + halo exchange.

The partition plan comes from ``repro.sharding.planner.stencil_halo_sharding``
(divisibility and halo-depth checks, PlanNote audit trail).  Each shard owns a
contiguous slab of i-rows, trades ``sweeps`` halo rows with its neighbours
via ``lax.ppermute`` (edge shards receive zeros -- the Dirichlet boundary),
and then runs the *same* fused Pallas kernel as the single-device path; the
kernel's geometry operand (global row offset, global M) keeps the
interior/boundary masking correct across shard seams.
"""

from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .._compat import shard_map

from ...sharding.planner import StencilShardPlan, stencil_halo_sharding
from .autotune import autotune_block_i
from .kernel import acc_dtype_for
from .ops import call_3d, stencil_apply
from .spec import StencilSpec, get_stencil


@functools.lru_cache(maxsize=64)
def _sharded_fn(spec: StencilSpec, mesh: Mesh, axis: str, bi: int,
                sweeps: int, interpret: bool, h: int, m_loc: int, n_sh: int,
                m: int, part):
    """Build (and cache) the jitted shard_map program for one geometry, so
    repeated calls don't retrace the inner pallas_call."""

    def local_fn(a_loc: jax.Array, wf_: jax.Array) -> jax.Array:
        idx = jax.lax.axis_index(axis)
        # halo rows from the i-1 / i+1 shards; edge shards get zeros, which
        # the kernel masks as out-of-domain (Dirichlet).
        lo = jax.lax.ppermute(a_loc[:, -h:], axis,
                              [(i, i + 1) for i in range(n_sh - 1)])
        hi = jax.lax.ppermute(a_loc[:, :h], axis,
                              [(i + 1, i) for i in range(n_sh - 1)])
        ext = jnp.concatenate([lo, a_loc, hi], axis=1)
        geom = jnp.stack([idx * m_loc - h,
                          jnp.int32(m)]).astype(jnp.int32)
        out = call_3d(ext, wf_, geom, spec, bi, sweeps, interpret)
        return out[:, h:h + m_loc]

    return jax.jit(shard_map(local_fn, mesh=mesh, in_specs=(part, P(None)),
                             out_specs=part, check_rep=False))


def stencil_sharded(a: jax.Array, w: jax.Array,
                    stencil: Union[str, int, StencilSpec] = "stencil27",
                    mesh: Optional[Mesh] = None, axis: str = "data",
                    block_i: Optional[int] = None, sweeps: int = 1,
                    interpret: bool = True,
                    plan: Optional[StencilShardPlan] = None) -> jax.Array:
    """Halo-exchange execution of ``stencil_apply`` over a mesh axis.

    ``a`` is ``(..., M, N, P)`` (volumetric specs only); ``mesh`` defaults to
    a 1-D mesh over every visible device.  Returns the same value as the
    single-device path; falls back to it when the planner declines to shard.

    Note: the kernel runs per shard on the halo-extended local slab, so an
    explicit ``block_i`` must divide ``M / n_shards + 2 * sweeps`` (not M);
    it is ignored when the planner falls back to the unsharded path.  Omit
    it to let the cost model choose in every configuration.
    """
    spec = get_stencil(stencil)
    if spec.ndim != 3:
        raise ValueError(f"{spec.name}: sharded execution needs a volumetric "
                         f"(ndim=3) spec")
    if a.ndim < 3:
        raise ValueError(f"{spec.name}: need (..., M, N, P), got {a.shape}")
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), (axis,))
    m, n, p = a.shape[-3:]
    if plan is None:
        plan = stencil_halo_sharding(m, mesh, axis=axis, sweeps=sweeps)
    if plan.n_shards <= 1:
        # An explicit block_i is sized for the halo-extended local slab; it
        # generally doesn't divide M, so let the cost model choose here --
        # the same call must work whatever the device count.
        return stencil_apply(a, w, spec, sweeps=sweeps, interpret=interpret)

    batch = int(np.prod(a.shape[:-3])) if a.ndim > 3 else 1
    a4 = a.reshape(batch, m, n, p)
    acc = acc_dtype_for(a.dtype)
    wf = spec.canon_weights(w).astype(acc)
    h, m_loc, n_sh = plan.halo, plan.local_rows, plan.n_shards
    m_ext = m_loc + 2 * h
    if block_i is not None and m_ext % block_i != 0:
        raise ValueError(
            f"sharded block_i={block_i} must divide the halo-extended local "
            f"slab (M/n_shards + 2*sweeps = {m_loc} + {2 * h} = {m_ext}); "
            f"omit block_i to let the cost model choose")
    bi = block_i or autotune_block_i(m_ext, n, p, a.dtype.itemsize,
                                     sweeps=sweeps, taps=spec.taps)
    fn = _sharded_fn(spec, mesh, axis, bi, sweeps, interpret, h, m_loc, n_sh,
                     m, plan.spec)
    return fn(a4, wf).reshape(a.shape)
