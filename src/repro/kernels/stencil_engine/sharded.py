"""Multi-device stencil execution: shard_map over the i-axis + halo exchange.

The partition plan comes from ``repro.sharding.planner.stencil_halo_sharding``
(divisibility and halo-depth checks, PlanNote audit trail).  Each shard owns a
contiguous slab of i-rows and trades ``radius * sweeps`` halo rows with its
neighbours via ``lax.ppermute``.  The exchange topology follows the spec's
i-axis boundary condition: a *chain* for the non-periodic BCs (edge shards
receive zeros, which the kernel's global-geometry ghost fill then turns into
the clamp / dirichlet / neumann boundary -- so those BCs materialize only on
the boundary shards) or a closed *ring* for periodic (shard 0 and shard N-1
trade wrap-around halos).  Each shard
then runs the *same* fused plan-compiled Pallas kernel as the
single-device path -- by default the plane-streaming body, so the shard_map
body also fetches each local plane from HBM exactly once and carries the
halo window in VMEM scratch (``path="replicate"`` stays available as the
parity escape hatch, and j-tiled blocking engages when the local N x P slab
exceeds the VMEM budget); the kernel's geometry operand (global row offset,
global M) keeps the interior/boundary masking correct across shard seams.

The compiled shard_map program is memoized in a small bounded cache keyed on
the mesh's *device ids + topology + axis names* (plus the execution
geometry), not on the ``Mesh`` object itself -- equal test meshes share one
entry and the cache can never retain more than ``_SHARDED_CACHE_MAX``
programs (the old ``lru_cache`` keyed on ``Mesh`` kept up to 64 meshes alive
indefinitely).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .._compat import shard_map

from ...sharding.planner import (StencilGridPlan, StencilShardPlan,
                                 stencil_grid_sharding,
                                 stencil_halo_sharding)
from .autotune import (PATH_KINDS, autotune_engine, autotune_sweeps,
                       exchange_bytes_per_point, wavefront_block_i)
from .kernel import acc_dtype_for
from .ops import (call_3d, call_3d_strip, call_3d_wavefront,
                  resolve_interpret, stencil_apply)
from .plan import StencilPlan, compile_plan
from .spec import StencilSpec, get_stencil

_SHARDED_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
_SHARDED_CACHE_MAX = 32

# Fault injection (tests): a callable (lo, hi, axis="i") -> (lo, hi) applied
# to the ppermute'd halo slabs inside the traced shard_map body -- the fault
# lives in the exchanged data itself, exactly where a real link corruption
# would; ``axis`` names which domain axis's exchange ("i"/"j"/"k") carried
# the slabs, so per-axis faults can target one face of the process grid.
# The version counter rides the program cache key so installing/clearing a
# fault always retraces instead of reusing a clean (or faulty) program.
_HALO_FAULT = [None]
_HALO_FAULT_VERSION = [0]


def set_halo_fault(fn) -> None:
    """Install (or clear, with ``None``) the halo-exchange fault hook.
    Only :mod:`.faults` calls this."""
    _HALO_FAULT[0] = fn
    _HALO_FAULT_VERSION[0] += 1


def _mesh_key(mesh: Mesh) -> tuple:
    """Hashable mesh identity that does not retain the Mesh object: device
    platforms + ids (ids restart at 0 per backend), topology shape, and axis
    names."""
    return (tuple((d.platform, int(d.id)) for d in mesh.devices.flat),
            tuple(mesh.devices.shape), tuple(mesh.axis_names))


def _sharded_fn(cplan: StencilPlan, mesh: Mesh, axis: str, bi: int,
                bj: Optional[int], sweeps: int, interpret: bool, h: int,
                m_loc: int, n_sh: int, m: int, part, path: str = "stream",
                mode: str = "fused"):
    """Build (and cache) the jitted shard_map program for one geometry, so
    repeated calls don't retrace the inner pallas_call."""
    key = (cplan, _mesh_key(mesh), axis, bi, bj, sweeps, interpret, h,
           m_loc, n_sh, m, part, path, mode, _HALO_FAULT_VERSION[0])
    fn = _SHARDED_CACHE.get(key)
    if fn is not None:
        _SHARDED_CACHE.move_to_end(key)
        return fn
    periodic_i = cplan.spec.bc[0][0].kind == "periodic"
    if periodic_i:
        # ring: shard 0's low halo wraps around from shard n-1 (and vice
        # versa) -- the periodic BC *is* the wrap-around link
        lo_perm = [(i, (i + 1) % n_sh) for i in range(n_sh)]
        hi_perm = [((i + 1) % n_sh, i) for i in range(n_sh)]
    else:
        # chain: edge shards get zeros; the kernel's global-geometry ghost
        # fill turns them into the clamp / dirichlet / neumann boundary
        # (so non-periodic BCs only materialize on the boundary shards)
        lo_perm = [(i, i + 1) for i in range(n_sh - 1)]
        hi_perm = [(i + 1, i) for i in range(n_sh - 1)]

    var = cplan.spec.coef == "var"

    def _halo_ext(x: jax.Array) -> jax.Array:
        # x is (lead, M_loc, N, P): the i axis sits at axis 1 for both the
        # batched field (lead = batch) and the canonicalized coefficient
        # stack (lead = n_weights), so one exchange serves both.
        lo = jax.lax.ppermute(x[:, -h:], axis, lo_perm)
        hi = jax.lax.ppermute(x[:, :h], axis, hi_perm)
        if _HALO_FAULT[0] is not None:
            lo, hi = _HALO_FAULT[0](lo, hi, axis="i")
        return jnp.concatenate([lo, x, hi], axis=1)

    def local_fn(a_loc: jax.Array, wf_: jax.Array) -> jax.Array:
        idx = jax.lax.axis_index(axis)
        ext = _halo_ext(a_loc)
        wx = _halo_ext(wf_) if var else wf_
        geom = jnp.stack([idx * m_loc - h,
                          jnp.int32(m)]).astype(jnp.int32)
        if mode == "wavefront":
            # one radius*sweep_apps*sweeps-deep exchange already happened
            # (ext); the pipeline redundantly recomputes the shard-edge
            # strip exactly like the fused deep halo does
            out = call_3d_wavefront(ext, wx, geom, cplan, bi, sweeps,
                                    interpret)
        else:
            out = call_3d(ext, wx, geom, cplan, bi, bj, sweeps, interpret,
                          path, external_i_halo=True)
        return out[:, h:h + m_loc]

    w_spec = part if var else P(None)
    fn = jax.jit(shard_map(local_fn, mesh=mesh, in_specs=(part, w_spec),
                           out_specs=part, check_rep=False))
    _SHARDED_CACHE[key] = fn
    while len(_SHARDED_CACHE) > _SHARDED_CACHE_MAX:
        _SHARDED_CACHE.popitem(last=False)
    return fn


_AXIS_LABEL = ("i", "j", "k")


def _grid_sharded_fn(cplan: StencilPlan, mesh: Mesh, names, bi: int,
                     bj: Optional[int], sweeps: int, interpret: bool,
                     halos, locs, nsh, gshape, part, path: str = "stream",
                     mode: str = "fused", overlap: str = "off"):
    """Build (and cache) the jitted shard_map program for an (pi, pj, pk)
    process grid.

    ``names`` is the per-domain-axis mesh-axis triple (``None`` = axis
    whole); ``halos`` / ``locs`` / ``nsh`` the per-axis deep halo, local
    extent and shard count; ``gshape`` the global (M, N, P).  Face ghosts
    are exchanged one axis at a time on the *progressively extended* slab
    -- j first, then k (whose face slabs already carry the j ghost
    columns), then i -- so corner and edge ghosts arrive transitively and
    no diagonal sends exist; i goes last so its slabs carry the complete
    j/k ghost columns and, under ``overlap="on"``, its ppermutes are the
    only ones the interior compute has to hide.

    ``overlap="off"`` (the serialized, bit-exact escape hatch) runs one
    kernel call on the fully extended slab.  ``overlap="on"`` splits the
    i-axis work: the two i ghost-slab ppermutes are issued with no
    consumer between them and the interior :func:`~.ops.call_3d` (which
    reads only resident planes and discards its ``h`` edge rows), leaving
    XLA free to run the collectives concurrently with the interior sweep;
    the two ``h``-deep boundary strips are then swept from the arrived
    slabs by :func:`~.ops.call_3d_strip` (``3h`` pre-extended planes
    each) and concatenated around the cropped interior."""
    key = ("grid", cplan, _mesh_key(mesh), tuple(names), bi, bj, sweeps,
           interpret, tuple(halos), tuple(locs), tuple(nsh), tuple(gshape),
           part, path, mode, overlap, _HALO_FAULT_VERSION[0])
    fn = _SHARDED_CACHE.get(key)
    if fn is not None:
        _SHARDED_CACHE.move_to_end(key)
        return fn
    var = cplan.spec.coef == "var"
    m_gl, n_gl, p_gl = gshape
    # effective halo: only sharded axes carry exchanged ghost planes
    hs = tuple(halos[d] if names[d] is not None else 0 for d in range(3))
    ext_i, ext_j, ext_k = (names[d] is not None for d in range(3))
    perms = []
    for d in range(3):
        n = nsh[d]
        if cplan.spec.bc[d][0].kind == "periodic":
            perms.append(([(i, (i + 1) % n) for i in range(n)],
                          [((i + 1) % n, i) for i in range(n)]))
        else:
            perms.append(([(i, i + 1) for i in range(n - 1)],
                          [(i + 1, i) for i in range(n - 1)]))

    def _pperm_pair(x: jax.Array, d: int):
        # ghost face slabs of domain axis d; the array axis is d + 1 for
        # the batched field (lead = batch) and the canonicalized
        # coefficient stack (lead = n_weights) alike
        ax, h = d + 1, hs[d]
        tail = jax.lax.slice_in_dim(x, x.shape[ax] - h, x.shape[ax],
                                    axis=ax)
        head = jax.lax.slice_in_dim(x, 0, h, axis=ax)
        lo = jax.lax.ppermute(tail, names[d], perms[d][0])
        hi = jax.lax.ppermute(head, names[d], perms[d][1])
        if _HALO_FAULT[0] is not None:
            lo, hi = _HALO_FAULT[0](lo, hi, axis=_AXIS_LABEL[d])
        return lo, hi

    def _exchange(x: jax.Array, d: int) -> jax.Array:
        lo, hi = _pperm_pair(x, d)
        return jnp.concatenate([lo, x, hi], axis=d + 1)

    def _offsets():
        return [jax.lax.axis_index(names[d]) * locs[d]
                if names[d] is not None else jnp.int32(0) for d in range(3)]

    def _geom(i_row, offs):
        return jnp.stack([i_row, jnp.int32(m_gl), offs[1] - hs[1],
                          offs[2] - hs[2]]).astype(jnp.int32)

    def local_serial(a_loc: jax.Array, wf_: jax.Array) -> jax.Array:
        offs = _offsets()
        ext, wx = a_loc, wf_
        for d in (1, 2, 0):         # j, then k, then i: transitive corners
            if names[d] is not None and hs[d] > 0:
                ext = _exchange(ext, d)
                if var:
                    wx = _exchange(wx, d)
        geom = _geom(offs[0] - hs[0], offs)
        if mode == "wavefront":
            out = call_3d_wavefront(ext, wx, geom, cplan, bi, sweeps,
                                    interpret, ext_j=ext_j, ext_k=ext_k,
                                    n_global=n_gl, p_global=p_gl)
        else:
            out = call_3d(ext, wx, geom, cplan, bi, bj, sweeps, interpret,
                          path, external_i_halo=ext_i, ext_j=ext_j,
                          ext_k=ext_k, n_global=n_gl, p_global=p_gl)
        return out[:, hs[0]:hs[0] + locs[0], hs[1]:hs[1] + locs[1],
                   hs[2]:hs[2] + locs[2]]

    h = hs[0]
    m_l = locs[0]

    def local_overlap(a_loc: jax.Array, wf_: jax.Array) -> jax.Array:
        offs = _offsets()
        ext, wx = a_loc, wf_
        for d in (1, 2):
            if names[d] is not None and hs[d] > 0:
                ext = _exchange(ext, d)
                if var:
                    wx = _exchange(wx, d)
        # Launch the i ghost-slab ppermutes now; the interior call below
        # has no data dependency on them, so the collectives and the
        # interior sweep can be scheduled concurrently.
        lo, hi = _pperm_pair(ext, 0)
        if var:
            wlo, whi = _pperm_pair(wx, 0)
        # Interior: the whole resident i extent with zero ghosts -- its
        # first/last h output rows are garbage and are replaced by the
        # strips; rows [h, m_l - h) are >= h planes from the slab edge and
        # therefore exact under the deep halo.
        interior = call_3d(ext, wx, _geom(offs[0], offs), cplan, bi, None,
                           sweeps, interpret, path, external_i_halo=True,
                           ext_j=ext_j, ext_k=ext_k, n_global=n_gl,
                           p_global=p_gl)
        strip_lo_in = jnp.concatenate([lo, ext[:, :2 * h]], axis=1)
        strip_hi_in = jnp.concatenate([ext[:, -2 * h:], hi], axis=1)
        w_lo = w_hi = wx
        if var:
            w_lo = jnp.concatenate([wlo, wx[:, :2 * h]], axis=1)
            w_hi = jnp.concatenate([wx[:, -2 * h:], whi], axis=1)
        strip_lo = call_3d_strip(strip_lo_in, w_lo,
                                 _geom(offs[0] - h, offs), cplan, sweeps,
                                 interpret, h, ext_j=ext_j, ext_k=ext_k,
                                 n_global=n_gl, p_global=p_gl)
        strip_hi = call_3d_strip(strip_hi_in, w_hi,
                                 _geom(offs[0] + m_l - 2 * h, offs), cplan,
                                 sweeps, interpret, h, ext_j=ext_j,
                                 ext_k=ext_k, n_global=n_gl, p_global=p_gl)
        out = jnp.concatenate(
            [strip_lo, interior[:, h:m_l - h], strip_hi], axis=1)
        return out[:, :, hs[1]:hs[1] + locs[1], hs[2]:hs[2] + locs[2]]

    local_fn = local_overlap if overlap == "on" else local_serial
    w_spec = part if var else P(None)
    fn = jax.jit(shard_map(local_fn, mesh=mesh, in_specs=(part, w_spec),
                           out_specs=part, check_rep=False))
    _SHARDED_CACHE[key] = fn
    while len(_SHARDED_CACHE) > _SHARDED_CACHE_MAX:
        _SHARDED_CACHE.popitem(last=False)
    return fn


def _grid_dispatch(a: jax.Array, w: jax.Array, spec: StencilSpec,
                   cplan: StencilPlan, mesh: Mesh, gaxes,
                   grid_plan: Optional[StencilGridPlan],
                   block_i: Optional[int], block_j: Optional[int],
                   plan_kind: str, sweeps: int, path: str, mode: str,
                   overlap: str, interpret: bool) -> jax.Array:
    """Plan, tune, and run :func:`stencil_sharded`'s process-grid route
    (multi-axis ``axes`` and/or ``overlap="on"``); split out to keep the
    entry point readable.  ``gaxes`` is the resolved (ai, aj, ak) triple,
    ``grid_plan`` a caller-supplied :class:`StencilGridPlan` or ``None``
    (plan here)."""
    m, n, p = a.shape[-3:]
    apps = spec.sweep_apps
    per = tuple(spec.bc[d][0].kind == "periodic" for d in range(3))
    if mode == "wavefront" and overlap == "on":
        raise ValueError(f"{spec.name}: overlap='on' needs the fused mode "
                         f"(the wavefront pipeline consumes its deep halo "
                         f"up front, leaving no interior to overlap); use "
                         f"overlap='off' or mode='fused'")
    if grid_plan is None:
        grid_plan = stencil_grid_sharding((m, n, p), mesh, axes=gaxes,
                                          sweeps=sweeps * apps,
                                          radius=spec.radius, periodic=per)
    else:
        for d in range(3):
            need = spec.radius[d] * sweeps * apps
            if grid_plan.n_shards[d] > 1 and grid_plan.halo[d] < need:
                raise ValueError(
                    f"grid_plan.halo[{d}]={grid_plan.halo[d]} planes/side "
                    f"cannot cover radius {spec.radius[d]} x sweeps "
                    f"{sweeps} x sweep_apps {apps} = {need}; re-plan with "
                    f"stencil_grid_sharding(..., sweeps={sweeps * apps})")
    if grid_plan.total_shards <= 1:
        # every axis fell back: same single-device fallback as the 1-D path
        if mode == "wavefront":
            from .sweeps import stencil_wavefront
            return stencil_wavefront(a, w, spec, sweeps=sweeps,
                                     plan=plan_kind, interpret=interpret)
        return stencil_apply(a, w, spec, plan=plan_kind, sweeps=sweeps,
                             path=path, interpret=interpret)
    names = grid_plan.axes
    hs = tuple(grid_plan.halo[d] if names[d] is not None else 0
               for d in range(3))
    m_l, n_l, p_l = grid_plan.local
    m_ext, n_ext, p_ext = m_l + 2 * hs[0], n_l + 2 * hs[1], p_l + 2 * hs[2]
    if block_j is not None and (names[1] is not None
                                or names[2] is not None):
        raise ValueError(f"{spec.name}: block_j tiling is incompatible with "
                         f"a j/k-sharded grid (axes={names}) -- the j/k "
                         f"ghosts are externally materialized; omit block_j")
    if mode == "wavefront" and names[0] is None and per[0]:
        raise ValueError(f"{spec.name}: the wavefront mode cannot run a "
                         f"periodic unsharded i axis inside a process grid "
                         f"(no local pre-extension there); shard i or use "
                         f"mode='fused'")
    batch = int(np.prod(a.shape[:-3])) if a.ndim > 3 else 1
    a4 = a.reshape(batch, m, n, p)
    acc = acc_dtype_for(a.dtype)
    var = spec.coef == "var"
    wf = spec.canon_weights(w, (m, n, p) if var else None).astype(acc)
    use_overlap = (overlap == "on" and names[0] is not None and hs[0] > 0
                   and m_l >= 2 * hs[0] and block_j is None
                   and mode != "wavefront")
    ebpp = exchange_bytes_per_point(a.dtype.itemsize, hs, grid_plan.local,
                                    sweeps, spec.n_weights if var else 0)
    # overlap tunes for the interior call (resident m_l planes); serialized
    # tunes for the one fully extended slab
    m_tune = m_l if use_overlap else m_ext
    if block_i is not None and m_tune % block_i != 0:
        raise ValueError(
            f"sharded block_i={block_i} must divide the local i extent "
            f"{m_tune} ({'resident, overlap interior' if use_overlap else 'halo-extended'}); "
            f"omit block_i to let the cost model choose")
    bi, bj, rpath = block_i, block_j, path
    run_mode = mode
    if run_mode == "auto":
        if use_overlap:
            run_mode = "fused"      # overlap is a fused-mode executor
        else:
            sel = autotune_sweeps(m_tune, n_ext, p_ext, a.dtype.itemsize,
                                  sweeps, cplan, block_j=bj, path=path,
                                  external_i_halo=names[0] is not None,
                                  exchange_bytes=ebpp["total"])
            run_mode = "wavefront" if sel.mode == "wavefront" else "fused"
            if run_mode == "wavefront" and names[0] is None and per[0]:
                run_mode = "fused"  # see the explicit-mode raise above
    if run_mode == "wavefront":
        if bj is not None:
            raise ValueError(f"{spec.name}: the wavefront mode is untiled "
                             f"(full-N blocks); omit block_j or use "
                             f"mode='fused'")
        if bi is None:
            bi = wavefront_block_i(m_ext, n_ext, p_ext, a.dtype.itemsize,
                                   sweeps, cplan)
        rpath = "wavefront"
    elif bi is None:
        rpath, bi, bj_auto = autotune_engine(m_tune, n_ext, p_ext,
                                             a.dtype.itemsize, sweeps=sweeps,
                                             plan=cplan, block_j=bj,
                                             path=path)
        bj = bj if bj is not None else bj_auto
        if names[1] is not None or names[2] is not None:
            bj = None               # external j/k ghosts: tiling disallowed
    elif rpath == "auto":
        rpath = "stream"
    fn = _grid_sharded_fn(cplan, mesh, names, bi, bj, sweeps, interpret,
                          grid_plan.halo, grid_plan.local,
                          grid_plan.n_shards, (m, n, p), grid_plan.spec,
                          rpath, run_mode, "on" if use_overlap else "off")
    return fn(a4, wf).reshape(a.shape)


def stencil_sharded(a: jax.Array, w: jax.Array,
                    stencil: Union[str, int, StencilSpec] = "stencil27",
                    mesh: Optional[Mesh] = None, axis: str = "data",
                    block_i: Optional[int] = None,
                    block_j: Optional[int] = None, plan: str = "auto",
                    sweeps: int = 1, path: str = "auto", mode: str = "fused",
                    bc=None, interpret: Optional[bool] = None,
                    shard_plan: Union[StencilShardPlan, StencilGridPlan,
                                      None] = None,
                    guard=None, axes=None,
                    overlap: str = "off") -> jax.Array:
    """Halo-exchange execution of ``stencil_apply`` over a mesh axis.

    ``a`` is ``(..., M, N, P)`` (volumetric specs only); ``mesh`` defaults to
    a 1-D mesh over every visible device.  Returns the same value as the
    single-device path; falls back to it when the planner declines to shard.
    ``path`` selects the per-shard data-movement strategy exactly as in
    ``stencil_apply`` -- ``"auto"`` streams the halo-extended local slab
    (each local plane fetched once), ``"replicate"`` re-fetches the halo
    neighbours per block (parity escape hatch).  ``mode`` selects the
    per-shard time integration: ``"fused"`` (default) runs one fused
    ``sweeps=s`` kernel per shard; ``"wavefront"`` runs the
    temporal-wavefront pipeline (:func:`~.ops.call_3d_wavefront`) per
    shard; ``"auto"`` races them on the sweeps-aware roofline over the
    halo-extended local slab.  Either way ``s`` sweeps cost *one*
    ``radius * sweep_apps * s``-deep ppermute round -- shard-edge strips
    are redundantly recomputed from the deep halo instead of re-exchanged
    per sweep.  ``bc`` overrides the
    spec's boundary conditions exactly as in ``stencil_apply``; a periodic
    i axis closes the halo exchange into a ring (wrap-around between shard
    0 and shard ``n-1``) while dirichlet/neumann ghosts materialize only on
    the boundary shards via the kernel's global-geometry fill.

    Note: the kernel runs per shard on the halo-extended local slab, so an
    explicit ``block_i`` must divide ``M / n_shards + 2 * sweeps`` (not M);
    it is ignored when the planner falls back to the unsharded path.  Omit
    it to let the plan-aware cost model choose in every configuration
    (including a j-tile width when the local slab overflows VMEM).

    ``axes`` generalizes ``axis`` to an (pi, pj, pk) *process grid*: a
    triple of mesh-axis names (``None`` = that domain axis stays whole),
    e.g. ``axes=("x", "y", "z")`` on a 2x2x2 mesh.  Face ghosts are
    exchanged per axis in the order j, k, i on the progressively extended
    slab, so corner/edge ghosts arrive transitively without diagonal
    sends (see :func:`~repro.sharding.stencil_grid_sharding`); per-axis
    BCs pick chain vs ring topology exactly as on the i axis.  Multi-axis
    sharding needs an explicit ``mesh`` and is incompatible with
    ``block_j`` tiling (the j/k ghosts are externally materialized).

    ``overlap="on"`` hides the i-axis exchange behind interior compute:
    the ghost-slab ppermutes are issued first, the interior i-planes
    (which need no ghosts) are swept while the collectives are in flight,
    and the two ``radius * sweep_apps * sweeps``-deep boundary strips are
    then computed from the arrived slabs.  Numerically it computes the
    same rows from the same data -- but through a separate strip kernel,
    so it is not guaranteed bit-exact against ``overlap="off"`` (the
    serialized escape hatch) on non-integer float data; it requires the
    fused mode and quietly serializes when the i axis is unsharded,
    j-tiled, or too thin (``M / n_shards < 2 * radius * sweep_apps *
    sweeps``).
    """
    if isinstance(plan, StencilShardPlan):
        raise TypeError(
            "stencil_sharded(plan=...) now selects the execution-plan kind "
            "(auto/direct/cse/factored); pass the partition plan as "
            "shard_plan=... instead")
    if path not in PATH_KINDS:
        raise ValueError(f"unknown path {path!r}; expected one of "
                         f"{PATH_KINDS}")
    if mode not in ("auto", "fused", "wavefront"):
        raise ValueError(f"unknown sharded mode {mode!r}; expected 'auto', "
                         f"'fused', or 'wavefront' (chained per-sweep "
                         f"exchange is exactly what the deep halo removes)")
    if overlap not in ("on", "off"):
        raise ValueError(f"unknown overlap {overlap!r}; expected 'on' or "
                         f"'off'")
    if axes is not None and len(axes) != 3:
        raise ValueError(f"axes must name 3 mesh axes (i, j, k; None = "
                         f"axis stays whole), got {axes!r}")
    spec = get_stencil(stencil)
    policy_src = spec.guard if guard is None else guard
    if policy_src is not None and policy_src != "off":
        from .guard import as_guard, guarded_sharded
        policy = as_guard(policy_src)
        if policy is not None:
            gspec = spec.with_bc(bc) if bc is not None else spec
            return guarded_sharded(a, w, gspec, policy, mesh=mesh, axis=axis,
                                   block_i=block_i, block_j=block_j,
                                   plan=plan, sweeps=sweeps, path=path,
                                   mode=mode, interpret=interpret,
                                   shard_plan=shard_plan, axes=axes,
                                   overlap=overlap)
    if spec.guard != "off":
        spec = spec.with_guard("off")   # guards never reach the trace
    if bc is not None:
        spec = spec.with_bc(bc)
    cplan = compile_plan(spec, plan)
    interpret = resolve_interpret(interpret)
    if mode == "wavefront" and spec.coef == "var":
        raise ValueError(f"{spec.name}: the wavefront mode needs constant "
                         f"coefficients; use mode='fused'")
    if spec.ndim != 3:
        raise ValueError(f"{spec.name}: sharded execution needs a volumetric "
                         f"(ndim=3) spec")
    if a.ndim < 3:
        raise ValueError(f"{spec.name}: need (..., M, N, P), got {a.shape}")
    grid_plan = shard_plan if isinstance(shard_plan, StencilGridPlan) else None
    grid_mode = (grid_plan is not None or overlap == "on"
                 or (axes is not None
                     and (axes[0] is None
                          or any(ax is not None for ax in axes[1:]))))
    if axes is not None and not grid_mode:
        axis = axes[0]              # 1-D spelling of the grid API
    if mesh is None:
        multi = ((axes is not None
                  and sum(1 for ax in axes if ax is not None) > 1)
                 or (grid_plan is not None
                     and sum(1 for ax in grid_plan.axes
                             if ax is not None) > 1))
        if multi:
            raise ValueError(
                "stencil_sharded: multi-axis sharding needs an explicit "
                "mesh -- build one with jax.make_mesh((pi, pj, pk), names) "
                "and pass its axis names in axes=(ai, aj, ak)")
        name = axis
        if axes is not None and axes[0] is not None:
            name = axes[0]
        elif grid_plan is not None and grid_plan.axes[0] is not None:
            name = grid_plan.axes[0]
        mesh = jax.make_mesh((jax.device_count(),), (name,))
    if grid_mode:
        gaxes = tuple(axes) if axes is not None else (axis, None, None)
        return _grid_dispatch(a, w, spec, cplan, mesh, gaxes, grid_plan,
                              block_i, block_j, plan, sweeps, path, mode,
                              overlap, interpret)
    m, n, p = a.shape[-3:]
    ri = spec.radius[0]
    periodic_i = spec.bc[0][0].kind == "periodic"
    apps = spec.sweep_apps              # red-black doubles the halo depth
    if shard_plan is None:
        shard_plan = stencil_halo_sharding(m, mesh, axis=axis,
                                           sweeps=sweeps * apps,
                                           radius=ri, periodic=periodic_i)
    if shard_plan.n_shards > 1 and shard_plan.halo < ri * sweeps * apps:
        raise ValueError(
            f"shard_plan.halo={shard_plan.halo} rows/side cannot cover "
            f"radius {ri} x sweeps {sweeps} x sweep_apps {apps} = "
            f"{ri * sweeps * apps}; re-plan with "
            f"stencil_halo_sharding(..., sweeps={sweeps * apps}, "
            f"radius={ri})")
    if shard_plan.n_shards <= 1:
        # An explicit block_i is sized for the halo-extended local slab; it
        # generally doesn't divide M, so let the cost model choose here --
        # the same call must work whatever the device count.
        if mode == "wavefront":
            from .sweeps import stencil_wavefront
            return stencil_wavefront(a, w, spec, sweeps=sweeps, plan=plan,
                                     interpret=interpret)
        return stencil_apply(a, w, spec, plan=plan, sweeps=sweeps,
                             path=path, interpret=interpret)

    batch = int(np.prod(a.shape[:-3])) if a.ndim > 3 else 1
    a4 = a.reshape(batch, m, n, p)
    acc = acc_dtype_for(a.dtype)
    # var weights canonicalize to (n_weights, M, N, P) and shard with the
    # domain (same PartitionSpec: the i axis sits at axis 1 either way)
    dom = (m, n, p) if spec.coef == "var" else None
    wf = spec.canon_weights(w, dom).astype(acc)
    h, m_loc, n_sh = shard_plan.halo, shard_plan.local_rows, shard_plan.n_shards
    m_ext = m_loc + 2 * h
    if block_i is not None and m_ext % block_i != 0:
        raise ValueError(
            f"sharded block_i={block_i} must divide the halo-extended local "
            f"slab (M/n_shards + 2*radius*sweeps = {m_loc} + {2 * h} = "
            f"{m_ext}); omit block_i to let the cost model choose")
    bi, bj, rpath = block_i, block_j, path
    run_mode = mode
    if run_mode == "auto":
        sel = autotune_sweeps(m_ext, n, p, a.dtype.itemsize, sweeps, cplan,
                              block_j=bj, path=path, external_i_halo=True)
        run_mode = "wavefront" if sel.mode == "wavefront" else "fused"
    if run_mode == "wavefront":
        if bj is not None:
            raise ValueError(f"{spec.name}: the wavefront mode is untiled "
                             f"(full-N blocks); omit block_j or use "
                             f"mode='fused'")
        if bi is None:
            bi = wavefront_block_i(m_ext, n, p, a.dtype.itemsize, sweeps,
                                   cplan)
        rpath = "wavefront"
    elif bi is None:
        rpath, bi, bj_auto = autotune_engine(m_ext, n, p, a.dtype.itemsize,
                                             sweeps=sweeps, plan=cplan,
                                             block_j=bj, path=path)
        bj = bj if bj is not None else bj_auto
    elif rpath == "auto":
        rpath = "stream"
    fn = _sharded_fn(cplan, mesh, axis, bi, bj, sweeps, interpret, h, m_loc,
                     n_sh, m, shard_plan.spec, rpath, run_mode)
    return fn(a4, wf).reshape(a.shape)
