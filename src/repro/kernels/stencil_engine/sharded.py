"""Multi-device stencil execution: shard_map over the i-axis + halo exchange.

The partition plan comes from ``repro.sharding.planner.stencil_halo_sharding``
(divisibility and halo-depth checks, PlanNote audit trail).  Each shard owns a
contiguous slab of i-rows and trades ``radius * sweeps`` halo rows with its
neighbours via ``lax.ppermute``.  The exchange topology follows the spec's
i-axis boundary condition: a *chain* for the non-periodic BCs (edge shards
receive zeros, which the kernel's global-geometry ghost fill then turns into
the clamp / dirichlet / neumann boundary -- so those BCs materialize only on
the boundary shards) or a closed *ring* for periodic (shard 0 and shard N-1
trade wrap-around halos).  Each shard
then runs the *same* fused plan-compiled Pallas kernel as the
single-device path -- by default the plane-streaming body, so the shard_map
body also fetches each local plane from HBM exactly once and carries the
halo window in VMEM scratch (``path="replicate"`` stays available as the
parity escape hatch, and j-tiled blocking engages when the local N x P slab
exceeds the VMEM budget); the kernel's geometry operand (global row offset,
global M) keeps the interior/boundary masking correct across shard seams.

The compiled shard_map program is memoized in a small bounded cache keyed on
the mesh's *device ids + topology + axis names* (plus the execution
geometry), not on the ``Mesh`` object itself -- equal test meshes share one
entry and the cache can never retain more than ``_SHARDED_CACHE_MAX``
programs (the old ``lru_cache`` keyed on ``Mesh`` kept up to 64 meshes alive
indefinitely).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .._compat import shard_map

from ...sharding.planner import StencilShardPlan, stencil_halo_sharding
from .autotune import (PATH_KINDS, autotune_engine, autotune_sweeps,
                       wavefront_block_i)
from .kernel import acc_dtype_for
from .ops import call_3d, call_3d_wavefront, resolve_interpret, stencil_apply
from .plan import StencilPlan, compile_plan
from .spec import StencilSpec, get_stencil

_SHARDED_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
_SHARDED_CACHE_MAX = 32

# Fault injection (tests): a callable (lo, hi) -> (lo, hi) applied to the
# ppermute'd halo slabs inside the traced shard_map body -- the fault lives
# in the exchanged data itself, exactly where a real link corruption would.
# The version counter rides the program cache key so installing/clearing a
# fault always retraces instead of reusing a clean (or faulty) program.
_HALO_FAULT = [None]
_HALO_FAULT_VERSION = [0]


def set_halo_fault(fn) -> None:
    """Install (or clear, with ``None``) the halo-exchange fault hook.
    Only :mod:`.faults` calls this."""
    _HALO_FAULT[0] = fn
    _HALO_FAULT_VERSION[0] += 1


def _mesh_key(mesh: Mesh) -> tuple:
    """Hashable mesh identity that does not retain the Mesh object: device
    platforms + ids (ids restart at 0 per backend), topology shape, and axis
    names."""
    return (tuple((d.platform, int(d.id)) for d in mesh.devices.flat),
            tuple(mesh.devices.shape), tuple(mesh.axis_names))


def _sharded_fn(cplan: StencilPlan, mesh: Mesh, axis: str, bi: int,
                bj: Optional[int], sweeps: int, interpret: bool, h: int,
                m_loc: int, n_sh: int, m: int, part, path: str = "stream",
                mode: str = "fused"):
    """Build (and cache) the jitted shard_map program for one geometry, so
    repeated calls don't retrace the inner pallas_call."""
    key = (cplan, _mesh_key(mesh), axis, bi, bj, sweeps, interpret, h,
           m_loc, n_sh, m, part, path, mode, _HALO_FAULT_VERSION[0])
    fn = _SHARDED_CACHE.get(key)
    if fn is not None:
        _SHARDED_CACHE.move_to_end(key)
        return fn
    periodic_i = cplan.spec.bc[0][0].kind == "periodic"
    if periodic_i:
        # ring: shard 0's low halo wraps around from shard n-1 (and vice
        # versa) -- the periodic BC *is* the wrap-around link
        lo_perm = [(i, (i + 1) % n_sh) for i in range(n_sh)]
        hi_perm = [((i + 1) % n_sh, i) for i in range(n_sh)]
    else:
        # chain: edge shards get zeros; the kernel's global-geometry ghost
        # fill turns them into the clamp / dirichlet / neumann boundary
        # (so non-periodic BCs only materialize on the boundary shards)
        lo_perm = [(i, i + 1) for i in range(n_sh - 1)]
        hi_perm = [(i + 1, i) for i in range(n_sh - 1)]

    var = cplan.spec.coef == "var"

    def _halo_ext(x: jax.Array) -> jax.Array:
        # x is (lead, M_loc, N, P): the i axis sits at axis 1 for both the
        # batched field (lead = batch) and the canonicalized coefficient
        # stack (lead = n_weights), so one exchange serves both.
        lo = jax.lax.ppermute(x[:, -h:], axis, lo_perm)
        hi = jax.lax.ppermute(x[:, :h], axis, hi_perm)
        if _HALO_FAULT[0] is not None:
            lo, hi = _HALO_FAULT[0](lo, hi)
        return jnp.concatenate([lo, x, hi], axis=1)

    def local_fn(a_loc: jax.Array, wf_: jax.Array) -> jax.Array:
        idx = jax.lax.axis_index(axis)
        ext = _halo_ext(a_loc)
        wx = _halo_ext(wf_) if var else wf_
        geom = jnp.stack([idx * m_loc - h,
                          jnp.int32(m)]).astype(jnp.int32)
        if mode == "wavefront":
            # one radius*sweep_apps*sweeps-deep exchange already happened
            # (ext); the pipeline redundantly recomputes the shard-edge
            # strip exactly like the fused deep halo does
            out = call_3d_wavefront(ext, wx, geom, cplan, bi, sweeps,
                                    interpret)
        else:
            out = call_3d(ext, wx, geom, cplan, bi, bj, sweeps, interpret,
                          path, external_i_halo=True)
        return out[:, h:h + m_loc]

    w_spec = part if var else P(None)
    fn = jax.jit(shard_map(local_fn, mesh=mesh, in_specs=(part, w_spec),
                           out_specs=part, check_rep=False))
    _SHARDED_CACHE[key] = fn
    while len(_SHARDED_CACHE) > _SHARDED_CACHE_MAX:
        _SHARDED_CACHE.popitem(last=False)
    return fn


def stencil_sharded(a: jax.Array, w: jax.Array,
                    stencil: Union[str, int, StencilSpec] = "stencil27",
                    mesh: Optional[Mesh] = None, axis: str = "data",
                    block_i: Optional[int] = None,
                    block_j: Optional[int] = None, plan: str = "auto",
                    sweeps: int = 1, path: str = "auto", mode: str = "fused",
                    bc=None, interpret: Optional[bool] = None,
                    shard_plan: Optional[StencilShardPlan] = None,
                    guard=None) -> jax.Array:
    """Halo-exchange execution of ``stencil_apply`` over a mesh axis.

    ``a`` is ``(..., M, N, P)`` (volumetric specs only); ``mesh`` defaults to
    a 1-D mesh over every visible device.  Returns the same value as the
    single-device path; falls back to it when the planner declines to shard.
    ``path`` selects the per-shard data-movement strategy exactly as in
    ``stencil_apply`` -- ``"auto"`` streams the halo-extended local slab
    (each local plane fetched once), ``"replicate"`` re-fetches the halo
    neighbours per block (parity escape hatch).  ``mode`` selects the
    per-shard time integration: ``"fused"`` (default) runs one fused
    ``sweeps=s`` kernel per shard; ``"wavefront"`` runs the
    temporal-wavefront pipeline (:func:`~.ops.call_3d_wavefront`) per
    shard; ``"auto"`` races them on the sweeps-aware roofline over the
    halo-extended local slab.  Either way ``s`` sweeps cost *one*
    ``radius * sweep_apps * s``-deep ppermute round -- shard-edge strips
    are redundantly recomputed from the deep halo instead of re-exchanged
    per sweep.  ``bc`` overrides the
    spec's boundary conditions exactly as in ``stencil_apply``; a periodic
    i axis closes the halo exchange into a ring (wrap-around between shard
    0 and shard ``n-1``) while dirichlet/neumann ghosts materialize only on
    the boundary shards via the kernel's global-geometry fill.

    Note: the kernel runs per shard on the halo-extended local slab, so an
    explicit ``block_i`` must divide ``M / n_shards + 2 * sweeps`` (not M);
    it is ignored when the planner falls back to the unsharded path.  Omit
    it to let the plan-aware cost model choose in every configuration
    (including a j-tile width when the local slab overflows VMEM).
    """
    if isinstance(plan, StencilShardPlan):
        raise TypeError(
            "stencil_sharded(plan=...) now selects the execution-plan kind "
            "(auto/direct/cse/factored); pass the partition plan as "
            "shard_plan=... instead")
    if path not in PATH_KINDS:
        raise ValueError(f"unknown path {path!r}; expected one of "
                         f"{PATH_KINDS}")
    if mode not in ("auto", "fused", "wavefront"):
        raise ValueError(f"unknown sharded mode {mode!r}; expected 'auto', "
                         f"'fused', or 'wavefront' (chained per-sweep "
                         f"exchange is exactly what the deep halo removes)")
    spec = get_stencil(stencil)
    policy_src = spec.guard if guard is None else guard
    if policy_src is not None and policy_src != "off":
        from .guard import as_guard, guarded_sharded
        policy = as_guard(policy_src)
        if policy is not None:
            gspec = spec.with_bc(bc) if bc is not None else spec
            return guarded_sharded(a, w, gspec, policy, mesh=mesh, axis=axis,
                                   block_i=block_i, block_j=block_j,
                                   plan=plan, sweeps=sweeps, path=path,
                                   mode=mode, interpret=interpret,
                                   shard_plan=shard_plan)
    if spec.guard != "off":
        spec = spec.with_guard("off")   # guards never reach the trace
    if bc is not None:
        spec = spec.with_bc(bc)
    cplan = compile_plan(spec, plan)
    interpret = resolve_interpret(interpret)
    if mode == "wavefront" and spec.coef == "var":
        raise ValueError(f"{spec.name}: the wavefront mode needs constant "
                         f"coefficients; use mode='fused'")
    if spec.ndim != 3:
        raise ValueError(f"{spec.name}: sharded execution needs a volumetric "
                         f"(ndim=3) spec")
    if a.ndim < 3:
        raise ValueError(f"{spec.name}: need (..., M, N, P), got {a.shape}")
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(),), (axis,))
    m, n, p = a.shape[-3:]
    ri = spec.radius[0]
    periodic_i = spec.bc[0][0].kind == "periodic"
    apps = spec.sweep_apps              # red-black doubles the halo depth
    if shard_plan is None:
        shard_plan = stencil_halo_sharding(m, mesh, axis=axis,
                                           sweeps=sweeps * apps,
                                           radius=ri, periodic=periodic_i)
    if shard_plan.n_shards > 1 and shard_plan.halo < ri * sweeps * apps:
        raise ValueError(
            f"shard_plan.halo={shard_plan.halo} rows/side cannot cover "
            f"radius {ri} x sweeps {sweeps} x sweep_apps {apps} = "
            f"{ri * sweeps * apps}; re-plan with "
            f"stencil_halo_sharding(..., sweeps={sweeps * apps}, "
            f"radius={ri})")
    if shard_plan.n_shards <= 1:
        # An explicit block_i is sized for the halo-extended local slab; it
        # generally doesn't divide M, so let the cost model choose here --
        # the same call must work whatever the device count.
        if mode == "wavefront":
            from .sweeps import stencil_wavefront
            return stencil_wavefront(a, w, spec, sweeps=sweeps, plan=plan,
                                     interpret=interpret)
        return stencil_apply(a, w, spec, plan=plan, sweeps=sweeps,
                             path=path, interpret=interpret)

    batch = int(np.prod(a.shape[:-3])) if a.ndim > 3 else 1
    a4 = a.reshape(batch, m, n, p)
    acc = acc_dtype_for(a.dtype)
    # var weights canonicalize to (n_weights, M, N, P) and shard with the
    # domain (same PartitionSpec: the i axis sits at axis 1 either way)
    dom = (m, n, p) if spec.coef == "var" else None
    wf = spec.canon_weights(w, dom).astype(acc)
    h, m_loc, n_sh = shard_plan.halo, shard_plan.local_rows, shard_plan.n_shards
    m_ext = m_loc + 2 * h
    if block_i is not None and m_ext % block_i != 0:
        raise ValueError(
            f"sharded block_i={block_i} must divide the halo-extended local "
            f"slab (M/n_shards + 2*radius*sweeps = {m_loc} + {2 * h} = "
            f"{m_ext}); omit block_i to let the cost model choose")
    bi, bj, rpath = block_i, block_j, path
    run_mode = mode
    if run_mode == "auto":
        sel = autotune_sweeps(m_ext, n, p, a.dtype.itemsize, sweeps, cplan,
                              block_j=bj, path=path, external_i_halo=True)
        run_mode = "wavefront" if sel.mode == "wavefront" else "fused"
    if run_mode == "wavefront":
        if bj is not None:
            raise ValueError(f"{spec.name}: the wavefront mode is untiled "
                             f"(full-N blocks); omit block_j or use "
                             f"mode='fused'")
        if bi is None:
            bi = wavefront_block_i(m_ext, n, p, a.dtype.itemsize, sweeps,
                                   cplan)
        rpath = "wavefront"
    elif bi is None:
        rpath, bi, bj_auto = autotune_engine(m_ext, n, p, a.dtype.itemsize,
                                             sweeps=sweeps, plan=cplan,
                                             block_j=bj, path=path)
        bj = bj if bj is not None else bj_auto
    elif rpath == "auto":
        rpath = "stream"
    fn = _sharded_fn(cplan, mesh, axis, bi, bj, sweeps, interpret, h, m_loc,
                     n_sh, m, shard_plan.spec, rpath, run_mode)
    return fn(a4, wf).reshape(a.shape)
