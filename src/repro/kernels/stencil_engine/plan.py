"""Plan IR: compile a :class:`StencilSpec` into an explicit tap schedule.

This is the paper's synthesis step (sect. 4: emit the kernel as a factored
instruction schedule, not 27 independent multiply-adds) made explicit as a
tiny SSA program that is *compiled before tracing* and then interpreted at
trace time by both the Pallas kernel and the jnp reference.  Because the two
executors walk the identical op list, the f64 paths stay bit-for-bit equal,
and the plan's static ``shifts``/``flops`` counts feed the block-size cost
model instead of the old blind ``2 * taps`` estimate.

Three plan kinds:

``direct``
    The naive schedule -- one shift per nonzero offset component per tap,
    one multiply-add per tap (54 shifts + 53 flop-ops for stencil27).  Kept
    as an escape hatch for parity testing.

``cse``
    Common-subexpression-eliminated direct schedule for *arbitrary* masks:
    taps are grouped by ``(dj, dk)`` so each trailing-plane shift is built
    once (j-shifts of ``u`` are themselves shared across ``dk``) and reused
    across ``di in {-1, 0, 1}``; per-``di`` partial sums are shifted once
    along i at the end (10 shifts + 53 flop-ops for stencil27).

``factored``
    The paper's partial-sum factorization for mirror-symmetric specs
    (stencil7, stencil27, any ``spec_from_mask`` mask closed under per-axis
    sign flips with weights depending only on ``(|di|, |dj|, |dk|)``):
    k-neighbour pair sums are built once, reused across j, then across i --
    8 shifts + 19 flop-ops for stencil27, i.e. <= 1/3 of the direct shift
    count and <= 40% of its flop count.

Shifts are single-axis, single-step ops with zero fill (static slices on the
halo-extended block -- no wrap-around values are ever computed then masked;
the vacated positions only ever land on rows the Dirichlet mask zeroes).

Determinism, precisely: a plan fixes the *mathematical* op sequence, so on
exact arithmetic (integer-valued data and weights within the mantissa) every
plan kind, blocking, and tiling is bit-identical -- the property tests
assert this.  In floating point, XLA/LLVM may contract a ``w * x + y`` into
an fma in one compiled program and not another (the choice follows fusion
shape, survives ``optimization_barrier`` and bitcast fences, and is *not*
controllable from JAX), so cross-*program* bit-equality -- e.g. j-tiled vs
untiled -- is only a per-op <= 1-ulp agreement in general.  Same-plan
kernel-vs-reference f64 parity for the blessed configurations (the engine's
reference path, asserted in tier-1) has been bit-exact in practice; the
builders keep products feeding their adds directly (scales are hoisted past
shifts: ``shift(w * x) -> w * shift(x)``, identical op counts) to keep the
contraction pattern as uniform as possible across programs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from .spec import StencilSpec, get_stencil

Offset = Tuple[int, int, int]

PLAN_KINDS = ("auto", "direct", "cse", "factored")


@dataclasses.dataclass(frozen=True)
class PlanOp:
    """One SSA op.  Value ids: 0 is the input ``u``; op ``k`` defines id
    ``k + 1``.  ``shift``: value ``a`` moved by ``off`` (exactly one nonzero
    +-1 component, ``out[x] = in[x + off]``, zero fill).  ``scale``:
    ``w[w_idx] * a``.  ``add``: ``a + b``.  ``fma``: ``b + w[w_idx] * a``."""

    kind: str                     # "shift" | "scale" | "add" | "fma"
    a: int
    b: int = -1
    off: Offset = (0, 0, 0)
    w_idx: int = -1


@dataclasses.dataclass(frozen=True)
class StencilPlan:
    """A compiled execution schedule for one spec.

    ``out`` is the id of the final value (-1 for an empty tap list, which
    executes as zeros).  ``shifts``/``flops`` are the static op counts the
    cost model consumes: each shift is one full-block lane/sublane move, and
    flops count multiplies and adds (an fma is two).
    """

    spec: StencilSpec
    kind: str                     # "direct" | "cse" | "factored"
    ops: Tuple[PlanOp, ...]
    out: int

    @property
    def shifts(self) -> int:
        return sum(1 for op in self.ops if op.kind == "shift")

    @property
    def flops(self) -> int:
        return sum({"scale": 1, "add": 1, "fma": 2}.get(op.kind, 0)
                   for op in self.ops)

    def describe(self) -> Dict[str, int]:
        """Machine-readable op counts (benchmark / JSON artifact form)."""
        return {"taps": self.spec.taps, "shifts": self.shifts,
                "flops": self.flops, "ops": len(self.ops)}


class _Builder:
    """Emit helper: returns the SSA id of each new value."""

    def __init__(self):
        self.ops: List[PlanOp] = []

    def _emit(self, op: PlanOp) -> int:
        self.ops.append(op)
        return len(self.ops)          # u is id 0; op k defines id k + 1

    def shift(self, a: int, axis: int, d: int) -> int:
        off = [0, 0, 0]
        off[axis] = d
        return self._emit(PlanOp("shift", a, off=tuple(off)))

    def scale(self, w_idx: int, a: int) -> int:
        return self._emit(PlanOp("scale", a, w_idx=w_idx))

    def add(self, a: int, b: int) -> int:
        return self._emit(PlanOp("add", a, b))

    def fma(self, w_idx: int, a: int, acc: int) -> int:
        return self._emit(PlanOp("fma", a, acc, w_idx=w_idx))

    def acc(self, w_idx: int, a: int, acc: Optional[int]) -> int:
        return self.scale(w_idx, a) if acc is None else self.fma(w_idx, a, acc)


def mirror_symmetric(spec: StencilSpec) -> bool:
    """True when the tap set is closed under per-axis sign flips and the
    weight index depends only on ``(|di|, |dj|, |dk|)`` -- the condition for
    the factored partial-sum schedule to be exact."""
    wmap = dict(zip(spec.offsets, spec.w_index))
    for (di, dj, dk), wi in wmap.items():
        for si in ((1, -1) if di else (1,)):
            for sj in ((1, -1) if dj else (1,)):
                for sk in ((1, -1) if dk else (1,)):
                    if wmap.get((di * si, dj * sj, dk * sk)) != wi:
                        return False
    return True


def _direct_ops(spec: StencilSpec, b: _Builder) -> Optional[int]:
    """Naive schedule: shift per nonzero offset component, fma per tap, in
    the spec's lexicographic order (the seed engine's arithmetic)."""
    acc = None
    for off, wi in zip(spec.offsets, spec.w_index):
        t = 0
        for axis, d in enumerate(off):
            if d:
                t = b.shift(t, axis, d)
        acc = b.acc(wi, t, acc)
    return acc


def _cse_ops(spec: StencilSpec, b: _Builder) -> Optional[int]:
    """Grouped schedule: one shift per distinct ``(dj, dk)`` plane (j-shifts
    of ``u`` shared across dk), reused across ``di``; per-``di`` partial sums
    are shifted along i once at the end.  A single-tap ``di`` group would
    shift a bare product, so its scale is hoisted past the i-shift (same op
    counts -- see the module determinism invariant)."""
    if not spec.offsets:
        return None
    by_di: Dict[int, List[Tuple[int, int, int]]] = {}
    for (di, dj, dk), wi in zip(spec.offsets, spec.w_index):
        by_di.setdefault(di, []).append((dj, dk, wi))
    jshift: Dict[int, int] = {0: 0}
    plane: Dict[Tuple[int, int], int] = {}
    for dj, dk in sorted({(dj, dk) for g in by_di.values()
                          for dj, dk, _ in g}):
        if dj not in jshift:
            jshift[dj] = b.shift(0, 1, dj)
        plane[(dj, dk)] = (b.shift(jshift[dj], 2, dk) if dk
                           else jshift[dj])
    out = None
    for di in sorted(by_di):
        group = sorted(by_di[di])
        if di and len(group) == 1:
            dj, dk, wi = group[0]
            out = b.acc(wi, b.shift(plane[(dj, dk)], 0, di), out)
            continue
        acc = None
        for dj, dk, wi in group:
            acc = b.acc(wi, plane[(dj, dk)], acc)
        term = b.shift(acc, 0, di) if di else acc
        out = term if out is None else b.add(out, term)
    return out


def _factored_ops(spec: StencilSpec, b: _Builder) -> Optional[int]:
    """Partial-sum schedule for mirror-symmetric specs: k-pair sums swept
    once, reused across j (j-pair sums), combined per ``|di|``, then reused
    across i -- the paper's factored 27-point kernel as a plan."""
    if not spec.offsets:
        return None
    classes: Dict[Tuple[int, int, int], int] = {}
    for off, wi in zip(spec.offsets, spec.w_index):
        classes[(abs(off[0]), abs(off[1]), abs(off[2]))] = wi
    k_sum: Dict[int, int] = {}
    for c in sorted({c for _, _, c in classes}):
        k_sum[c] = 0 if c == 0 else b.add(b.shift(0, 2, -1),
                                          b.shift(0, 2, 1))
    j_sum: Dict[Tuple[int, int], int] = {}
    for bb, c in sorted({(bb, c) for _, bb, c in classes}):
        j_sum[(bb, c)] = (k_sum[c] if bb == 0
                          else b.add(b.shift(k_sum[c], 1, -1),
                                     b.shift(k_sum[c], 1, 1)))
    out = None
    if any(a == 0 for a, _, _ in classes):
        acc = None
        for bb, c in sorted((bb, c) for aa, bb, c in classes if aa == 0):
            acc = b.acc(classes[(0, bb, c)], j_sum[(bb, c)], acc)
        out = acc
    pairs_1 = sorted((bb, c) for aa, bb, c in classes if aa == 1)
    if len(pairs_1) == 1:
        # a single |di|=1 class would shift a bare product; hoist the scale
        # past the i-pair sum (same op counts -- determinism invariant)
        bb, c = pairs_1[0]
        pair = b.add(b.shift(j_sum[(bb, c)], 0, -1),
                     b.shift(j_sum[(bb, c)], 0, 1))
        out = b.acc(classes[(1, bb, c)], pair, out)
    elif pairs_1:
        acc = None
        for bb, c in pairs_1:
            acc = b.acc(classes[(1, bb, c)], j_sum[(bb, c)], acc)
        pair = b.add(b.shift(acc, 0, -1), b.shift(acc, 0, 1))
        out = pair if out is None else b.add(out, pair)
    return out


@functools.lru_cache(maxsize=256)
def _compile_plan_cached(spec: StencilSpec, kind: str) -> StencilPlan:
    """The memoized synthesis step, keyed on the *canonical* (spec, resolved
    plan kind) pair -- a frozen spec hashes on its name + tap/weight-index
    tuples, so repeated eager/un-jitted calls, the autotuner, and
    equal-valued ad-hoc ``spec_from_mask`` specs all share one compiled
    schedule instead of rebuilding the SSA program per call."""
    b = _Builder()
    build = {"direct": _direct_ops, "cse": _cse_ops,
             "factored": _factored_ops}[kind]
    out = build(spec, b)
    return StencilPlan(spec=spec, kind=kind, ops=tuple(b.ops),
                       out=-1 if out is None else out)


def compile_plan(spec: Union[str, int, StencilSpec],
                 plan: str = "auto") -> StencilPlan:
    """Compile ``spec`` into a :class:`StencilPlan` (memoized).

    ``plan="auto"`` picks ``factored`` for mirror-symmetric specs (stencil3,
    stencil7, stencil27, symmetric masks) and ``cse`` otherwise;
    ``plan="direct"`` is the naive parity escape hatch.  The spec and the
    plan kind are canonicalized *before* the cache lookup, so
    ``compile_plan("27")``, ``compile_plan("stencil27")`` and
    ``compile_plan(get_stencil("stencil27"))`` -- and ``plan="auto"`` vs its
    resolved kind -- return the identical plan object.
    """
    spec = get_stencil(spec)
    if plan not in PLAN_KINDS:
        raise ValueError(f"unknown plan {plan!r}; expected one of {PLAN_KINDS}")
    kind = plan
    if kind == "auto":
        kind = "factored" if mirror_symmetric(spec) else "cse"
    if kind == "factored" and not mirror_symmetric(spec):
        raise ValueError(
            f"{spec.name}: factored plan needs a mirror-symmetric tap set "
            f"(closed under per-axis sign flips, weights on |offsets|); "
            f"use plan='cse' or 'auto'")
    return _compile_plan_cached(spec, kind)


def shift_slice(t: jax.Array, off: Offset) -> jax.Array:
    """``out[x] = t[x + off]`` along one trailing axis, zero fill -- a static
    slice plus an edge pad, never a wrap-around roll.  ``off`` indexes the
    (i, j, k) axes as the trailing three dims (k-only specs use only the
    last)."""
    (idx, d), = [(i, o) for i, o in enumerate(off) if o]
    axis = t.ndim - 3 + idx
    src = [slice(None)] * t.ndim
    src[axis] = slice(1, None) if d > 0 else slice(0, -1)
    pad_shape = list(t.shape)
    pad_shape[axis] = 1
    pad = jnp.zeros(pad_shape, t.dtype)
    body = t[tuple(src)]
    return jnp.concatenate([body, pad] if d > 0 else [pad, body], axis=axis)


def execute_plan(cplan: StencilPlan, u: jax.Array, w: jax.Array,
                 shift=shift_slice) -> jax.Array:
    """Interpret the plan at trace time.  ``u`` must already carry the
    accumulation dtype; ``w`` is the canonical flat weight vector in the same
    dtype.  Both the Pallas kernel and the jnp reference call this -- one op
    walk, identical arithmetic (see the module docstring for what that
    guarantees bitwise)."""
    if cplan.out < 0:
        return jnp.zeros_like(u)
    vals = [u]
    for op in cplan.ops:
        if op.kind == "shift":
            v = shift(vals[op.a], op.off)
        elif op.kind == "scale":
            v = w[op.w_idx] * vals[op.a]
        elif op.kind == "add":
            v = vals[op.a] + vals[op.b]
        else:                                     # fma
            v = vals[op.b] + w[op.w_idx] * vals[op.a]
        vals.append(v)
    return vals[cplan.out]
