"""Plan- and path-aware cost-model block selection.

Same shape of reasoning as ``repro.core.perfmodel``: performance is
``min(compute limit, bandwidth limit)``, so the modeled time of one grid step
is ``max(DMA time, VPU time)`` and we pick the feasible (path, block) pair
minimizing the modeled time per output point:

* DMA bytes/step: every staged input view plus one output block.  The
  *replicated* path stages 3 i-neighbour views untiled (9 i/j views
  j-tiled); the *streaming* path fetches each i-block once (one
  identity-mapped view untiled, the 3 j-neighbour views j-tiled) and
  carries the halo in VMEM scratch -- see :func:`bytes_per_point`.  Fused
  sweeps amortize the traffic over ``s`` operator applications.
* VPU ops/step: the *plan's* static op counts -- ``flops + shifts`` per
  point of the extended working strip per sweep (a lane shift occupies the
  VPU like a flop), not the old blind ``2 * taps``.  A factored stencil27
  plan (8 shifts + 19 flops) therefore models ~4x cheaper than the naive
  schedule (54 + 53), which shifts the DMA/VPU crossover -- the paper's
  Table-4 point that the synthesized schedule changes which resource binds.
* VMEM residency: the staged tiles (input dtype) + the extended working
  strip and its tap accumulator (accumulation dtype) -- plus, on the
  streaming path, the ``bi + s``-plane rotating scratch window -- must fit
  the budget: the paper's Table-2 "registers required vs registers
  available" constraint in VMEM terms.

Feasible blocks divide M (and N when j-tiled -- Pallas grid constraint) and
satisfy ``bi, bj >= s`` (the carried window / +-1-block halo must cover the
fused-sweep depth).  j-tiling engages only when no full-N block fits the
budget.  Ties prefer sublane multiples (8), as the old heuristic did.

:func:`autotune_engine` is the top-level entry: it races the streaming and
replicated rooflines per shape and returns ``(path, block_i, block_j)`` --
streaming wins whenever it is feasible (it moves 2 bytes/point where the
replicated path moves 4, or 4 vs 10 j-tiled) but the replicated path
remains reachable as the ``path="replicate"`` parity escape hatch and for
shapes where the streaming scratch window itself overflows VMEM.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

# TPU-v5e-flavoured roofline constants (per core), only ever used as a ratio.
HBM_BW = 819e9          # bytes/s
VPU_FLOPS = 3e12        # f32 elementwise flop/s

PATH_KINDS = ("auto", "stream", "replicate")


def _divisors(x: int) -> List[int]:
    small, large = [], []
    d = 1
    while d * d <= x:
        if x % d == 0:
            small.append(d)
            if d != x // d:
                large.append(x // d)
        d += 1
    return small + large[::-1]


def _plan_ops(plan, taps: int) -> Tuple[int, int]:
    """(shifts, flops) per extended point per sweep; ``plan=None`` keeps the
    legacy ``2 * taps`` pure-flop accounting for old callers."""
    if plan is not None:
        return plan.shifts, plan.flops
    return 0, 2 * taps


def _views(j_tiled: bool, path: str) -> int:
    """Input views staged per grid step: the streaming path fetches each
    block once (plus the 3 j-neighbour tiles when j-tiled); the replicated
    path re-fetches the full 3 (untiled) / 9 (j-tiled) halo neighbourhood."""
    if path == "stream":
        return 3 if j_tiled else 1
    return 9 if j_tiled else 3


def _geometry(bi: int, bj: Optional[int], n: int, sweeps: int,
              path: str = "replicate"):
    """(output columns, extended columns, staged input views) per step."""
    if bj is None:
        return n, n, _views(False, path)
    return bj, bj + 2 * sweeps, _views(True, path)


def bytes_per_point(path: str, itemsize: int, j_tiled: bool = False,
                    sweeps: int = 1) -> float:
    """Modeled HBM bytes moved per output point per call (reads + the one
    write), amortized over ``sweeps`` fused applications.

    Streaming untiled is the paper's ideal ~2 transfers/point: each input
    plane read exactly once, each output plane written once.  The replicated
    path re-reads every plane per staged view: 3 + 1 untiled, 9 + 1
    j-tiled.  Streaming j-tiled re-reads along j only (3 + 1).
    """
    if path not in ("stream", "replicate"):
        raise ValueError(f"unknown path {path!r}; expected 'stream' or "
                         f"'replicate'")
    return (_views(j_tiled, path) + 1) * itemsize / sweeps


def _step_time(bi: int, bj: Optional[int], n: int, p: int, itemsize: int,
               sweeps: int, shifts: int, flops: int,
               path: str = "replicate") -> float:
    wj, ej, views = _geometry(bi, bj, n, sweeps, path)
    dma = (views + 1.0) * bi * wj * p * itemsize / HBM_BW
    vpu = ((flops + shifts) * sweeps * (bi + 2 * sweeps) * ej * p
           / VPU_FLOPS)
    return max(dma, vpu) / (bi * wj * p * sweeps)  # per output point-sweep


def _fits(bi: int, bj: Optional[int], n: int, p: int, itemsize: int,
          sweeps: int, acc_itemsize: int, vmem_budget: int,
          path: str = "replicate") -> bool:
    wj, ej, views = _geometry(bi, bj, n, sweeps, path)
    io_tiles = (views + 1) * bi * wj * p * itemsize
    scratch = ((bi + sweeps) * ej * p * itemsize if path == "stream" else 0)
    working = 2 * (bi + 2 * sweeps) * ej * p * acc_itemsize
    return io_tiles + scratch + working <= vmem_budget


def autotune_blocks(m: int, n: int, p: int, itemsize: int,
                    sweeps: int = 1, plan=None, taps: int = 27,
                    acc_itemsize: int = 4,
                    vmem_budget: int = 8 * 1024 * 1024,
                    block_j: Optional[int] = None,
                    allow_j_tiling: bool = True,
                    path: str = "replicate"
                    ) -> Tuple[int, Optional[int]]:
    """Smallest modeled time per output point over feasible blockings of one
    execution ``path``.

    Returns ``(block_i, block_j)`` with ``block_j=None`` meaning untiled
    (full-N) blocks.  j-tiling is considered only when no untiled block fits
    ``vmem_budget`` (or when ``block_j`` pins a tile width).  ``plan`` (a
    :class:`~.plan.StencilPlan`) supplies the actual shift/flop counts;
    without it the legacy ``2 * taps`` estimate applies.
    """
    shifts, flops = _plan_ops(plan, taps)
    cands_i = [bi for bi in _divisors(m) if bi >= sweeps] or [m]

    def key(bi: int, bj: Optional[int]):
        return (_step_time(bi, bj, n, p, itemsize, sweeps, shifts, flops,
                           path),
                0 if (bi % 8 == 0 or bi < 8) else 1,
                -bi * (bj if bj is not None else n))

    if block_j is None:
        feasible = [bi for bi in cands_i
                    if _fits(bi, None, n, p, itemsize, sweeps, acc_itemsize,
                             vmem_budget, path)]
        if feasible:
            return min(feasible, key=lambda bi: key(bi, None)), None
        if not allow_j_tiling:      # nothing fits: smallest legal block
            return cands_i[0], None
        cands_j = [bj for bj in _divisors(n) if sweeps <= bj < n] or [n]
    else:
        cands_j = [block_j]
    pairs = [(bi, bj) for bi in cands_i for bj in cands_j
             if _fits(bi, bj, n, p, itemsize, sweeps, acc_itemsize,
                      vmem_budget, path)]
    if pairs:
        return min(pairs, key=lambda bb: key(*bb))
    return cands_i[0], cands_j[0]   # nothing fits: smallest legal tile


def autotune_engine(m: int, n: int, p: int, itemsize: int,
                    sweeps: int = 1, plan=None, taps: int = 27,
                    acc_itemsize: int = 4,
                    vmem_budget: int = 8 * 1024 * 1024,
                    block_j: Optional[int] = None,
                    path: str = "auto"
                    ) -> Tuple[str, int, Optional[int]]:
    """Race the streaming and replicated rooflines: returns the modeled-best
    ``(path, block_i, block_j)`` over both paths' feasible blockings.

    ``path="stream"``/``"replicate"`` pins the path and only tunes blocks.
    Feasible streaming (strictly fewer HBM bytes per point, same VPU work)
    wins every tie; the replicated path is chosen only when the streaming
    scratch window cannot fit the VMEM budget at any legal blocking.
    """
    if path not in PATH_KINDS:
        raise ValueError(f"unknown path {path!r}; expected one of "
                         f"{PATH_KINDS}")
    shifts, flops = _plan_ops(plan, taps)
    cands = ("stream", "replicate") if path == "auto" else (path,)
    best = None
    for cand in cands:
        bi, bj = autotune_blocks(m, n, p, itemsize, sweeps=sweeps, plan=plan,
                                 taps=taps, acc_itemsize=acc_itemsize,
                                 vmem_budget=vmem_budget, block_j=block_j,
                                 path=cand)
        feasible = _fits(bi, bj, n, p, itemsize, sweeps, acc_itemsize,
                         vmem_budget, cand)
        t = _step_time(bi, bj, n, p, itemsize, sweeps, shifts, flops, cand)
        # infeasible blockings only ever win when nothing fits anywhere;
        # the streaming path wins exact ties (strictly fewer HBM bytes).
        rank = (0 if feasible else 1, t, 0 if cand == "stream" else 1)
        if best is None or rank < best[0]:
            best = (rank, cand, bi, bj)
    return best[1], best[2], best[3]


def autotune_block_i(m: int, n: int, p: int, itemsize: int,
                     sweeps: int = 1, taps: int = 27, plan=None,
                     acc_itemsize: int = 4,
                     vmem_budget: int = 8 * 1024 * 1024) -> int:
    """Untiled (full-N) i-block choice -- the pre-j-tiling entry point."""
    bi, _ = autotune_blocks(m, n, p, itemsize, sweeps=sweeps, plan=plan,
                            taps=taps, acc_itemsize=acc_itemsize,
                            vmem_budget=vmem_budget, allow_j_tiling=False)
    return bi


def pick_block_i(m: int, n: int, p: int, itemsize: int,
                 vmem_budget: int = 8 * 1024 * 1024) -> int:
    """Legacy entry point (kept for the MXU kernel and old callers)."""
    return autotune_block_i(m, n, p, itemsize, sweeps=1, taps=27,
                            vmem_budget=vmem_budget)


def pick_block_rows(rows: int, p: int, itemsize: int,
                    vmem_budget: int = 4 << 20) -> int:
    """Row-block choice for the k-only (1-D) path: the largest power-of-two
    row count whose tile fits the budget; when no power of two divides
    ``rows``, the largest *fitting divisor* (never an over-budget full-rows
    block, which the old fallback could return)."""
    for cand in (256, 128, 64, 32, 16, 8):
        if rows % cand == 0 and cand * p * itemsize <= vmem_budget:
            return cand
    for cand in sorted(_divisors(rows), reverse=True):
        if cand * p * itemsize <= vmem_budget:
            return cand
    return 1
