"""Plan-aware cost-model block selection (replaces ``pick_block_i``).

Same shape of reasoning as ``repro.core.perfmodel``: performance is
``min(compute limit, bandwidth limit)``, so the modeled time of one grid step
is ``max(DMA time, VPU time)`` and we pick the feasible block minimizing the
modeled time per output point:

* DMA bytes/step: every staged input view (3 i-neighbours untiled, 3x3
  i/j-neighbours when j-tiled) plus one output block; fused sweeps amortize
  this over ``s`` operator applications.
* VPU ops/step: the *plan's* static op counts -- ``flops + shifts`` per
  point of the extended working block per sweep (a lane shift occupies the
  VPU like a flop), not the old blind ``2 * taps``.  A factored stencil27
  plan (8 shifts + 19 flops) therefore models ~4x cheaper than the naive
  schedule (54 + 53), which shifts the DMA/VPU crossover -- the paper's
  Table-4 point that the synthesized schedule changes which resource binds.
* VMEM residency: the staged tiles (input dtype) + the extended working
  block and its tap accumulator (accumulation dtype) must fit the budget --
  the paper's Table-2 "registers required vs registers available"
  constraint in VMEM terms.

Feasible blocks divide M (and N when j-tiled -- Pallas grid constraint) and
satisfy ``bi, bj >= s`` (the +-1-block halo must cover the fused-sweep
depth).  j-tiling engages only when no full-N block fits the budget --
previously a hard wall where ``autotune_block_i`` returned an infeasible
block.  Ties prefer sublane multiples (8), as the old heuristic did.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

# TPU-v5e-flavoured roofline constants (per core), only ever used as a ratio.
HBM_BW = 819e9          # bytes/s
VPU_FLOPS = 3e12        # f32 elementwise flop/s


def _divisors(x: int) -> List[int]:
    small, large = [], []
    d = 1
    while d * d <= x:
        if x % d == 0:
            small.append(d)
            if d != x // d:
                large.append(x // d)
        d += 1
    return small + large[::-1]


def _plan_ops(plan, taps: int) -> Tuple[int, int]:
    """(shifts, flops) per extended point per sweep; ``plan=None`` keeps the
    legacy ``2 * taps`` pure-flop accounting for old callers."""
    if plan is not None:
        return plan.shifts, plan.flops
    return 0, 2 * taps


def _geometry(bi: int, bj: Optional[int], n: int, sweeps: int):
    """(output columns, extended columns, staged input views) per step."""
    if bj is None:
        return n, n, 3
    return bj, bj + 2 * sweeps, 9


def _step_time(bi: int, bj: Optional[int], n: int, p: int, itemsize: int,
               sweeps: int, shifts: int, flops: int) -> float:
    wj, ej, views = _geometry(bi, bj, n, sweeps)
    dma = (views + 1.0) * bi * wj * p * itemsize / HBM_BW
    vpu = ((flops + shifts) * sweeps * (bi + 2 * sweeps) * ej * p
           / VPU_FLOPS)
    return max(dma, vpu) / (bi * wj * p * sweeps)  # per output point-sweep


def _fits(bi: int, bj: Optional[int], n: int, p: int, itemsize: int,
          sweeps: int, acc_itemsize: int, vmem_budget: int) -> bool:
    wj, ej, views = _geometry(bi, bj, n, sweeps)
    io_tiles = (views + 1) * bi * wj * p * itemsize
    working = 2 * (bi + 2 * sweeps) * ej * p * acc_itemsize
    return io_tiles + working <= vmem_budget


def autotune_blocks(m: int, n: int, p: int, itemsize: int,
                    sweeps: int = 1, plan=None, taps: int = 27,
                    acc_itemsize: int = 4,
                    vmem_budget: int = 8 * 1024 * 1024,
                    block_j: Optional[int] = None,
                    allow_j_tiling: bool = True
                    ) -> Tuple[int, Optional[int]]:
    """Smallest modeled time per output point over feasible blockings.

    Returns ``(block_i, block_j)`` with ``block_j=None`` meaning untiled
    (full-N) blocks.  j-tiling is considered only when no untiled block fits
    ``vmem_budget`` (or when ``block_j`` pins a tile width).  ``plan`` (a
    :class:`~.plan.StencilPlan`) supplies the actual shift/flop counts;
    without it the legacy ``2 * taps`` estimate applies.
    """
    shifts, flops = _plan_ops(plan, taps)
    cands_i = [bi for bi in _divisors(m) if bi >= sweeps] or [m]

    def key(bi: int, bj: Optional[int]):
        return (_step_time(bi, bj, n, p, itemsize, sweeps, shifts, flops),
                0 if (bi % 8 == 0 or bi < 8) else 1,
                -bi * (bj if bj is not None else n))

    if block_j is None:
        feasible = [bi for bi in cands_i
                    if _fits(bi, None, n, p, itemsize, sweeps, acc_itemsize,
                             vmem_budget)]
        if feasible:
            return min(feasible, key=lambda bi: key(bi, None)), None
        if not allow_j_tiling:      # nothing fits: smallest legal block
            return cands_i[0], None
        cands_j = [bj for bj in _divisors(n) if sweeps <= bj < n] or [n]
    else:
        cands_j = [block_j]
    pairs = [(bi, bj) for bi in cands_i for bj in cands_j
             if _fits(bi, bj, n, p, itemsize, sweeps, acc_itemsize,
                      vmem_budget)]
    if pairs:
        return min(pairs, key=lambda bb: key(*bb))
    return cands_i[0], cands_j[0]   # nothing fits: smallest legal tile


def autotune_block_i(m: int, n: int, p: int, itemsize: int,
                     sweeps: int = 1, taps: int = 27, plan=None,
                     acc_itemsize: int = 4,
                     vmem_budget: int = 8 * 1024 * 1024) -> int:
    """Untiled (full-N) i-block choice -- the pre-j-tiling entry point."""
    bi, _ = autotune_blocks(m, n, p, itemsize, sweeps=sweeps, plan=plan,
                            taps=taps, acc_itemsize=acc_itemsize,
                            vmem_budget=vmem_budget, allow_j_tiling=False)
    return bi


def pick_block_i(m: int, n: int, p: int, itemsize: int,
                 vmem_budget: int = 8 * 1024 * 1024) -> int:
    """Legacy entry point (kept for the MXU kernel and old callers)."""
    return autotune_block_i(m, n, p, itemsize, sweeps=1, taps=27,
                            vmem_budget=vmem_budget)


def pick_block_rows(rows: int, p: int, itemsize: int,
                    vmem_budget: int = 4 << 20) -> int:
    """Row-block choice for the k-only (1-D) path: the largest power-of-two
    row count whose tile fits the budget; when no power of two divides
    ``rows``, the largest *fitting divisor* (never an over-budget full-rows
    block, which the old fallback could return)."""
    for cand in (256, 128, 64, 32, 16, 8):
        if rows % cand == 0 and cand * p * itemsize <= vmem_budget:
            return cand
    for cand in sorted(_divisors(rows), reverse=True):
        if cand * p * itemsize <= vmem_budget:
            return cand
    return 1
