"""Plan-, path-, and radius-aware cost-model block selection.

Same shape of reasoning as ``repro.core.perfmodel``: performance is
``min(compute limit, bandwidth limit)``, so the modeled time of one grid step
is ``max(DMA time, VPU time)`` and we pick the feasible (path, block) pair
minimizing the modeled time per output point:

* DMA bytes/step: every staged input view plus one output block.  The
  *replicated* path stages ``2*ri + 1`` i-neighbour views untiled
  (``(2*ri + 1) * (2*rj + 1)`` i/j views j-tiled); the *streaming* path
  fetches each i-block once (one identity-mapped view untiled, the
  ``2*rj + 1`` j-neighbour views j-tiled) and carries the halo in VMEM
  scratch -- see :func:`bytes_per_point`.  Streaming therefore stays at
  ~2 transfers/point *at any radius* while the replicated cost grows with
  ``r``; fused sweeps amortize the traffic over ``s`` applications.
* VPU ops/step: the *plan's* static op counts -- ``flops + shifts`` per
  point of the extended working strip per sweep (a lane shift occupies the
  VPU like a flop), not a blind ``2 * taps``.  A factored stencil27 plan
  (8 shifts + 19 flops) therefore models ~4x cheaper than the naive
  schedule (54 + 53), which shifts the DMA/VPU crossover -- the paper's
  Table-4 point that the synthesized schedule changes which resource binds.
* VMEM residency: the staged tiles (input dtype) + the extended working
  strip and its tap accumulator (accumulation dtype) -- plus, on the
  streaming path, the ``bi + ri * sweeps``-plane rotating scratch window --
  must fit the budget: the paper's Table-2 "registers required vs registers
  available" constraint in VMEM terms.

Feasible blocks divide M (and N when j-tiled -- Pallas grid constraint) and
satisfy ``bi >= ri * s`` / ``bj >= rj * s`` (the carried window / +-1-block
halo must cover the fused-sweep halo depth).  j-tiling engages only when no
full-N block fits the budget.  Ties prefer sublane multiples (8), as the old
heuristic did.

:func:`autotune_engine` is the top-level entry: it races the streaming and
replicated rooflines per shape and returns ``(path, block_i, block_j)`` --
streaming wins whenever it is feasible (it moves 2 bytes/point where the
replicated path moves ``2*ri + 2``, or ``2*rj + 2`` vs
``(2ri+1)(2rj+1) + 1`` j-tiled) but the replicated path remains reachable
as the ``path="replicate"`` parity escape hatch and for shapes where the
streaming scratch window itself overflows VMEM.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

from .common import DEFAULT_VMEM_BUDGET, divisors as _divisors

# TPU-v5e-flavoured roofline constants (per core), only ever used as a ratio.
HBM_BW = 819e9          # bytes/s
VPU_FLOPS = 3e12        # f32 elementwise flop/s

PATH_KINDS = ("auto", "stream", "replicate")

# Time-integration execution modes for ``s`` sweeps (see autotune_sweeps):
# one fused pallas_call with a radius*s-deep halo, s pipelined wavefront
# stages each carrying the single-sweep halo, or s chained single-sweep
# calls (one HBM round-trip per sweep -- the bit-exact baseline).
SWEEP_MODES = ("auto", "fused", "wavefront", "chained")

RadiusLike = Union[int, Tuple[int, int, int], None]

# ---------------------------------------------------------------------------
# Guarded-execution candidate blacklist.
#
# Historically a candidate that raised at compile or run time was fatal: the
# autotuner would happily re-select it on the next call and the caller would
# crash again.  The guard's degradation ladder (see .guard) records a
# demoted candidate here after its retry also fails, and the two autotune
# races consult the registry so a known-bad (spec, mode) / (spec, path)
# pair drops out of future selections -- the process-local analogue of the
# paper's "discard variants the simulator rejects" step.  Empty by default,
# so unguarded behaviour is unchanged.
# ---------------------------------------------------------------------------

_BLACKLIST: set = set()


def blacklist_candidate(spec_name: str, mode: Optional[str] = None,
                        path: Optional[str] = None) -> None:
    """Exclude a sweep ``mode`` and/or a data-movement ``path`` from future
    ``auto`` races for the named spec (pinned modes/paths stay reachable --
    an explicit request is the caller's escape hatch)."""
    if mode is None and path is None:
        raise ValueError("blacklist_candidate needs a mode and/or a path")
    if mode is not None:
        _BLACKLIST.add((str(spec_name), "mode", mode))
    if path is not None:
        _BLACKLIST.add((str(spec_name), "path", path))


def is_blacklisted(spec_name: str, mode: Optional[str] = None,
                   path: Optional[str] = None) -> bool:
    return ((mode is not None
             and (str(spec_name), "mode", mode) in _BLACKLIST)
            or (path is not None
                and (str(spec_name), "path", path) in _BLACKLIST))


def clear_blacklist(spec_name: Optional[str] = None) -> None:
    """Drop every blacklist entry (or only the named spec's)."""
    if spec_name is None:
        _BLACKLIST.clear()
    else:
        for e in [e for e in _BLACKLIST if e[0] == str(spec_name)]:
            _BLACKLIST.discard(e)


def list_blacklist() -> Tuple[Tuple[str, str, str], ...]:
    return tuple(sorted(_BLACKLIST))


def _radius3(radius: RadiusLike, plan=None) -> Tuple[int, int, int]:
    """Canonicalize a radius argument: ``None`` defers to the plan's spec
    (radius-1 when neither is given); an int is isotropic."""
    if radius is None:
        if plan is not None:
            return tuple(plan.spec.radius)
        return (1, 1, 1)
    if isinstance(radius, int):
        return (radius, radius, radius)
    r = tuple(int(x) for x in radius)
    if len(r) != 3:
        raise ValueError(f"radius must be an int or 3-tuple, got {radius!r}")
    return r


def _plan_ops(plan, taps: int) -> Tuple[int, int]:
    """(shifts, flops) per extended point per sweep; ``plan=None`` keeps the
    legacy ``2 * taps`` pure-flop accounting for old callers."""
    if plan is not None:
        return plan.shifts, plan.flops
    return 0, 2 * taps


def _plan_apps(plan) -> int:
    """Operator applications per sweep: ``spec.sweep_apps`` (2 for red-black
    Gauss-Seidel, whose fused halo and VPU work both double), 1 without a
    plan (legacy Jacobi callers)."""
    if plan is not None:
        return plan.spec.sweep_apps
    return 1


def _plan_var_weights(plan) -> int:
    """Coefficient planes staged per input view: ``n_weights`` for a
    variable-coefficient plan (its weights are domain-shaped fields that
    ride the same block walk as the input), 0 for constant coefficients
    (register/VMEM-resident, no per-block traffic)."""
    if plan is not None and plan.spec.coef == "var":
        return plan.spec.n_weights
    return 0


def _views(j_tiled: bool, path: str, ri: int = 1, rj: int = 1) -> int:
    """Input views staged per grid step: the streaming path fetches each
    block once (plus the ``2rj + 1`` j-neighbour tiles when j-tiled); the
    replicated path re-fetches the full ``2ri + 1`` (untiled) /
    ``(2ri+1)(2rj+1)`` (j-tiled) halo neighbourhood."""
    if path == "stream":
        return (2 * rj + 1) if j_tiled else 1
    return (2 * ri + 1) * (2 * rj + 1) if j_tiled else (2 * ri + 1)


def _geometry(bi: int, bj: Optional[int], n: int, sweeps: int,
              path: str = "replicate",
              radius: Tuple[int, int, int] = (1, 1, 1), apps: int = 1):
    """(output columns, extended columns, staged input views) per step."""
    ri, rj, _ = radius
    if bj is None:
        return n, n, _views(False, path, ri, rj)
    return bj, bj + 2 * rj * sweeps * apps, _views(True, path, ri, rj)


def bytes_per_point(path: str, itemsize: int, j_tiled: bool = False,
                    sweeps: int = 1, radius: RadiusLike = None,
                    coef: str = "const", n_weights: int = 0) -> float:
    """Modeled HBM bytes moved per output point per call (reads + the one
    write), amortized over ``sweeps`` fused applications.

    Streaming untiled is the paper's ideal ~2 transfers/point *at any
    radius*: each input plane read exactly once, each output plane written
    once.  The replicated path re-reads every plane per staged view:
    ``2ri + 2`` untiled, ``(2ri+1)(2rj+1) + 1`` j-tiled (4 and 10 at
    radius 1, 6 and 26 at radius 2).  Streaming j-tiled re-reads along j
    only (``2rj + 2``).

    ``coef="var"`` adds the coefficient traffic: ``n_weights`` planes ride
    every staged input view (co-streamed / replicated exactly like the
    field), so e.g. streaming untiled moves ``2 + n_weights`` transfers
    per point.  Constant coefficients stay resident and move nothing.

    ``path="wavefront"`` is the temporal-wavefront pipeline (untiled,
    constant coefficients): one read + one write amortized over ``sweeps``
    pipelined stages -- ``2 * itemsize / sweeps``, the paper's streaming
    ideal extended through time.  (A periodic i axis re-reads its
    ``2 * radius * sweep_apps * sweeps`` pre-extension rows on top of
    this canonical figure; see ``autotune_sweeps`` for the shape-aware
    number.)
    """
    if path == "wavefront":
        if j_tiled:
            raise ValueError("the wavefront path is untiled (full-N blocks)")
        if coef == "var":
            raise ValueError("the wavefront path needs constant coefficients")
        return 2 * itemsize / sweeps
    if path not in ("stream", "replicate"):
        raise ValueError(f"unknown path {path!r}; expected 'stream', "
                         f"'replicate', or 'wavefront'")
    ri, rj, _ = _radius3(radius)
    nv = _views(j_tiled, path, ri, rj)
    wv = nv * n_weights if coef == "var" else 0
    return (nv + wv + 1) * itemsize / sweeps


def _step_time(bi: int, bj: Optional[int], n: int, p: int, itemsize: int,
               sweeps: int, shifts: int, flops: int,
               path: str = "replicate",
               radius: Tuple[int, int, int] = (1, 1, 1),
               var_weights: int = 0, apps: int = 1) -> float:
    """``var_weights`` > 0 (a variable-coefficient plan) charges that many
    coefficient planes of DMA per staged input view -- modeled at the input
    itemsize (the coefficient dtype is the accumulation dtype; the model is
    only consumed relatively, per spec).  ``apps`` scales the VPU work and
    the halo-redundant strip extent (red-black runs 2 masked applications
    per sweep)."""
    wj, ej, views = _geometry(bi, bj, n, sweeps, path, radius, apps)
    dma = ((views * (1 + var_weights) + 1.0) * bi * wj * p * itemsize
           / HBM_BW)
    vpu = ((flops + shifts) * apps * sweeps
           * (bi + 2 * radius[0] * sweeps * apps) * ej * p / VPU_FLOPS)
    return max(dma, vpu) / (bi * wj * p * sweeps)  # per output point-sweep


def _fits(bi: int, bj: Optional[int], n: int, p: int, itemsize: int,
          sweeps: int, acc_itemsize: int, vmem_budget: int,
          path: str = "replicate",
          radius: Tuple[int, int, int] = (1, 1, 1),
          var_weights: int = 0, apps: int = 1) -> bool:
    wj, ej, views = _geometry(bi, bj, n, sweeps, path, radius, apps)
    hi = radius[0] * sweeps * apps
    io_tiles = (views + 1) * bi * wj * p * itemsize
    scratch = (bi + hi) * ej * p * itemsize if path == "stream" else 0
    working = 2 * (bi + 2 * hi) * ej * p * acc_itemsize
    if var_weights:
        # staged coefficient views + co-rotating scratch + assembled strip,
        # all in the accumulation dtype
        io_tiles += views * var_weights * bi * wj * p * acc_itemsize
        if path == "stream":
            scratch += var_weights * (bi + hi) * ej * p * acc_itemsize
        working += var_weights * (bi + 2 * hi) * ej * p * acc_itemsize
    return io_tiles + scratch + working <= vmem_budget


def autotune_blocks(m: int, n: int, p: int, itemsize: int,
                    sweeps: int = 1, plan=None, taps: int = 27,
                    acc_itemsize: int = 4,
                    vmem_budget: int = DEFAULT_VMEM_BUDGET,
                    block_j: Optional[int] = None,
                    allow_j_tiling: bool = True,
                    path: str = "replicate",
                    radius: RadiusLike = None
                    ) -> Tuple[int, Optional[int]]:
    """Smallest modeled time per output point over feasible blockings of one
    execution ``path``.

    Returns ``(block_i, block_j)`` with ``block_j=None`` meaning untiled
    (full-N) blocks.  j-tiling is considered only when no untiled block fits
    ``vmem_budget`` (or when ``block_j`` pins a tile width).  ``plan`` (a
    :class:`~.plan.StencilPlan`) supplies the actual shift/flop counts and
    the spec radius; without it the legacy radius-1 ``2 * taps`` estimate
    applies.
    """
    shifts, flops = _plan_ops(plan, taps)
    var_w = _plan_var_weights(plan)
    apps = _plan_apps(plan)
    rad = _radius3(radius, plan)
    min_bi = max(1, rad[0] * sweeps * apps)
    min_bj = max(1, rad[1] * sweeps * apps)
    cands_i = [bi for bi in _divisors(m) if bi >= min_bi] or [m]

    def key(bi: int, bj: Optional[int]):
        return (_step_time(bi, bj, n, p, itemsize, sweeps, shifts, flops,
                           path, rad, var_w, apps),
                0 if (bi % 8 == 0 or bi < 8) else 1,
                -bi * (bj if bj is not None else n))

    if block_j is None:
        feasible = [bi for bi in cands_i
                    if _fits(bi, None, n, p, itemsize, sweeps, acc_itemsize,
                             vmem_budget, path, rad, var_w, apps)]
        if feasible:
            return min(feasible, key=lambda bi: key(bi, None)), None
        if not allow_j_tiling:      # nothing fits: smallest legal block
            return cands_i[0], None
        cands_j = [bj for bj in _divisors(n) if min_bj <= bj < n] or [n]
    else:
        cands_j = [block_j]
    pairs = [(bi, bj) for bi in cands_i for bj in cands_j
             if _fits(bi, bj, n, p, itemsize, sweeps, acc_itemsize,
                      vmem_budget, path, rad, var_w, apps)]
    if pairs:
        return min(pairs, key=lambda bb: key(*bb))
    return cands_i[0], cands_j[0]   # nothing fits: smallest legal tile


def autotune_engine(m: int, n: int, p: int, itemsize: int,
                    sweeps: int = 1, plan=None, taps: int = 27,
                    acc_itemsize: int = 4,
                    vmem_budget: int = DEFAULT_VMEM_BUDGET,
                    block_j: Optional[int] = None,
                    path: str = "auto",
                    radius: RadiusLike = None
                    ) -> Tuple[str, int, Optional[int]]:
    """Race the streaming and replicated rooflines: returns the modeled-best
    ``(path, block_i, block_j)`` over both paths' feasible blockings.

    ``path="stream"``/``"replicate"`` pins the path and only tunes blocks.
    Feasible streaming (strictly fewer HBM bytes per point at any radius,
    same VPU work) wins every tie; the replicated path is chosen only when
    the streaming scratch window cannot fit the VMEM budget at any legal
    blocking.
    """
    if path not in PATH_KINDS:
        raise ValueError(f"unknown path {path!r}; expected one of "
                         f"{PATH_KINDS}")
    shifts, flops = _plan_ops(plan, taps)
    var_w = _plan_var_weights(plan)
    apps = _plan_apps(plan)
    rad = _radius3(radius, plan)
    cands = ("stream", "replicate") if path == "auto" else (path,)
    if path == "auto" and plan is not None:
        live = tuple(c for c in cands
                     if not is_blacklisted(plan.spec.name, path=c))
        cands = live or cands       # never race an empty field
    best = None
    for cand in cands:
        bi, bj = autotune_blocks(m, n, p, itemsize, sweeps=sweeps, plan=plan,
                                 taps=taps, acc_itemsize=acc_itemsize,
                                 vmem_budget=vmem_budget, block_j=block_j,
                                 path=cand, radius=rad)
        feasible = _fits(bi, bj, n, p, itemsize, sweeps, acc_itemsize,
                         vmem_budget, cand, rad, var_w, apps)
        t = _step_time(bi, bj, n, p, itemsize, sweeps, shifts, flops, cand,
                       rad, var_w, apps)
        # infeasible blockings only ever win when nothing fits anywhere;
        # the streaming path wins exact ties (strictly fewer HBM bytes).
        rank = (0 if feasible else 1, t, 0 if cand == "stream" else 1)
        if best is None or rank < best[0]:
            best = (rank, cand, bi, bj)
    return best[1], best[2], best[3]


def autotune_block_i(m: int, n: int, p: int, itemsize: int,
                     sweeps: int = 1, taps: int = 27, plan=None,
                     acc_itemsize: int = 4,
                     vmem_budget: int = DEFAULT_VMEM_BUDGET) -> int:
    """Untiled (full-N) i-block choice -- the pre-j-tiling entry point."""
    bi, _ = autotune_blocks(m, n, p, itemsize, sweeps=sweeps, plan=plan,
                            taps=taps, acc_itemsize=acc_itemsize,
                            vmem_budget=vmem_budget, allow_j_tiling=False)
    return bi


def pick_block_i(m: int, n: int, p: int, itemsize: int,
                 vmem_budget: int = DEFAULT_VMEM_BUDGET) -> int:
    """Legacy entry point (kept for the MXU kernel and old callers)."""
    return autotune_block_i(m, n, p, itemsize, sweeps=1, taps=27,
                            vmem_budget=vmem_budget)


def pick_block_rows(rows: int, p: int, itemsize: int,
                    vmem_budget: int = DEFAULT_VMEM_BUDGET) -> int:
    """Row-block choice for the k-only (1-D) path: the largest power-of-two
    row count whose tile fits the budget; when no power of two divides
    ``rows``, the largest *fitting divisor* (never an over-budget full-rows
    block, which the old fallback could return)."""
    for cand in (256, 128, 64, 32, 16, 8):
        if rows % cand == 0 and cand * p * itemsize <= vmem_budget:
            return cand
    for cand in sorted(_divisors(rows), reverse=True):
        if cand * p * itemsize <= vmem_budget:
            return cand
    return 1


# ---------------------------------------------------------------------------
# Temporal wavefront tiling: the sweeps-aware roofline race.
# ---------------------------------------------------------------------------

def _fits_wavefront(bi: int, n: int, p: int, itemsize: int, sweeps: int,
                    acc_itemsize: int, vmem_budget: int, ha: int) -> bool:
    """VMEM residency of the wavefront pipeline at block ``bi``: the staged
    input view + output block, ``sweeps`` rotating stage windows of
    ``bi + ha`` planes (stage 1 input dtype, the rest accumulation dtype),
    and one working strip + accumulator per concurrently-live stage compute
    (stages run sequentially within a step, so two strips bound the live
    set)."""
    io = 2 * bi * n * p * itemsize
    scratch = ((bi + ha) * n * p * itemsize
               + (sweeps - 1) * (bi + ha) * n * p * acc_itemsize)
    working = 2 * (bi + 2 * ha) * n * p * acc_itemsize
    return io + scratch + working <= vmem_budget


def _wavefront_step_time(bi: int, n: int, p: int, itemsize: int, sweeps: int,
                         shifts: int, flops: int, ha: int, apps: int,
                         read_factor: float = 1.0) -> float:
    """Modeled seconds per output point-sweep of the wavefront pipeline:
    one input-block read (scaled by ``read_factor`` -- ``m_ext / m`` for a
    periodic pre-extension, 1 otherwise) + one output-block write per step
    against ``sweeps`` stage computations, each over the ``bi + 2 * ha``
    single-sweep strip (the wavefront's VPU advantage: the fused path's
    strip is ``bi + 2 * radius * sweeps * apps`` wide)."""
    dma = (read_factor + 1.0) * bi * n * p * itemsize / HBM_BW
    vpu = ((flops + shifts) * apps * sweeps * (bi + 2 * ha) * n * p
           / VPU_FLOPS)
    return max(dma, vpu) / (bi * n * p * sweeps)


def wavefront_block_i(m: int, n: int, p: int, itemsize: int, sweeps: int,
                      plan, acc_itemsize: int = 4,
                      vmem_budget: int = DEFAULT_VMEM_BUDGET) -> int:
    """Best modeled i-block for the wavefront pipeline over divisors of
    ``m`` (the *run* extent -- pre-extended for periodic) with
    ``bi >= ha``; the smallest legal block when nothing fits the budget
    (mirroring :func:`autotune_blocks`)."""
    shifts, flops = _plan_ops(plan, plan.spec.taps)
    apps = _plan_apps(plan)
    ha = plan.spec.radius[0] * apps
    cands = [bi for bi in _divisors(m) if bi >= ha] or [m]

    def key(bi: int):
        return (_wavefront_step_time(bi, n, p, itemsize, sweeps, shifts,
                                     flops, ha, apps),
                0 if (bi % 8 == 0 or bi < 8) else 1, -bi)

    feasible = [bi for bi in cands
                if _fits_wavefront(bi, n, p, itemsize, sweeps, acc_itemsize,
                                   vmem_budget, ha)]
    if feasible:
        return min(feasible, key=key)
    return cands[0]


def exchange_bytes_per_point(itemsize: int, halos, locs, sweeps: int = 1,
                             n_weights: int = 0) -> Dict[str, float]:
    """Per-axis halo-exchange traffic of the multi-axis sharded executor,
    in bytes per owned point per sweep.

    ``halos``/``locs`` are the per-domain-axis (i, j, k) deep halo and
    local extent (halo 0 = axis unsharded, no exchange).  The executor
    exchanges one axis at a time on the *progressively extended* slab
    (j, then k, then i -- the transitive corner fill), so each later
    axis's face slabs carry the earlier axes' ghost columns and grow
    accordingly: that growth is the entire cost of corner correctness --
    no extra diagonal messages.  Each sharded axis moves two face slabs
    per shard (send+receive symmetric, counted once as arriving bytes);
    variable-coefficient specs ship ``n_weights`` coefficient slabs with
    the field (the ``1 + n_weights`` factor).  ``sweeps`` fused sweeps
    amortize the one deep exchange, exactly like the compute-side deep
    halo.  Returns ``{"i", "j", "k", "total"}``."""
    hi, hj, hk = halos
    m_l, n_l, p_l = locs
    stacks = itemsize * (1 + n_weights)
    bj = 2 * hj * m_l * p_l * stacks
    bk = 2 * hk * m_l * (n_l + 2 * hj) * stacks
    bi = 2 * hi * (n_l + 2 * hj) * (p_l + 2 * hk) * stacks
    pts = m_l * n_l * p_l * max(sweeps, 1)
    return {"i": bi / pts, "j": bj / pts, "k": bk / pts,
            "total": (bi + bj + bk) / pts}


@dataclasses.dataclass(frozen=True)
class SweepSelection:
    """The sweeps-aware autotuner's verdict for one ``(spec, shape, s)``.

    ``mode`` is the chosen time-integration strategy (fused / wavefront /
    chained), ``path`` the spatial data-movement path underneath it
    (``"wavefront"`` for the wavefront pipeline; stream/replicate
    otherwise), and ``candidates`` the full race table --
    ``(mode, path, block_i, block_j, bytes_per_point, time_per_point,
    feasible)`` per entrant -- which is what lets the regression gate
    judge whether a selection flip is consistent with the fresh model.
    """

    sweeps: int
    mode: str
    path: str
    block_i: int
    block_j: Optional[int]
    bytes_per_point: float          # modeled HBM bytes per point per sweep
    time_per_point: float           # modeled seconds per point per sweep
    candidates: Tuple[Tuple[str, str, int, Optional[int], float, float,
                            bool], ...] = ()

    def describe(self) -> Dict[str, object]:
        """Machine-readable selection record (benchmark / JSON form)."""
        return {"selection": {
            "sweeps": self.sweeps, "mode": self.mode, "path": self.path,
            "block_i": self.block_i, "block_j": self.block_j,
            "bytes_per_point": self.bytes_per_point,
            "time_per_point": self.time_per_point,
            "candidates": [
                {"mode": mo, "path": pa, "block_i": bi, "block_j": bj,
                 "bytes_per_point": bpp, "time_per_point": tpp,
                 "feasible": fe}
                for mo, pa, bi, bj, bpp, tpp, fe in self.candidates],
        }}


def autotune_sweeps(m: int, n: int, p: int, itemsize: int, sweeps: int,
                    plan, acc_itemsize: int = 4,
                    vmem_budget: int = DEFAULT_VMEM_BUDGET,
                    block_j: Optional[int] = None, mode: str = "auto",
                    path: str = "auto", external_i_halo: bool = False,
                    exchange_bytes: float = 0.0) -> SweepSelection:
    """Race the three ways to run ``sweeps`` applications -- one *fused*
    call (halo ``radius * sweeps * apps``), the *wavefront* pipeline (each
    plane fetched once per ``sweeps``, per-stage halo ``radius * apps``),
    and ``sweeps`` *chained* single-sweep calls -- on a sweeps-aware
    roofline, per ``(spec, shape, s)``.

    Ranking follows the paper's accounting: feasible entrants first, then
    *fewest modeled HBM bytes/point* (these kernels are memory-bound by
    thesis -- traffic is the resource being optimized), with modeled time
    per point-sweep breaking byte ties.  The fused stream and the
    wavefront both model ``2 * itemsize / sweeps`` vs ``2 * itemsize``
    chained, so the byte tie between them is broken by VPU redundancy
    (the fused strip is ``2 * radius * sweeps * apps`` wider than the
    output block, the wavefront strip only ``2 * radius * apps``) and,
    before that, by VMEM residency (the deep fused halo is exactly what
    stops large ``s``).  Exact ties break toward the wavefront at
    ``sweeps > 1`` and toward the fused call at ``sweeps == 1`` (they
    are the same program there; fused is the bit-exact escape hatch).  The wavefront entrant is infeasible for
    variable coefficients, j-tiled shapes, and 1-D specs; a periodic i
    axis (unless ``external_i_halo``) charges its pre-extension re-read
    (``m + 2 * radius * apps * sweeps`` rows read per ``m`` written).

    ``exchange_bytes`` (the sharded caller: per-point-per-sweep halo
    traffic from :func:`exchange_bytes_per_point`) is added to every
    entrant's modeled bytes/point -- the deep exchange happens once per
    call whatever the mode, so it shifts the reported totals without
    re-ranking the race.
    """
    if mode not in SWEEP_MODES:
        raise ValueError(f"unknown sweep mode {mode!r}; expected one of "
                         f"{SWEEP_MODES}")
    if sweeps < 1:
        raise ValueError(f"sweeps must be >= 1, got {sweeps}")
    spec = plan.spec
    shifts, flops = _plan_ops(plan, spec.taps)
    var_w = _plan_var_weights(plan)
    apps = _plan_apps(plan)
    rad = _radius3(None, plan)
    modes = ("fused", "wavefront", "chained") if mode == "auto" else (mode,)
    if mode == "auto":
        live = tuple(c for c in modes
                     if not is_blacklisted(spec.name, mode=c))
        modes = live or modes       # never race an empty field
    pref = ({"wavefront": 0, "fused": 1, "chained": 2} if sweeps > 1
            else {"fused": 0, "wavefront": 1, "chained": 2})
    rows = []
    for cand in modes:
        if cand == "wavefront":
            ha = rad[0] * apps
            per_i = spec.bc[0][0].kind == "periodic" and not external_i_halo
            h = ha * sweeps
            m_wf = m + 2 * h if (per_i and h) else m
            kind_ok = (spec.ndim == 3 and spec.coef == "const"
                       and block_j is None and not (per_i and h > m))
            if not kind_ok:
                if mode != "auto":
                    raise ValueError(
                        f"{spec.name}: wavefront mode needs a volumetric "
                        f"constant-coefficient spec, untiled j, and (for "
                        f"periodic i) halo {h} <= M={m}")
                continue
            bi = wavefront_block_i(m_wf, n, p, itemsize, sweeps, plan,
                                   acc_itemsize, vmem_budget)
            feasible = _fits_wavefront(bi, n, p, itemsize, sweeps,
                                       acc_itemsize, vmem_budget, ha)
            read_f = m_wf / m
            bpp = (read_f + 1.0) * itemsize / sweeps
            tpp = _wavefront_step_time(bi, n, p, itemsize, sweeps, shifts,
                                       flops, ha, apps, read_f)
            rows.append((cand, "wavefront", bi, None, bpp + exchange_bytes,
                         tpp, feasible))
        else:
            s_eff = sweeps if cand == "fused" else 1
            rpath, bi, bj = autotune_engine(
                m, n, p, itemsize, sweeps=s_eff, plan=plan,
                acc_itemsize=acc_itemsize, vmem_budget=vmem_budget,
                block_j=block_j, path=path)
            feasible = _fits(bi, bj, n, p, itemsize, s_eff, acc_itemsize,
                             vmem_budget, rpath, rad, var_w, apps)
            bpp = bytes_per_point(rpath, itemsize, bj is not None, s_eff,
                                  rad, spec.coef, spec.n_weights)
            tpp = _step_time(bi, bj, n, p, itemsize, s_eff, shifts, flops,
                             rpath, rad, var_w, apps)
            rows.append((cand, rpath, bi, bj, bpp + exchange_bytes, tpp,
                         feasible))
    if not rows:
        raise ValueError(f"{spec.name}: no feasible sweep mode candidates")
    best = min(rows, key=lambda r: (not r[6], r[4], r[5], pref[r[0]]))
    return SweepSelection(sweeps=sweeps, mode=best[0], path=best[1],
                          block_i=best[2], block_j=best[3],
                          bytes_per_point=best[4], time_per_point=best[5],
                          candidates=tuple(rows))
