"""Cost-model block selection (replaces the old ``pick_block_i`` heuristic).

Same shape of reasoning as ``repro.core.perfmodel``: performance is
``min(compute limit, bandwidth limit)``, so the modeled time of one grid step
is ``max(DMA time, VPU time)`` and we pick the feasible block minimizing the
modeled time per output point:

* DMA bytes/step: three input blocks (centre + the two i-neighbours that
  carry the halo) plus one output block -- ``4 * bi * N * P * itemsize``;
  fused sweeps amortize this over ``s`` operator applications.
* VPU flops/step: ``2 * taps`` per point of the *extended* ``(bi + 2s)``-row
  working block, per sweep -- the halo-recompute tax, which shrinks as ``bi``
  grows.
* VMEM residency: 3 input tiles + output tile (input dtype) + the extended
  working block and its tap accumulator (accumulation dtype) must fit the
  budget -- the paper's Table-2 "registers required vs registers available"
  constraint in VMEM terms.

Feasible blocks divide M (Pallas grid constraint) and satisfy ``bi >= s``
(the +-1-block halo must cover the fused-sweep depth).  Ties prefer sublane
multiples (8), as the old heuristic did.
"""

from __future__ import annotations

# TPU-v5e-flavoured roofline constants (per core), only ever used as a ratio.
HBM_BW = 819e9          # bytes/s
VPU_FLOPS = 3e12        # f32 elementwise flop/s


def _step_time(bi: int, n: int, p: int, itemsize: int, sweeps: int,
               taps: int) -> float:
    dma = 4.0 * bi * n * p * itemsize / HBM_BW
    vpu = 2.0 * taps * sweeps * (bi + 2 * sweeps) * n * p / VPU_FLOPS
    return max(dma, vpu) / (bi * n * p * sweeps)   # per output point-sweep


def _fits(bi: int, n: int, p: int, itemsize: int, sweeps: int,
          acc_itemsize: int, vmem_budget: int) -> bool:
    io_tiles = 4 * bi * n * p * itemsize
    working = 2 * (bi + 2 * sweeps) * n * p * acc_itemsize
    return io_tiles + working <= vmem_budget


def autotune_block_i(m: int, n: int, p: int, itemsize: int,
                     sweeps: int = 1, taps: int = 27,
                     acc_itemsize: int = 4,
                     vmem_budget: int = 8 * 1024 * 1024) -> int:
    """Smallest modeled time per output point over feasible divisors of M."""
    cands = [bi for bi in range(max(1, sweeps), m + 1) if m % bi == 0]
    if not cands:
        return m
    feasible = [bi for bi in cands
                if _fits(bi, n, p, itemsize, sweeps, acc_itemsize,
                         vmem_budget)]
    if not feasible:           # nothing fits: take the smallest legal block
        return cands[0]
    # min cost; tie-break to sublane multiples (or tiny blocks), then larger.
    def key(bi: int):
        return (_step_time(bi, n, p, itemsize, sweeps, taps),
                0 if (bi % 8 == 0 or bi < 8) else 1,
                -bi)
    return min(feasible, key=key)


def pick_block_i(m: int, n: int, p: int, itemsize: int,
                 vmem_budget: int = 8 * 1024 * 1024) -> int:
    """Legacy entry point (kept for the MXU kernel and old callers)."""
    return autotune_block_i(m, n, p, itemsize, sweeps=1, taps=27,
                            vmem_budget=vmem_budget)


def pick_block_rows(rows: int, p: int, itemsize: int,
                    vmem_budget: int = 4 << 20) -> int:
    """Row-block choice for the k-only (1-D) path: the largest power-of-two
    row count whose tile fits the budget, falling back to all rows."""
    for cand in (256, 128, 64, 32, 16, 8):
        if rows % cand == 0 and cand * p * itemsize <= vmem_budget:
            return cand
    return rows
