"""Plan IR: the tiny SSA program a spec compiles to, plus its analyses.

A :class:`StencilPlan` is an explicit tap schedule -- shift/scale/add/fma ops
in SSA form -- *compiled before tracing* (by the pass pipeline in
:mod:`.passes`) and then interpreted at trace time by both the Pallas kernel
and the jnp reference.  Because the two executors walk the identical op list,
the f64 paths stay bit-for-bit equal, and the plan's static ``shifts`` /
``flops`` / ``peak_live`` counts feed the block-size cost model instead of a
blind ``2 * taps`` estimate.

Shifts are single-axis ops of any magnitude up to the spec's per-axis radius,
with zero fill (static slices on the halo-extended block -- no wrap-around
values are ever computed then masked; the vacated positions only ever land on
rows the Dirichlet mask zeroes).

Determinism, precisely: a plan fixes the *mathematical* op sequence, so on
exact arithmetic (integer-valued data and weights within the mantissa) every
plan kind, blocking, and tiling is bit-identical -- the property tests
assert this.  In floating point, XLA/LLVM may contract a ``w * x + y`` into
an fma in one compiled program and not another (the choice follows fusion
shape, survives ``optimization_barrier`` and bitcast fences, and is *not*
controllable from JAX), so cross-*program* bit-equality -- e.g. j-tiled vs
untiled -- is only a per-op <= 1-ulp agreement in general.  Same-plan
kernel-vs-reference f64 parity for the blessed configurations (the engine's
reference path, asserted in tier-1) has been bit-exact in practice; the
builders keep products feeding their adds directly (scales are hoisted past
shifts: ``shift(w * x) -> w * shift(x)``, identical op counts) to keep the
contraction pattern as uniform as possible across programs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..spec import Boundary, StencilSpec, bc_labels

Offset = Tuple[int, int, int]


@dataclasses.dataclass(frozen=True)
class PlanOp:
    """One SSA op.  Value ids: 0 is the input ``u``; op ``k`` defines id
    ``k + 1``.  ``shift``: value ``a`` moved by ``off`` (exactly one nonzero
    component, ``|off| <= radius`` on that axis, ``out[x] = in[x + off]``,
    zero fill).  ``scale``: ``w[w_idx] * a``.  ``add``: ``a + b``.  ``fma``:
    ``b + w[w_idx] * a``."""

    kind: str                     # "shift" | "scale" | "add" | "fma"
    a: int
    b: int = -1
    off: Offset = (0, 0, 0)
    w_idx: int = -1


@dataclasses.dataclass(frozen=True)
class StencilPlan:
    """A compiled execution schedule for one spec.

    ``out`` is the id of the final value (-1 for an empty tap list, which
    executes as zeros).  ``passes`` records the pass pipeline that produced
    the schedule (the BENCH ``pass_list`` column).  ``shifts``/``flops`` are
    the static op counts the cost model consumes: each shift is one
    full-block lane/sublane move, and flops count multiplies and adds (an
    fma is two).  ``peak_live`` is the maximum number of simultaneously live
    SSA values while executing the schedule in order -- the paper's
    register-pressure constraint recast as the VMEM working-set the executor
    carries.

    ``unroll`` is the innermost-sweep unroll factor chosen by the
    ``unroll[k]`` pass: the executor splits the trailing (k) axis into
    ``unroll`` independent chunks whose arithmetic interleaves, the paper's
    register-level unroll recast at trace level.  ``modeled`` carries the
    chosen variant's :class:`~.cost.PlanCost` and ``candidates`` the full
    ``(kind, unroll, cycles_per_point)`` table the cost-driven compiler
    selected from (both hashable, so plans still ride through jit static
    args and cache keys).
    """

    spec: StencilSpec
    kind: str                     # "direct" | "cse" | "factored"
    ops: Tuple[PlanOp, ...]
    out: int
    passes: Tuple[str, ...] = ()
    unroll: int = 1
    modeled: Optional[object] = None            # cost.PlanCost of the choice
    candidates: Tuple[Tuple[str, int, float], ...] = ()

    @property
    def shifts(self) -> int:
        return sum(1 for op in self.ops if op.kind == "shift")

    @property
    def flops(self) -> int:
        return sum({"scale": 1, "add": 1, "fma": 2}.get(op.kind, 0)
                   for op in self.ops)

    @property
    def peak_live(self) -> int:
        return peak_live(self)

    def describe(self) -> Dict[str, object]:
        """Machine-readable op counts (benchmark / JSON artifact form).

        When the plan came out of the cost-driven compiler, ``selection``
        records the choice: the chosen ``(pass_list, unroll)``, its modeled
        cycles/point (and which core model produced the number), and the
        losing ``(kind, unroll, cycles_per_point)`` candidates.
        """
        d = {"taps": self.spec.taps, "shifts": self.shifts,
             "flops": self.flops, "ops": len(self.ops),
             "peak_live": self.peak_live,
             "radius": list(self.spec.radius),
             "bc": list(bc_labels(self.spec.bc)),
             "coef": self.spec.coef,
             "ordering": self.spec.ordering,
             "unroll": self.unroll,
             "pass_list": list(self.passes)}
        if self.modeled is not None:
            d["selection"] = {
                "kind": self.kind, "unroll": self.unroll,
                "cycles_per_point": self.modeled.cycles_per_point,
                "source": self.modeled.source,
                "candidates": [
                    {"kind": k, "unroll": u, "cycles_per_point": c}
                    for k, u, c in self.candidates],
            }
        return d


class Builder:
    """Emit helper: returns the SSA id of each new value."""

    def __init__(self):
        self.ops: List[PlanOp] = []

    def _emit(self, op: PlanOp) -> int:
        self.ops.append(op)
        return len(self.ops)          # u is id 0; op k defines id k + 1

    def shift(self, a: int, axis: int, d: int) -> int:
        off = [0, 0, 0]
        off[axis] = d
        return self._emit(PlanOp("shift", a, off=tuple(off)))

    def scale(self, w_idx: int, a: int) -> int:
        return self._emit(PlanOp("scale", a, w_idx=w_idx))

    def add(self, a: int, b: int) -> int:
        return self._emit(PlanOp("add", a, b))

    def fma(self, w_idx: int, a: int, acc: int) -> int:
        return self._emit(PlanOp("fma", a, acc, w_idx=w_idx))

    def acc(self, w_idx: int, a: int, acc: Optional[int]) -> int:
        return self.scale(w_idx, a) if acc is None else self.fma(w_idx, a, acc)


def op_sources(op: PlanOp) -> Tuple[int, ...]:
    """The SSA value ids an op reads (deduplicated, order preserved)."""
    srcs = [op.a]
    if op.b >= 0 and op.b != op.a:
        srcs.append(op.b)
    return tuple(srcs)


def peak_live(plan: StencilPlan) -> int:
    """Peak number of simultaneously live SSA values over the schedule.

    A value is live from its definition (the input ``u`` from the start)
    until its last use; the output stays live through the end.  This is the
    sequential-execution working set -- what ``execute_plan`` actually keeps
    resident -- and the invariant the ``order_ops`` pass must never increase.
    """
    if not plan.ops:
        return 1 if plan.out == 0 else 0
    last_use: Dict[int, int] = {}
    for i, op in enumerate(plan.ops):
        for v in op_sources(op):
            last_use[v] = i
    if plan.out >= 0:
        last_use[plan.out] = len(plan.ops)
    live = 1 if 0 in last_use else 0          # the input u
    peak = live
    for i, op in enumerate(plan.ops):
        live += 1                              # op i defines value i + 1
        peak = max(peak, live)
        for v in set(op_sources(op)):
            if last_use.get(v, -1) == i:
                live -= 1                      # last use: dead after op i
        if (i + 1) not in last_use:
            live -= 1                          # defined but never consumed
    return peak


def renumber(ops: List[PlanOp], order: List[int], out: int
             ) -> Tuple[Tuple[PlanOp, ...], int]:
    """Re-emit ``ops`` in ``order`` (a topological permutation of op
    indices) with SSA ids renumbered to the new positions."""
    newid = {0: 0}
    new_ops: List[PlanOp] = []
    for pos, old in enumerate(order):
        op = ops[old]
        new_ops.append(dataclasses.replace(
            op, a=newid[op.a], b=newid[op.b] if op.b >= 0 else -1))
        newid[old + 1] = pos + 1
    return tuple(new_ops), (newid[out] if out >= 0 else -1)


def shift_slice(t: jax.Array, off: Offset) -> jax.Array:
    """``out[x] = t[x + off]`` along one trailing axis, zero fill -- a static
    slice plus an edge pad, never a wrap-around roll.  ``off`` indexes the
    (i, j, k) axes as the trailing three dims (k-only specs use only the
    last); the single nonzero component may have any magnitude up to the
    spec radius."""
    (idx, d), = [(i, o) for i, o in enumerate(off) if o]
    axis = t.ndim - 3 + idx
    k = abs(d)
    if k >= t.shape[axis]:
        return jnp.zeros_like(t)
    src = [slice(None)] * t.ndim
    src[axis] = slice(k, None) if d > 0 else slice(0, -k)
    pad_shape = list(t.shape)
    pad_shape[axis] = k
    pad = jnp.zeros(pad_shape, t.dtype)
    body = t[tuple(src)]
    return jnp.concatenate([body, pad] if d > 0 else [pad, body], axis=axis)


def shift_slice_bc(t: jax.Array, off: Offset, bc: Boundary,
                   bc_axes: Tuple[bool, bool, bool]) -> jax.Array:
    """:func:`shift_slice` with the boundary condition realized in the fill.

    Only axes flagged in ``bc_axes`` -- those whose extent in ``t`` *is* the
    full domain extent (k always; j on untiled volumetric blocks; the 1-D
    path's k) -- realize their BC here: a positive shift vacates the high
    side of the axis (reads past the top edge), so the fill block is that
    side's ghost region: ``periodic`` wraps the opposite edge and
    ``neumann`` mirrors the edge symmetrically (``ghost[q] = t[n-1-q]``).
    ``clamp`` keeps the zero fill, and so does ``dirichlet`` -- the plan
    executor runs on the *offset* field ``u - value`` (whose ghosts are
    exactly zero; the executor adds ``value * sum(w)`` back, see
    ``run_sweeps``), because a constant fill would be wrong for shifts of
    intermediate partial sums.  Axes with a staged halo (i; j when tiled)
    keep zero fill -- their BC is realized by the kernel's halo/ghost fill
    instead.  Because the fill runs inside every operator application,
    fused sweeps re-pad exactly like the per-sweep ``np.pad`` reference.
    """
    (idx, d), = [(i, o) for i, o in enumerate(off) if o]
    axis = t.ndim - 3 + idx
    n = t.shape[axis]
    side = bc[idx][1] if d > 0 else bc[idx][0]
    if not bc_axes[idx] or side.kind in ("clamp", "dirichlet"):
        return shift_slice(t, off)
    k = abs(d)
    if side.kind == "periodic":
        k = k % n
        if k == 0:
            return t
    elif k >= n:                      # degenerate: whole axis out of domain
        return jnp.zeros_like(t)
    src = [slice(None)] * t.ndim
    src[axis] = slice(k, None) if d > 0 else slice(0, -k)
    body = t[tuple(src)]
    ghost = [slice(None)] * t.ndim
    if side.kind == "periodic":
        # the vacated positions read the opposite edge
        ghost[axis] = slice(0, k) if d > 0 else slice(-k, None)
        pad = t[tuple(ghost)]
    else:                             # neumann: symmetric mirror of this
        ghost[axis] = slice(-k, None) if d > 0 else slice(0, k)
        pad = jnp.flip(t[tuple(ghost)], axis=axis)   # side's own edge
    return jnp.concatenate([body, pad] if d > 0 else [pad, body], axis=axis)


def execute_plan(cplan: StencilPlan, u: jax.Array, w: jax.Array,
                 shift=shift_slice) -> jax.Array:
    """Interpret the plan at trace time.  ``u`` must already carry the
    accumulation dtype; ``w`` is the canonical flat weight vector in the same
    dtype -- or, for a variable-coefficient spec, the canonical
    ``(n_weights, *strip)`` coefficient field whose trailing dims match
    ``u``'s (coefficients are evaluated at the *output* point, so ``w`` is
    indexed, never shifted).  Both the Pallas kernel and the jnp reference
    call this -- one op walk, identical arithmetic (see the module docstring
    for what that guarantees bitwise).

    A plan with ``unroll > 1`` executes the arithmetic ops on ``unroll``
    independent trailing-axis chunks (shifts stay full-width); slicing
    commutes with elementwise arithmetic, so the chunked walk computes the
    same per-element op sequence.  When the trailing extent does not divide,
    the plan falls back to the single-chunk walk.
    """
    if cplan.out < 0:
        return jnp.zeros_like(u)
    n = cplan.unroll
    if n > 1 and u.shape[-1] % n == 0 and u.shape[-1] >= n:
        return _execute_chunked(cplan, u, w, shift, n)
    vals = [u]
    for op in cplan.ops:
        if op.kind == "shift":
            v = shift(vals[op.a], op.off)
        elif op.kind == "scale":
            v = w[op.w_idx] * vals[op.a]
        elif op.kind == "add":
            v = vals[op.a] + vals[op.b]
        else:                                     # fma
            v = vals[op.b] + w[op.w_idx] * vals[op.a]
        vals.append(v)
    return vals[cplan.out]


def _execute_chunked(cplan: StencilPlan, u: jax.Array, w: jax.Array,
                     shift, n: int) -> jax.Array:
    """The ``unroll`` executor: arithmetic per trailing-axis chunk, shifts
    full-width.  Values live either as a full array (shift results, the
    input) or as a chunk list (arithmetic results); conversions happen
    lazily, only when a shift consumes an arithmetic result or the output
    is assembled."""
    var = cplan.spec.coef == "var"
    c = u.shape[-1] // n

    def split(v):
        return [v[..., q * c:(q + 1) * c] for q in range(n)]

    wq = split(w) if var else None
    full: Dict[int, jax.Array] = {0: u}
    chunks: Dict[int, List[jax.Array]] = {}

    def as_chunks(i):
        if i not in chunks:
            chunks[i] = split(full[i])
        return chunks[i]

    def as_full(i):
        if i not in full:
            full[i] = jnp.concatenate(chunks[i], axis=-1)
        return full[i]

    def wsel(q, w_idx):
        return wq[q][w_idx] if var else w[w_idx]

    for k, op in enumerate(cplan.ops):
        vid = k + 1
        if op.kind == "shift":
            full[vid] = shift(as_full(op.a), op.off)
        elif op.kind == "scale":
            a = as_chunks(op.a)
            chunks[vid] = [wsel(q, op.w_idx) * a[q] for q in range(n)]
        elif op.kind == "add":
            a, bv = as_chunks(op.a), as_chunks(op.b)
            chunks[vid] = [a[q] + bv[q] for q in range(n)]
        else:                                     # fma
            a, bv = as_chunks(op.a), as_chunks(op.b)
            chunks[vid] = [bv[q] + wsel(q, op.w_idx) * a[q]
                           for q in range(n)]
    return as_full(cplan.out)
