"""Plan compiler: spec -> pass pipeline -> :class:`StencilPlan`.

The package splits the former monolithic ``plan.py`` into the IR
(:mod:`.ir`: ops, liveness, the trace-time interpreter) and the rewrite
passes (:mod:`.passes`: ``build_direct`` -> ``cse`` / ``mirror_factor`` ->
``order_ops``).  :func:`compile_plan` resolves a plan *kind* to its pass
preset and runs the pipeline, memoized on the canonical (spec, kind) pair.

Three plan kinds (now pass-list presets, ``PASS_PRESETS``):

``direct``
    ``[build_direct]`` -- the naive schedule, kept as an escape hatch for
    parity testing (54 shifts + 53 flop-ops for stencil27).

``cse``
    ``[build_direct, cse, order_ops]`` -- common-subexpression-eliminated
    schedule for arbitrary masks (10 + 53 for stencil27).

``factored``
    ``[build_direct, mirror_factor, order_ops]`` -- the paper's partial-sum
    factorization for mirror-symmetric specs at any radius (8 + 19 for
    stencil27, 12 + 19 for the radius-2 star13, 20 + 63 for box125).

``auto`` resolves to ``factored`` for mirror-symmetric specs and ``cse``
otherwise, *before* the memo lookup, so every alias spelling shares one
compiled plan object.
"""

from __future__ import annotations

import functools
from typing import Tuple, Union

from ..spec import StencilSpec, get_stencil
from .ir import (Builder, PlanOp, StencilPlan, execute_plan,  # noqa: F401
                 op_sources, peak_live, renumber, shift_slice,
                 shift_slice_bc)
from .passes import (PASS_PRESETS, build_direct, cse,  # noqa: F401
                     mirror_factor, mirror_symmetric, order_ops, run_passes)

PLAN_KINDS = ("auto", "direct", "cse", "factored")


@functools.lru_cache(maxsize=256)
def _compile_plan_cached(spec: StencilSpec, kind: str) -> StencilPlan:
    """The memoized synthesis step, keyed on the *canonical* (spec, resolved
    plan kind) pair -- a frozen spec hashes on its name + tap/weight-index
    tuples + radius, so repeated eager/un-jitted calls, the autotuner, and
    equal-valued ad-hoc ``spec_from_mask`` specs all share one compiled
    schedule instead of re-running the pass pipeline per call."""
    return run_passes(spec, PASS_PRESETS[kind])


def compile_plan(spec: Union[str, int, StencilSpec],
                 plan: str = "auto") -> StencilPlan:
    """Compile ``spec`` into a :class:`StencilPlan` (memoized).

    ``plan="auto"`` picks ``factored`` for mirror-symmetric specs (stencil3,
    stencil7, stencil27, star13, box125, symmetric masks) and ``cse``
    otherwise; ``plan="direct"`` is the naive parity escape hatch.  The spec
    and the plan kind are canonicalized *before* the cache lookup, so
    ``compile_plan("27")``, ``compile_plan("stencil27")`` and
    ``compile_plan(get_stencil("stencil27"))`` -- and ``plan="auto"`` vs its
    resolved kind -- return the identical plan object.
    """
    spec = get_stencil(spec)
    if plan not in PLAN_KINDS:
        raise ValueError(f"unknown plan {plan!r}; expected one of {PLAN_KINDS}")
    kind = plan
    if kind == "auto":
        kind = "factored" if mirror_symmetric(spec) else "cse"
    if kind == "factored" and not mirror_symmetric(spec):
        raise ValueError(
            f"{spec.name}: factored plan needs a mirror-symmetric tap set "
            f"(closed under per-axis sign flips, weights on |offsets|); "
            f"use plan='cse' or 'auto'")
    return _compile_plan_cached(spec, kind)
