"""Plan compiler: spec -> candidate pass pipelines -> cost model -> plan.

The package splits the former monolithic ``plan.py`` into the IR
(:mod:`.ir`: ops, liveness, the trace-time interpreter), the rewrite passes
(:mod:`.passes`: ``build_direct`` -> ``cse`` / ``mirror_factor`` ->
``unroll[k]`` -> ``order_ops``) and the cost model (:mod:`.cost`: lower a
plan onto the core PPC450 scheduler/simulator).  :func:`compile_plan` is
cost-driven: it enumerates candidate ``(pass_list, unroll)`` variants,
estimates cycles/point for each on the core machine model, and selects the
modeled-fastest -- the paper's synthesize -> simulate -> select loop, run at
plan-compile time.  The choice, its modeled cost, and the losing candidates
are recorded on the plan (``describe()['selection']``).

Three plan kinds (pass-list presets, ``PASS_PRESETS``):

``direct``
    ``[build_direct]`` -- the naive schedule, kept as an escape hatch for
    parity testing (54 shifts + 53 flop-ops for stencil27).  Always costed
    at ``unroll=1``; it is the baseline every selection must beat.

``cse``
    ``[build_direct, cse, order_ops]`` -- common-subexpression-eliminated
    schedule for arbitrary masks (10 + 53 for stencil27).

``factored``
    ``[build_direct, mirror_factor, order_ops]`` -- the paper's partial-sum
    factorization for mirror-symmetric specs at any radius (8 + 19 for
    stencil27, 12 + 19 for the radius-2 star13, 20 + 63 for box125; on
    variable-coefficient specs the pass partially factors -- unweighted
    pair sums stay shared, scales land at the output point).

``auto`` enumerates every kind valid for the spec; an explicit kind
enumerates its unroll ladder only.  Either way the resolved ``(kind,
unroll)`` is canonical *before* the memo lookup, so every alias spelling --
and ``auto`` vs its resolved kind -- shares one compiled plan object.  The
memo key is the canonical ``(spec, kind, unroll)`` triple; the spec hashes
on its full value including the coefficient kind, so variable- and
constant-coefficient variants never share an entry.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple, Union

from ..spec import StencilSpec, get_stencil
from .cost import PlanCost, estimate_plan, fits_registers  # noqa: F401
from .ir import (Builder, PlanOp, StencilPlan, execute_plan,  # noqa: F401
                 op_sources, peak_live, renumber, shift_slice,
                 shift_slice_bc)
from .passes import (PASS_PRESETS, build_direct, cse,  # noqa: F401
                     mirror_factor, mirror_symmetric, order_ops,
                     preset_with_unroll, run_passes, unroll)

PLAN_KINDS = ("auto", "direct", "cse", "factored")

# The unroll ladder the compiler enumerates (paper sect. 4.2 explores the
# same small powers of two); candidates that overflow the FPR file are
# dropped by ``cost.fits_registers``.
UNROLL_CANDIDATES = (1, 2, 4)

_KIND_RANK = {"factored": 0, "cse": 1, "direct": 2}


def _valid_kinds(spec: StencilSpec) -> Tuple[str, ...]:
    if mirror_symmetric(spec):
        return ("direct", "cse", "factored")
    return ("direct", "cse")


@functools.lru_cache(maxsize=256)
def _cost_table(spec: StencilSpec
                ) -> Tuple[Tuple[str, int, PlanCost], ...]:
    """Every enumerated ``(kind, unroll) -> PlanCost`` row for one spec.

    ``direct`` is pinned at ``unroll=1`` (the untouched-naive baseline);
    the optimizing kinds walk ``UNROLL_CANDIDATES`` subject to the
    register-file guard.  Cached per spec so the table is computed once and
    shared by every request spelling.
    """
    rows = []
    for kind in _valid_kinds(spec):
        ladder = (1,) if kind == "direct" else UNROLL_CANDIDATES
        for u in ladder:
            plan = run_passes(spec, preset_with_unroll(kind, u))
            if u > 1 and not fits_registers(plan, u):
                continue
            rows.append((kind, u, estimate_plan(plan)))
    return tuple(rows)


def _select(spec: StencilSpec, kinds: Tuple[str, ...]) -> Tuple[str, int]:
    """The modeled-fastest ``(kind, unroll)`` among ``kinds``.

    Ties (to 1e-6 cycles) break toward the smaller unroll factor, then the
    more-factored kind -- deterministic, and stable under float noise in
    the simulator's steady-state differencing.
    """
    rows = [r for r in _cost_table(spec) if r[0] in kinds]
    best = min(rows, key=lambda r: (round(r[2].cycles_per_point, 6), r[1],
                                    _KIND_RANK[r[0]]))
    return best[0], best[1]


@functools.lru_cache(maxsize=256)
def _compile_plan_cached(spec: StencilSpec, kind: str,
                         unroll_factor: int) -> StencilPlan:
    """The memoized synthesis step, keyed on the *canonical* ``(spec, kind,
    unroll)`` triple -- a frozen spec hashes on its full value (taps,
    weight indices, radius, bc, coefficient kind), so repeated eager calls,
    the autotuner, and equal-valued ad-hoc ``spec_from_mask`` specs all
    share one compiled schedule, while variable- vs constant-coefficient
    specs and distinct unroll factors never collide."""
    plan = run_passes(spec, preset_with_unroll(kind, unroll_factor))
    table = _cost_table(spec)
    mine = next((c for k, u, c in table
                 if k == kind and u == unroll_factor), None)
    if mine is None:          # explicit unroll outside the enumerated ladder
        mine = estimate_plan(plan)
    return dataclasses.replace(
        plan, modeled=mine,
        candidates=tuple((k, u, c.cycles_per_point) for k, u, c in table))


def compile_plan(spec: Union[str, int, StencilSpec], plan: str = "auto",
                 unroll: Optional[int] = None) -> StencilPlan:
    """Compile ``spec`` into a :class:`StencilPlan` (memoized, cost-driven).

    ``plan="auto"`` enumerates every kind valid for the spec (``factored``
    only for mirror-symmetric tap sets) crossed with the unroll ladder, and
    selects the variant the core PPC450 model rates fastest; an explicit
    kind restricts the enumeration to that kind's unroll ladder, and an
    explicit ``unroll`` pins the factor (``direct`` stays pinned at 1 -- it
    is the untouched-naive baseline unless you ask otherwise).  The spec,
    kind, and unroll factor are canonicalized *before* the cache lookup, so
    ``compile_plan("27")``, ``compile_plan("stencil27")`` and
    ``compile_plan(get_stencil("stencil27"))`` -- and ``plan="auto"`` vs
    its resolved kind -- return the identical plan object.  The selection
    (chosen variant, modeled cycles/point, losing candidates) is recorded
    in ``describe()['selection']``.
    """
    spec = get_stencil(spec)
    if plan not in PLAN_KINDS:
        raise ValueError(f"unknown plan {plan!r}; expected one of {PLAN_KINDS}")
    if plan == "factored" and not mirror_symmetric(spec):
        raise ValueError(
            f"{spec.name}: factored plan needs a mirror-symmetric tap set "
            f"(closed under per-axis sign flips, weights on |offsets|); "
            f"use plan='cse' or 'auto'")
    kinds = _valid_kinds(spec) if plan == "auto" else (plan,)
    if unroll is not None:
        if unroll < 1:
            raise ValueError(f"unroll must be >= 1, got {unroll}")
        if plan == "auto":
            rows = [r for r in _cost_table(spec) if r[1] == unroll] or None
            if rows:
                kind = min(rows, key=lambda r: (
                    round(r[2].cycles_per_point, 6),
                    _KIND_RANK[r[0]]))[0]
            else:
                kind, _ = _select(spec, kinds)
        else:
            kind = plan
        return _compile_plan_cached(spec, kind, unroll)
    kind, factor = _select(spec, kinds)
    return _compile_plan_cached(spec, kind, factor)
