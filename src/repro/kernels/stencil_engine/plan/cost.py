"""Plan cost model: lower a :class:`~.ir.StencilPlan` onto the core PPC450
machine model and estimate cycles/point.

This closes the paper's loop (synthesize -> schedule -> simulate -> select)
for the Pallas engine's plan compiler: each candidate ``(pass_list, unroll)``
variant is lowered to a symbolic PPC450 instruction block -- shift ops become
LSU quad loads (L1 latency 4, one issue per 2 cycles), arithmetic becomes FPU
ops (latency 5, one per cycle), constant weights live in registers, variable
coefficients add one weight-plane load per point -- and costed exactly the way
``core.perfmodel.analyze`` costs the paper's synthesized kernels: greedy
list-schedule over the renamed (RAW-only) dependence DAG, then, for blocks
small enough, an in-order pipeline replay (``core.simulator``) whose
steady-state cycles/iteration is the estimate.  Unrolling replicates the
block per point with disjoint registers, which is what lets the scheduler
interleave independent chains across the latency-5 FPU pipe -- the paper's
sect. 4.2 effect, reproduced on the plan IR.

The absolute numbers are PPC450 cycles for one SIMD lane pair; the compiler
only consumes them *relatively*, to rank variants of the same spec.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from ....core.dag import build_dag
from ....core.isa import (NUM_FPRS, Instr, MemRef, Unit, fpadd, fpmadd,
                          fxcpmul, lfpdx, stfpdx)
from ....core.scheduler import greedy_schedule
from ....core.simulator import simulate_inorder
from .ir import StencilPlan

# Blocks at or below this instruction count get the in-order pipeline replay
# (the paper's simulator); larger blocks keep the scheduler's makespan.  All
# radius-1 builtin variants fall below it, so the fidelity tests can pin the
# estimate to ``core.simulator`` output exactly.
SIM_INSTR_LIMIT = 320

SIM_ITERS = 12


@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Modeled cost of one (plan, unroll) variant -- frozen/hashable so it
    can ride inside a :class:`~.ir.StencilPlan` through jit static args and
    cache keys."""

    cycles_per_point: float       # the selection metric
    makespan: int                 # greedy-schedule issue span of the block
    lower_bound: int              # paper eq. (1): max(CP, 2|LSU|, |FPU|)
    n_instrs: int                 # block size after unrolling
    unroll: int
    source: str                   # "simulator" (in-order replay) | "scheduler"


def lower_plan(plan: StencilPlan, unroll: int = 1) -> List[Instr]:
    """Lower a plan to a symbolic PPC450 instruction block for one unrolled
    iteration (``unroll`` output points).

    Per copy ``q``: the input value is one quad load; every ``shift`` is a
    quad load from the input stream (a shift of a *computed* value keeps a
    register dependence on it -- spill + shifted reload); ``scale``/``add``/
    ``fma`` map to their FPU instructions; the output is one quad store.
    Constant weights are register-resident (the paper keeps them in FPRs for
    the whole sweep); variable coefficients cost one weight-plane load per
    (weight, point) -- the extra streaming traffic the var path pays.
    """
    var = plan.spec.coef == "var"
    instrs: List[Instr] = []
    slot = 0

    def load(dest: str, space: str, deps: tuple = ()) -> None:
        nonlocal slot
        base = {"A": "gA", "W": "gW"}[space]
        ins = lfpdx(dest, base, 16 * slot, space=space)
        if deps:
            ins = dataclasses.replace(ins, srcs=ins.srcs + deps)
        instrs.append(ins)
        slot += 1

    for q in range(unroll):
        def reg(vid: int) -> str:
            return f"v{vid}q{q}"

        uses = {0} if plan.out == 0 else set()
        for op in plan.ops:
            uses.add(op.a)
            if op.b >= 0:
                uses.add(op.b)
        if 0 in uses:
            load(reg(0), "A")
        wregs = {}
        for op in plan.ops:
            if op.w_idx >= 0:
                if var:
                    if op.w_idx not in wregs:
                        wr = f"w{op.w_idx}q{q}"
                        load(wr, "W")
                        wregs[op.w_idx] = wr
                else:
                    wregs.setdefault(op.w_idx, f"w{op.w_idx}")
        for i, op in enumerate(plan.ops):
            dest = reg(i + 1)
            if op.kind == "shift":
                load(dest, "A", deps=() if op.a == 0 else (reg(op.a),))
            elif op.kind == "scale":
                instrs.append(fxcpmul(dest, wregs[op.w_idx], reg(op.a)))
            elif op.kind == "add":
                instrs.append(fpadd(dest, reg(op.a), reg(op.b)))
            else:                                 # fma: b + w * a
                instrs.append(fpmadd(dest, wregs[op.w_idx], reg(op.a),
                                     reg(op.b)))
        if plan.out >= 0:
            instrs.append(stfpdx(reg(plan.out), "gR", 16 * q, space="R"))
    return instrs


def fits_registers(plan: StencilPlan, unroll: int) -> bool:
    """Paper-style register-file guard for an unroll candidate.

    Each unrolled copy carries ``peak_live`` SSA values; constant weights
    stay resident (``n_weights`` FPRs shared by every copy), variable
    coefficients keep roughly one in-flight weight register per copy.  A
    candidate that cannot fit the ``NUM_FPRS`` file is not enumerated --
    e.g. box125's 27 resident weights pin it to ``unroll=1``.
    """
    if plan.spec.coef == "var":
        need = (plan.peak_live + 1) * unroll
    else:
        need = plan.peak_live * unroll + plan.spec.n_weights
    return need <= NUM_FPRS


def estimate_plan(plan: StencilPlan, unroll: Optional[int] = None) -> PlanCost:
    """Modeled cycles/point for one plan variant.

    The block is scheduled exactly the way ``core.perfmodel.analyze`` costs
    the paper's kernels -- greedy list schedule over the register-renamed
    (RAW-only) DAG -- and, when it fits ``SIM_INSTR_LIMIT``, replayed
    through the in-order pipeline simulator for the steady-state
    cycles/iteration; ``cycles_per_point`` divides by the unroll factor
    (one output point per unrolled copy).
    """
    u = plan.unroll if unroll is None else unroll
    instrs = lower_plan(plan, u)
    if not instrs:
        return PlanCost(0.0, 0, 0, 0, u, "scheduler")
    sched = greedy_schedule(instrs, build_dag(instrs, war=False))
    if len(instrs) <= SIM_INSTR_LIMIT:
        ordered = [instrs[i] for i in sched.order]
        timing = simulate_inorder(ordered, n_iters=SIM_ITERS)
        return PlanCost(timing.per_iter_cycles / u, sched.makespan,
                        sched.lower_bound, len(instrs), u, "simulator")
    return PlanCost(sched.makespan / u, sched.makespan, sched.lower_bound,
                    len(instrs), u, "scheduler")
