"""Rewrite passes: the plan compiler as an explicit pass pipeline.

This is the paper's synthesis step (sect. 4: emit the kernel as a factored
instruction schedule, not N independent multiply-adds) restructured
xdsl-style: :func:`~.compile_plan` runs an ordered list of passes, each a
``StencilPlan -> StencilPlan`` rewrite that either improves the schedule or
returns its input unchanged, and each unit-testable on op-count / liveness
invariants.  The passes:

``build_direct`` (the mandatory first pass)
    Emits the naive schedule from the spec -- one shift per nonzero offset
    component per tap, one multiply-add per tap, in the spec's lexicographic
    order (54 shifts + 53 flop-ops for stencil27; kept alone as the
    ``direct`` parity escape hatch).

``cse``
    Rewrites to the common-subexpression-eliminated schedule for *arbitrary*
    masks: taps are grouped by ``(dj, dk)`` so each trailing-plane shift is
    built once (j-shifts of ``u`` are themselves shared across ``dk``) and
    reused across ``di``; per-``di`` partial sums are shifted once along i
    at the end (10 shifts + 53 flop-ops for stencil27).  Never emits more
    shifts or flops than the direct schedule.

``mirror_factor``
    The paper's partial-sum factorization, generalized to per-axis
    ``|d|``-symmetry at any radius: for specs closed under per-axis sign
    flips with weights depending only on ``(|di|, |dj|, |dk|)``,
    k-neighbour pair sums per distance are built once, reused across j,
    then across i -- 8 shifts + 19 flop-ops for stencil27, 12 + 19 for the
    radius-2 star13, 20 + 63 for box125.  A no-op on asymmetric specs.

``unroll[k]``
    Records an innermost-sweep unroll factor ``k`` in the plan IR: the
    executor splits the trailing axis into ``k`` independent chunks whose
    arithmetic interleaves -- the paper's register-level unroll (sect. 4.2,
    the 1xU / 2xU configurations) recast at trace level.  Inserted by the
    cost-driven compiler when the modeled PPC450 schedule says breaking the
    latency-5 FPU dependence chain pays for the extra live values.

``order_ops``
    Pure reordering: builds the plan's SSA dependence DAG (shift ops on
    the LSU, arithmetic on the FPU) and list-schedules it greedily for
    minimal live-value count, reusing the core scheduler's priority logic
    -- ``repro.core.dag.path_to_sink``, the longest-path-to-sink priority
    ``greedy_schedule`` issues by (paper sect. 4.4) -- as the tie-break
    among pressure-equal ready ops.  The register-pressure constraint
    recast as the executor's live-value working set: the reordered
    schedule is kept only when its :func:`~.ir.peak_live` does not exceed
    the input's, so the pass *provably never increases* peak SSA
    liveness; op multiset, dataflow, and therefore arithmetic are
    unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from ..spec import StencilSpec
from .ir import Builder, PlanOp, StencilPlan, op_sources, peak_live, renumber

PassFn = Callable[[StencilPlan], StencilPlan]


def mirror_symmetric(spec: StencilSpec) -> bool:
    """True when the tap set is closed under per-axis sign flips and the
    weight index depends only on ``(|di|, |dj|, |dk|)`` -- the condition for
    the factored partial-sum schedule to be exact (any radius)."""
    wmap = dict(zip(spec.offsets, spec.w_index))
    for (di, dj, dk), wi in wmap.items():
        for si in ((1, -1) if di else (1,)):
            for sj in ((1, -1) if dj else (1,)):
                for sk in ((1, -1) if dk else (1,)):
                    if wmap.get((di * si, dj * sj, dk * sk)) != wi:
                        return False
    return True


def _mark(plan: StencilPlan, pass_name: str, kind: Optional[str] = None,
          ops: Optional[Tuple[PlanOp, ...]] = None,
          out: Optional[int] = None) -> StencilPlan:
    return dataclasses.replace(
        plan,
        kind=plan.kind if kind is None else kind,
        ops=plan.ops if ops is None else ops,
        out=plan.out if out is None else out,
        passes=plan.passes + (pass_name,))


def build_direct(spec: StencilSpec) -> StencilPlan:
    """Seed pass: the naive schedule, one shift per nonzero offset component
    per tap (a radius-2 component is one magnitude-2 shift), one
    multiply-add per tap, in the spec's lexicographic order (the seed
    engine's arithmetic)."""
    b = Builder()
    acc = None
    for off, wi in zip(spec.offsets, spec.w_index):
        t = 0
        for axis, d in enumerate(off):
            if d:
                t = b.shift(t, axis, d)
        acc = b.acc(wi, t, acc)
    return StencilPlan(spec=spec, kind="direct", ops=tuple(b.ops),
                       out=-1 if acc is None else acc,
                       passes=("build_direct",))


def cse(plan: StencilPlan) -> StencilPlan:
    """Grouped schedule: one shift per distinct ``(dj, dk)`` plane (j-shifts
    of ``u`` shared across dk), reused across ``di``; per-``di`` partial sums
    are shifted along i once at the end.  A single-tap ``di`` group would
    shift a bare product, so its scale is hoisted past the i-shift (same op
    counts -- see the :mod:`.ir` determinism invariant).  Offsets of any
    magnitude (radius-R) shift once by their full distance."""
    spec = plan.spec
    if not spec.offsets:
        return _mark(plan, "cse", kind="cse")
    var = spec.coef == "var"
    b = Builder()
    by_di: Dict[int, List[Tuple[int, int, int]]] = {}
    for (di, dj, dk), wi in zip(spec.offsets, spec.w_index):
        by_di.setdefault(di, []).append((dj, dk, wi))
    jshift: Dict[int, int] = {0: 0}
    plane: Dict[Tuple[int, int], int] = {}
    for dj, dk in sorted({(dj, dk) for g in by_di.values()
                          for dj, dk, _ in g}):
        if dj not in jshift:
            jshift[dj] = b.shift(0, 1, dj)
        plane[(dj, dk)] = (b.shift(jshift[dj], 2, dk) if dk
                           else jshift[dj])
    out = None
    for di in sorted(by_di):
        group = sorted(by_di[di])
        if di and (len(group) == 1 or var):
            # Variable coefficients are evaluated at the *output* point, so
            # a scaled partial sum must never be shifted: keep each tap's
            # i-shift on the unweighted plane and scale at the output (the
            # same hoist a single-tap group always used).
            for dj, dk, wi in group:
                out = b.acc(wi, b.shift(plane[(dj, dk)], 0, di), out)
            continue
        acc = None
        for dj, dk, wi in group:
            acc = b.acc(wi, plane[(dj, dk)], acc)
        term = b.shift(acc, 0, di) if di else acc
        out = term if out is None else b.add(out, term)
    return _mark(plan, "cse", kind="cse", ops=tuple(b.ops), out=out)


def mirror_factor(plan: StencilPlan) -> StencilPlan:
    """Partial-sum schedule for mirror-symmetric specs, per-axis at any
    radius: k-pair sums per distance swept once, reused across j (j-pair
    sums per distance), combined per ``|di|`` class, then reused across i --
    the paper's factored 27-point kernel as a rewrite.  A no-op on
    asymmetric specs (use inside ``auto`` pipelines); raising on misuse is
    the caller's job."""
    spec = plan.spec
    if not spec.offsets or not mirror_symmetric(spec):
        return plan
    var = spec.coef == "var"
    b = Builder()
    classes: Dict[Tuple[int, int, int], int] = {}
    for off, wi in zip(spec.offsets, spec.w_index):
        classes[(abs(off[0]), abs(off[1]), abs(off[2]))] = wi
    k_sum: Dict[int, int] = {}
    for c in sorted({c for _, _, c in classes}):
        k_sum[c] = 0 if c == 0 else b.add(b.shift(0, 2, -c),
                                          b.shift(0, 2, c))
    j_sum: Dict[Tuple[int, int], int] = {}
    for bb, c in sorted({(bb, c) for _, bb, c in classes}):
        j_sum[(bb, c)] = (k_sum[c] if bb == 0
                          else b.add(b.shift(k_sum[c], 1, -bb),
                                     b.shift(k_sum[c], 1, bb)))
    out = None
    for a in sorted({aa for aa, _, _ in classes}):
        group = sorted((bb, c) for aa, bb, c in classes if aa == a)
        if a == 0:
            acc = None
            for bb, c in group:
                acc = b.acc(classes[(0, bb, c)], j_sum[(bb, c)], acc)
            out = acc
        elif len(group) == 1 or var:
            # A single |di|=a class would shift a bare product; hoist the
            # scale past the i-pair sum (same op counts -- determinism
            # invariant).  Variable-coefficient specs take this branch for
            # *every* class -- the partial factoring: the unweighted k- and
            # j-pair sums stay shared (pure shifts of u), each class gets
            # its own i-pair sum, and the per-point weight lands at the
            # output, where the coefficient field is evaluated.
            for bb, c in group:
                pair = b.add(b.shift(j_sum[(bb, c)], 0, -a),
                             b.shift(j_sum[(bb, c)], 0, a))
                out = b.acc(classes[(a, bb, c)], pair, out)
        else:
            acc = None
            for bb, c in group:
                acc = b.acc(classes[(a, bb, c)], j_sum[(bb, c)], acc)
            pair = b.add(b.shift(acc, 0, -a), b.shift(acc, 0, a))
            out = pair if out is None else b.add(out, pair)
    return _mark(plan, "mirror_factor", kind="factored", ops=tuple(b.ops),
                 out=out)


def unroll(plan: StencilPlan, factor: int) -> StencilPlan:
    """Record an innermost-sweep unroll factor in the plan IR.

    The executor realizes it by splitting the trailing (k) axis into
    ``factor`` independent chunks whose arithmetic interleaves -- the
    paper's register-level unroll (sect. 4.2) recast at trace level, and
    the knob the cost model turns to break the latency-5 FPU dependence
    chain.  ``factor=1`` is the identity (no marker recorded); the op list
    itself is untouched either way, so every op-count/liveness invariant
    is preserved by construction.
    """
    if factor < 1:
        raise ValueError(f"unroll factor must be >= 1, got {factor}")
    if factor == 1:
        return plan
    return dataclasses.replace(plan, unroll=factor,
                               passes=plan.passes + (f"unroll[{factor}]",))


def preset_with_unroll(kind: str, factor: int) -> Tuple[str, ...]:
    """The ``PASS_PRESETS[kind]`` pass list with ``unroll[factor]`` spliced
    in (before the trailing ``order_ops`` so the liveness-ordering pass
    stays last; a factor of 1 leaves the preset untouched)."""
    names = PASS_PRESETS[kind]
    if factor <= 1:
        return names
    tag = f"unroll[{factor}]"
    if names and names[-1] == "order_ops":
        return names[:-1] + (tag, "order_ops")
    return names + (tag,)


def order_ops(plan: StencilPlan) -> StencilPlan:
    """Reorder the schedule for minimal live-value count, keeping the
    result only when peak SSA liveness does not grow.

    The plan's ops become a symbolic instruction block (shift -> LSU,
    arithmetic -> FPU, SSA value ``v{id}`` registers), the dependence DAG
    is the pure-RAW SSA graph, and a greedy list scheduler emits, each
    step, the ready op that retires the most live values -- breaking ties
    by the core scheduler's priority logic, ``path_to_sink`` (the
    longest-path-to-sink priority ``repro.core.scheduler.greedy_schedule``
    issues by, paper sect. 4.4).  The emitted order is always a valid
    topological order, so dataflow (and hence arithmetic, bit-for-bit
    under a fixed executor) is unchanged; only the live-value working set
    can move, and the guard makes "never worse" unconditional.
    """
    if len(plan.ops) <= 1:
        return _mark(plan, "order_ops")
    from ....core.dag import build_dag, path_to_sink
    from ....core.isa import Instr, Unit
    instrs = [Instr(op.kind,
                    Unit.LSU if op.kind == "shift" else Unit.FPU,
                    f"v{i + 1}",
                    tuple(f"v{v}" for v in op_sources(op)))
              for i, op in enumerate(plan.ops)]
    g = build_dag(instrs)                      # pure RAW on SSA values
    prio = path_to_sink(g)                     # the scheduler's priority
    uses: Dict[int, int] = {}                  # value id -> remaining uses
    for op in plan.ops:
        for v in op_sources(op):
            uses[v] = uses.get(v, 0) + 1
    if plan.out >= 0:
        uses[plan.out] = uses.get(plan.out, 0) + 1
    pending = {i: set(g.predecessors(i)) for i in range(len(plan.ops))}
    ready = sorted(i for i, p in pending.items() if not p)
    order: List[int] = []
    while ready:
        # Emit the ready op that frees the most live values *now* (its dying
        # sources minus the one value it defines); break ties by the list
        # scheduler's longest-path-to-sink priority, then program order.
        def gain(i: int) -> Tuple[int, int, int]:
            dies = sum(1 for v in set(op_sources(plan.ops[i]))
                       if uses.get(v, 0) == 1)
            return (dies, prio[i], -i)
        nxt = max(ready, key=gain)
        ready.remove(nxt)
        order.append(nxt)
        for v in set(op_sources(plan.ops[nxt])):
            uses[v] -= 1
        for s in g.successors(nxt):
            pending[s].discard(nxt)
            if not pending[s]:
                ready.append(s)
    ops, out = renumber(list(plan.ops), order, plan.out)
    cand = dataclasses.replace(plan, ops=ops, out=out)
    if peak_live(cand) <= peak_live(plan):
        return _mark(cand, "order_ops")
    return _mark(plan, "order_ops[kept-original]")


# Pass-list presets: the former monolithic plan kinds, now pipelines.  The
# ``direct`` preset stays untouched-naive (the parity escape hatch); the
# optimizing presets end with the liveness-ordering pass.
PASS_PRESETS: Dict[str, Tuple[str, ...]] = {
    "direct": ("build_direct",),
    "cse": ("build_direct", "cse", "order_ops"),
    "factored": ("build_direct", "mirror_factor", "order_ops"),
}

_PASSES: Dict[str, PassFn] = {
    "cse": cse,
    "mirror_factor": mirror_factor,
    "order_ops": order_ops,
}


def run_passes(spec: StencilSpec, pass_names: Tuple[str, ...]) -> StencilPlan:
    """Run an ordered pass list over ``spec``.  The first pass must be
    ``build_direct`` (the seed); every subsequent name indexes a
    ``StencilPlan -> StencilPlan`` rewrite.  The parametrized spelling
    ``unroll[k]`` records an unroll factor ``k`` (see :func:`unroll`)."""
    if not pass_names or pass_names[0] != "build_direct":
        raise ValueError(f"pass list must start with 'build_direct', got "
                         f"{pass_names!r}")
    plan = build_direct(spec)
    for name in pass_names[1:]:
        if name.startswith("unroll[") and name.endswith("]"):
            try:
                factor = int(name[len("unroll["):-1])
            except ValueError:
                raise ValueError(f"bad unroll factor in pass name {name!r}")
            plan = unroll(plan, factor)
            continue
        if name not in _PASSES:
            raise ValueError(f"unknown pass {name!r}; available: "
                             f"{sorted(_PASSES) + ['unroll[<k>]']}")
        plan = _PASSES[name](plan)
    return plan
