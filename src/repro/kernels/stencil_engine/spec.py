"""Stencil specifications: radius-R coefficient masks + the named registry.

A :class:`StencilSpec` describes a stencil as a list of taps -- ``(di, dj,
dk)`` offsets in lexicographic order -- each tagged with an index into a flat
vector of unique coefficients, plus a per-axis ``radius`` bounding the
offsets.  The paper's three streaming kernels (3-, 7-, 27-point, sect. 3.1)
are radius-1 entries in the registry; high-order operators (the 4th-order
13-point star, the 5x5x5 box) are radius-2 entries, and any other operator is
one :func:`spec_from_mask` call away from an odd-shaped coefficient mask.
The spec is a frozen (hashable) dataclass so it can ride through ``jax.jit``
as a static argument, and both the Pallas kernel body and the jnp reference
expand the same compiled plan, in the same order -- which is what makes the
f64 paths agree bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Offset = Tuple[int, int, int]
Radius = Tuple[int, int, int]

BC_KINDS = ("clamp", "periodic", "dirichlet", "neumann")
COEF_KINDS = ("const", "var")
ORDERING_KINDS = ("jacobi", "redblack")
# Guarded-execution spellings a spec may carry (see .guard for the policy
# each resolves to): "off" is the historical default -- no checks, no
# wrappers, byte-identical programs.
GUARD_KINDS = ("off", "nan", "invariant", "oracle", "full")


@dataclasses.dataclass(frozen=True)
class BC:
    """One boundary condition on one side of one axis.

    ``clamp``
        The engine's historical semantics (and the default): out-of-domain
        reads are zeros and the one-point boundary ring of the *output* is
        zeroed every sweep -- a homogeneous-Dirichlet solve where the ring
        itself is the held boundary.
    ``periodic``
        Out-of-domain reads wrap around the axis (``np.pad`` mode
        ``"wrap"``); the operator is applied at every point.  Must be paired
        -- periodic on one side of an axis requires periodic on the other.
    ``dirichlet``
        Out-of-domain (ghost) reads are the constant ``value`` (``np.pad``
        mode ``"constant"``); the operator is applied at every point.
    ``neumann``
        Zero-flux: out-of-domain reads mirror the domain edge-inclusively
        (ghost ``u[-1-q] = u[q]``; ``np.pad`` mode ``"symmetric"``); the
        operator is applied at every point.
    """

    kind: str
    value: float = 0.0            # dirichlet ghost value; ignored otherwise

    def __post_init__(self):
        if self.kind not in BC_KINDS:
            raise ValueError(f"unknown BC kind {self.kind!r}; expected one "
                             f"of {BC_KINDS}")
        if self.kind != "dirichlet" and self.value != 0.0:
            raise ValueError(f"BC value is only meaningful for dirichlet, "
                             f"got {self.kind}({self.value})")

    def label(self) -> str:
        if self.kind == "dirichlet":
            return f"dirichlet({self.value:g})"
        return self.kind


CLAMP = BC("clamp")
PERIODIC = BC("periodic")
NEUMANN = BC("neumann")


def dirichlet(value: float = 0.0) -> BC:
    """The constant-ghost boundary condition ``u_ghost = value``."""
    return BC("dirichlet", float(value))


# (lo, hi) per axis, axes in (i, j, k) order.
Boundary = Tuple[Tuple[BC, BC], Tuple[BC, BC], Tuple[BC, BC]]

CLAMP_ALL: Boundary = ((CLAMP, CLAMP), (CLAMP, CLAMP), (CLAMP, CLAMP))


def _as_bc(x) -> BC:
    if isinstance(x, BC):
        return x
    if isinstance(x, str):
        return BC(x)
    raise TypeError(f"cannot interpret {x!r} as a BC (use a kind string, a "
                    f"BC, or dirichlet(value))")


def _as_axis_bc(x) -> Tuple[BC, BC]:
    if isinstance(x, (BC, str)):
        b = _as_bc(x)
        return (b, b)
    if isinstance(x, (tuple, list)) and len(x) == 2:
        return (_as_bc(x[0]), _as_bc(x[1]))
    raise TypeError(f"cannot interpret {x!r} as a per-axis BC (use one "
                    f"kind/BC for both sides or a (lo, hi) pair)")


def as_boundary(bc) -> Boundary:
    """Canonicalize a boundary-condition spelling to the per-axis-side form.

    Accepts ``None`` (all clamp, the default), one kind string or :class:`BC`
    (applied to every side), or a 3-sequence of per-axis entries where each
    entry is itself a kind/:class:`BC` (both sides) or a ``(lo, hi)`` pair.
    The result is a hashable nested tuple, so a spec carrying it still rides
    through ``jax.jit`` as a static argument.
    """
    if bc is None:
        return CLAMP_ALL
    if isinstance(bc, (BC, str)):
        b = _as_bc(bc)
        return ((b, b), (b, b), (b, b))
    if isinstance(bc, (tuple, list)) and len(bc) == 3:
        return tuple(_as_axis_bc(ax) for ax in bc)  # type: ignore[return-value]
    raise TypeError(f"cannot interpret {bc!r} as boundary conditions (use a "
                    f"kind, a BC, or 3 per-axis entries)")


def _validate_boundary(bc: Boundary, ndim: int,
                       radius: Radius = (1, 1, 1)) -> None:
    for ax, (lo, hi) in enumerate(bc):
        if (lo.kind == "periodic") != (hi.kind == "periodic"):
            raise ValueError(
                f"axis {ax}: periodic must be paired -- lo={lo.label()} "
                f"hi={hi.label()} (a one-sided wrap has no meaning)")
    if ndim == 1 and any(s.kind != "clamp" for ax in bc[:2] for s in ax):
        raise ValueError("ndim=1 specs may only carry k-axis boundary "
                         "conditions; i/j sides must stay clamp")
    values = {s.value for ax in bc for s in ax if s.kind == "dirichlet"}
    if len(values) > 1:
        raise ValueError(
            f"multiple distinct dirichlet values {sorted(values)}: corner "
            f"ghost cells would depend on the plan's shift order; use one "
            f"value for every dirichlet side")
    # A nonzero dirichlet ghost value is realized by linearity
    # (``stencil(u) = stencil(u - v) + v * sum(w)``, ghosts of the offset
    # field all zero) -- which requires every *other* ghost kind to be zero
    # under the offset too.  Clamp ghosts stay raw zeros (offset ghost
    # ``-v``), so any point that genuinely reads a clamp ghost -- an
    # interior point at distance >= 2 from a radius->=2 clamp edge -- would
    # be off by ``v * w``.  At radius 1 clamp ghosts only feed ring-masked
    # outputs, so the mix is well-defined there (and dirichlet(0) always
    # agrees with clamp's zero ghosts).
    if any(v != 0.0 for v in values):
        for ax, sides in enumerate(bc):
            if radius[ax] >= 2 and any(s.kind == "clamp" for s in sides):
                raise ValueError(
                    f"dirichlet with a nonzero ghost value cannot combine "
                    f"with a clamp side on a radius-{radius[ax]} axis "
                    f"(axis {ax}): clamp ghosts stay zero under the "
                    f"dirichlet offset identity and are genuinely read at "
                    f"radius >= 2; use dirichlet(0) or a non-clamp BC on "
                    f"that axis")


def bc_labels(bc: Boundary) -> Tuple[str, str, str]:
    """Compact per-axis labels (``describe()`` / benchmark form)."""
    return tuple(lo.label() if lo == hi else f"{lo.label()}|{hi.label()}"
                 for lo, hi in bc)  # type: ignore[return-value]


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """A radius-``(ri, rj, rk)`` stencil: taps in lexicographic ``(di, dj,
    dk)`` order.

    ``ndim == 3`` operates on ``(..., M, N, P)`` volumes with an i-direction
    halo; ``ndim == 1`` has k-only taps and operates on ``(..., P)`` rows
    (every leading dim is an independent row -- the paper's 3-point kernel).
    ``radius`` bounds per-axis offsets (``|di| <= ri`` etc.) and drives every
    geometry decision downstream: halo width is ``radius * sweeps``, the
    replicated path stages ``2r + 1`` neighbour views, the streaming scratch
    window carries ``block_i + ri * sweeps`` planes.  ``bc`` is the per-axis-
    side boundary condition (:class:`BC`; default all-clamp, the historical
    semantics) -- part of the frozen spec, so plan memoization, jit static
    hashing, and ``describe()`` all distinguish BC variants for free.
    """

    name: str
    ndim: int                        # 3 (volumetric) or 1 (k-only rows)
    offsets: Tuple[Offset, ...]      # lexicographic tap order
    w_index: Tuple[int, ...]         # per-tap index into the flat weights
    n_weights: int                   # number of unique coefficients
    w_shape: Tuple[int, ...]         # user-facing weight array shape
    radius: Radius = (1, 1, 1)       # per-axis (ri, rj, rk) offset bound
    bc: Boundary = CLAMP_ALL         # per-axis (lo, hi) boundary conditions
    coef: str = "const"              # "const" scalars | "var" per-point arrays
    ordering: str = "jacobi"         # "jacobi" | "redblack" sweep ordering
    guard: str = "off"               # runtime-verification level (GUARD_KINDS)

    @property
    def taps(self) -> int:
        return len(self.offsets)

    @property
    def sweep_apps(self) -> int:
        """Operator applications per sweep: 1 for Jacobi, 2 for red-black
        Gauss-Seidel (red half-update then black half-update).  Every halo
        computation downstream scales by this -- the black half reads the
        red-updated field, so one red-black sweep propagates information
        ``2 * radius`` cells and the fused halo depth is
        ``radius * sweeps * sweep_apps``."""
        return 2 if self.ordering == "redblack" else 1

    def canon_weights(self, w: jax.Array, domain_shape=None) -> jax.Array:
        """Canonicalize a user weight array.

        ``coef="const"``: flatten to the ``(n_weights,)`` form.
        ``coef="var"``: the weights are per-point coefficient fields evaluated
        at the *output* point -- accept ``(n_weights, ...)`` (or the
        ``w_shape``-shaped leading block) with trailing dims broadcastable
        over the domain, and return ``(n_weights, *domain_shape)``.
        ``domain_shape`` is the trailing spatial shape the operator runs on
        (``(M, N, P)`` volumetric, ``(P,)`` for k-only specs) and is required
        for variable coefficients.
        """
        w = jnp.asarray(w)
        if self.coef == "var":
            if domain_shape is None:
                raise ValueError(
                    f"{self.name}: variable-coefficient weights need the "
                    f"domain shape to canonicalize against")
            domain_shape = tuple(int(s) for s in domain_shape)
            lead = len(self.w_shape)
            if w.shape[:lead] == tuple(self.w_shape):
                w = w.reshape((self.n_weights,) + w.shape[lead:])
            if w.ndim == 0 or w.shape[0] != self.n_weights:
                raise ValueError(
                    f"{self.name}: variable-coefficient weights must carry a "
                    f"leading ({self.n_weights},) (or {self.w_shape}) "
                    f"coefficient axis, got shape {w.shape}")
            tail = w.shape[1:]
            try:
                full = jnp.broadcast_shapes(tail, domain_shape)
            except ValueError:
                full = None
            if full != domain_shape:
                raise ValueError(
                    f"{self.name}: variable-coefficient weights with trailing "
                    f"shape {tail} do not broadcast over the domain "
                    f"{domain_shape}")
            return jnp.broadcast_to(
                w.reshape((self.n_weights,) + (1,) * (len(domain_shape)
                                                      - len(tail)) + tail),
                (self.n_weights,) + domain_shape)
        if int(np.prod(w.shape)) != int(np.prod(self.w_shape)):
            raise ValueError(
                f"{self.name}: weights shape {w.shape} incompatible with "
                f"expected {self.w_shape}")
        return w.reshape(-1)

    def __post_init__(self):
        if self.ndim not in (1, 3):
            raise ValueError(f"ndim must be 1 or 3, got {self.ndim}")
        if len(self.offsets) != len(self.w_index):
            raise ValueError("offsets and w_index must be parallel")
        if (len(self.radius) != 3
                or any(r < 0 for r in self.radius)):
            raise ValueError(f"radius must be 3 non-negative ints, got "
                             f"{self.radius}")
        if self.ndim == 1 and any(di or dj for di, dj, _ in self.offsets):
            raise ValueError("ndim=1 specs may only carry k-direction taps")
        for o in self.offsets:
            if any(abs(d) > r for d, r in zip(o, self.radius)):
                raise ValueError(
                    f"offset {o} out of range for radius {self.radius}")
        if sorted(self.offsets) != list(self.offsets):
            raise ValueError("offsets must be in lexicographic order")
        if self.w_index and max(self.w_index) >= self.n_weights:
            raise ValueError("w_index refers past n_weights")
        if self.coef not in COEF_KINDS:
            raise ValueError(f"unknown coef kind {self.coef!r}; expected one "
                             f"of {COEF_KINDS}")
        if self.ordering not in ORDERING_KINDS:
            raise ValueError(f"unknown ordering {self.ordering!r}; expected "
                             f"one of {ORDERING_KINDS}")
        if self.guard not in GUARD_KINDS:
            raise ValueError(f"unknown guard {self.guard!r}; expected one "
                             f"of {GUARD_KINDS} (or pass a GuardPolicy to "
                             f"the guard= call argument)")
        # canonicalize any as_boundary spelling in place (idempotent on the
        # canonical nested-tuple form)
        object.__setattr__(self, "bc", as_boundary(self.bc))
        _validate_boundary(self.bc, self.ndim, self.radius)

    def with_bc(self, bc, name: str = None) -> "StencilSpec":
        """The same stencil under different boundary conditions.

        ``bc`` takes any :func:`as_boundary` spelling; ``name`` defaults to
        the current name (specs hash on their full value including ``bc``,
        so same-named BC variants still compile and memoize separately).
        """
        return dataclasses.replace(self, bc=as_boundary(bc),
                                   name=self.name if name is None else name)

    def with_coef(self, coef: str, name: str = None) -> "StencilSpec":
        """The same tap set with a different coefficient kind.

        ``coef="var"`` makes the weights per-point arrays evaluated at the
        output point (``out[x] = sum_t w_t(x) * u[x + off_t]``); specs hash
        on their full value including ``coef``, so the plan memo, jit static
        hashing, and ``describe()`` distinguish variable-coefficient variants
        from the constant-coefficient original for free.
        """
        return dataclasses.replace(self, coef=coef,
                                   name=self.name if name is None else name)

    def with_ordering(self, ordering: str, name: str = None) -> "StencilSpec":
        """The same stencil under a different sweep ordering.

        ``ordering="redblack"`` makes every sweep a red-black Gauss-Seidel
        sweep: the operator is applied at the *red* checkerboard parity
        (``(i + j + k) % 2 == 0`` in global coordinates), merged, then at
        the black parity reading the red-updated field.  Specs hash on their
        full value including ``ordering``, so plan memoization, jit static
        hashing, and ``describe()`` distinguish ordering variants for free;
        the plan itself (the per-application op schedule) is unchanged --
        ordering is realized by the sweep loop's checkerboard masks.
        """
        return dataclasses.replace(self, ordering=ordering,
                                   name=self.name if name is None else name)

    def with_guard(self, guard: str, name: str = None) -> "StencilSpec":
        """The same stencil under a guarded-execution level.

        ``guard`` is one of :data:`GUARD_KINDS` -- ``"off"`` (the default:
        no checks, the historical byte-identical programs), ``"nan"``
        (NaN/Inf output screening), ``"invariant"`` (+ the weight-sum
        conservation check), ``"oracle"`` (+ the sampled-plane oracle spot
        check), or ``"full"`` (every check over the full output).  The
        guarded entry points strip the field back to ``"off"`` before
        compiling plans and tracing kernels, so the executed programs are
        shared with unguarded calls -- the guard only wraps them with
        host-side checks and the degradation ladder (see :mod:`.guard`).
        """
        return dataclasses.replace(self, guard=guard,
                                   name=self.name if name is None else name)


_REGISTRY: Dict[str, StencilSpec] = {}


def register_stencil(spec: StencilSpec, aliases: Iterable[str] = ()) -> StencilSpec:
    for key in (spec.name, *aliases):
        _REGISTRY[str(key)] = spec
    return spec


def get_stencil(stencil: Union[str, int, StencilSpec]) -> StencilSpec:
    if isinstance(stencil, StencilSpec):
        return stencil
    key = str(stencil)
    if key not in _REGISTRY:
        raise KeyError(f"unknown stencil {stencil!r}; registered: "
                       f"{sorted(set(_REGISTRY))}")
    return _REGISTRY[key]


def list_stencils() -> Dict[str, StencilSpec]:
    return dict(_REGISTRY)


def spec_from_mask(name: str, mask, ndim: int = 3, bc=None) -> StencilSpec:
    """Build a spec from an odd-shaped coefficient-index mask.

    ``mask`` has shape ``(2*ri + 1, 2*rj + 1, 2*rk + 1)`` (every extent odd;
    ``(3, 3, 3)`` is the radius-1 case) and ``mask[di + ri, dj + rj, dk +
    rk]`` is the weight index of the tap at offset ``(di, dj, dk)``; negative
    entries mean "no tap".  A boolean mask assigns every active tap its own
    weight in lexicographic order.  Integer masks must use the contiguous
    weight indices ``0..k-1`` -- a gap (e.g. ``{0, 2}``) would silently
    create a dangling unused weight, so it is rejected.
    """
    m = np.asarray(mask)
    if m.ndim != 3 or any(s < 1 or s % 2 == 0 for s in m.shape):
        raise ValueError(f"mask must be 3-D with odd extents "
                         f"(2r+1 per axis), got {m.shape}")
    ri, rj, rk = (s // 2 for s in m.shape)
    offsets, w_index = [], []
    next_w = 0
    for di in range(-ri, ri + 1):
        for dj in range(-rj, rj + 1):
            for dk in range(-rk, rk + 1):
                v = m[di + ri, dj + rj, dk + rk]
                if m.dtype == bool:
                    if not v:
                        continue
                    idx = next_w
                    next_w += 1
                else:
                    if v < 0:
                        continue
                    idx = int(v)
                offsets.append((di, dj, dk))
                w_index.append(idx)
    if m.dtype == bool:
        n_w = next_w
    else:
        used = sorted(set(w_index))
        if used and used != list(range(len(used))):
            missing = sorted(set(range(used[-1] + 1)) - set(used))
            raise ValueError(
                f"{name}: weight indices {used} skip {missing}; indices "
                f"must be contiguous 0..k-1 (a gap would leave an unused "
                f"dangling weight)")
        n_w = used[-1] + 1 if used else 0
    return StencilSpec(name=name, ndim=ndim, offsets=tuple(offsets),
                       w_index=tuple(w_index), n_weights=n_w, w_shape=(n_w,),
                       radius=(ri, rj, rk), bc=as_boundary(bc))


def _builtin_specs() -> None:
    # 3-point: w = (w_edge, w_center), k-only (paper's 1-D streaming kernel).
    register_stencil(StencilSpec(
        name="stencil3", ndim=1,
        offsets=((0, 0, -1), (0, 0, 0), (0, 0, 1)),
        w_index=(0, 1, 0), n_weights=2, w_shape=(2,)),
        aliases=("3",))
    # 7-point: w = (wc, wk, wj, wi), 4 unique coefficients (paper sect. 3.1).
    register_stencil(StencilSpec(
        name="stencil7", ndim=3,
        offsets=((-1, 0, 0), (0, -1, 0), (0, 0, -1), (0, 0, 0),
                 (0, 0, 1), (0, 1, 0), (1, 0, 0)),
        w_index=(3, 2, 1, 0, 1, 2, 3), n_weights=4, w_shape=(4,)),
        aliases=("7",))
    # 27-point: w[|di|, |dj|, |dk|], 8 unique coefficients; the tap order is
    # the legacy reference's nested (di, dj, dk) loop, so the f64 path is
    # bit-identical to the seed oracle.
    offs, widx = [], []
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            for dk in (-1, 0, 1):
                offs.append((di, dj, dk))
                widx.append(4 * abs(di) + 2 * abs(dj) + abs(dk))
    register_stencil(StencilSpec(
        name="stencil27", ndim=3, offsets=tuple(offs), w_index=tuple(widx),
        n_weights=8, w_shape=(2, 2, 2)),
        aliases=("27",))
    # star13: radius-2 axis star (the 4th-order Laplacian shape) -- one tap
    # at distance 1 and 2 along each axis plus the centre, weights shared per
    # distance: w = (w_center, w_dist1, w_dist2).
    offs, widx = [], []
    for di in range(-2, 3):
        for dj in range(-2, 3):
            for dk in range(-2, 3):
                nz = [abs(d) for d in (di, dj, dk) if d]
                if len(nz) > 1 or (nz and nz[0] > 2):
                    continue
                offs.append((di, dj, dk))
                widx.append(nz[0] if nz else 0)
    register_stencil(StencilSpec(
        name="star13", ndim=3, offsets=tuple(offs), w_index=tuple(widx),
        n_weights=3, w_shape=(3,), radius=(2, 2, 2)),
        aliases=("13",))
    # box125: the full 5x5x5 box, w[|di|, |dj|, |dk|] with shape (3, 3, 3)
    # (27 unique coefficients) -- the radius-2 analogue of stencil27.
    offs, widx = [], []
    for di in range(-2, 3):
        for dj in range(-2, 3):
            for dk in range(-2, 3):
                offs.append((di, dj, dk))
                widx.append(9 * abs(di) + 3 * abs(dj) + abs(dk))
    register_stencil(StencilSpec(
        name="box125", ndim=3, offsets=tuple(offs), w_index=tuple(widx),
        n_weights=27, w_shape=(3, 3, 3), radius=(2, 2, 2)),
        aliases=("125",))


def _builtin_bc_variants() -> None:
    """BC-suffixed registry aliases: every builtin under each non-default
    boundary condition (``dirichlet`` at the homogeneous value 0; pass an
    explicit ``spec.with_bc(dirichlet(v))`` for inhomogeneous ghosts).  For
    the k-only ``stencil3`` the BC applies to the k axis alone (i/j sides of
    a 1-D spec must stay clamp)."""
    for base in ("stencil3", "stencil7", "stencil27", "star13", "box125"):
        spec = _REGISTRY[base]
        for tag, b in (("periodic", PERIODIC), ("neumann", NEUMANN),
                       ("dirichlet", dirichlet(0.0))):
            bc = (((CLAMP, CLAMP), (CLAMP, CLAMP), (b, b))
                  if spec.ndim == 1 else b)
            register_stencil(spec.with_bc(bc, name=f"{base}_{tag}"))


def _builtin_ordering_variants() -> None:
    """Red-black Gauss-Seidel registry aliases for the volumetric builtins
    (and the k-only ``stencil3``): one checkerboarded sweep ordering per
    base spec, same taps / weights / BCs."""
    for base in ("stencil3", "stencil7", "stencil27", "star13", "box125"):
        spec = _REGISTRY[base]
        register_stencil(spec.with_ordering("redblack",
                                            name=f"{base}_redblack"))


_builtin_specs()
_builtin_bc_variants()
_builtin_ordering_variants()
