"""Stencil specifications: radius-R coefficient masks + the named registry.

A :class:`StencilSpec` describes a stencil as a list of taps -- ``(di, dj,
dk)`` offsets in lexicographic order -- each tagged with an index into a flat
vector of unique coefficients, plus a per-axis ``radius`` bounding the
offsets.  The paper's three streaming kernels (3-, 7-, 27-point, sect. 3.1)
are radius-1 entries in the registry; high-order operators (the 4th-order
13-point star, the 5x5x5 box) are radius-2 entries, and any other operator is
one :func:`spec_from_mask` call away from an odd-shaped coefficient mask.
The spec is a frozen (hashable) dataclass so it can ride through ``jax.jit``
as a static argument, and both the Pallas kernel body and the jnp reference
expand the same compiled plan, in the same order -- which is what makes the
f64 paths agree bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Offset = Tuple[int, int, int]
Radius = Tuple[int, int, int]


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """A radius-``(ri, rj, rk)`` stencil: taps in lexicographic ``(di, dj,
    dk)`` order.

    ``ndim == 3`` operates on ``(..., M, N, P)`` volumes with an i-direction
    halo; ``ndim == 1`` has k-only taps and operates on ``(..., P)`` rows
    (every leading dim is an independent row -- the paper's 3-point kernel).
    ``radius`` bounds per-axis offsets (``|di| <= ri`` etc.) and drives every
    geometry decision downstream: halo width is ``radius * sweeps``, the
    replicated path stages ``2r + 1`` neighbour views, the streaming scratch
    window carries ``block_i + ri * sweeps`` planes.
    """

    name: str
    ndim: int                        # 3 (volumetric) or 1 (k-only rows)
    offsets: Tuple[Offset, ...]      # lexicographic tap order
    w_index: Tuple[int, ...]         # per-tap index into the flat weights
    n_weights: int                   # number of unique coefficients
    w_shape: Tuple[int, ...]         # user-facing weight array shape
    radius: Radius = (1, 1, 1)       # per-axis (ri, rj, rk) offset bound

    @property
    def taps(self) -> int:
        return len(self.offsets)

    def canon_weights(self, w: jax.Array) -> jax.Array:
        """Flatten a user weight array to the ``(n_weights,)`` canonical form."""
        w = jnp.asarray(w)
        if int(np.prod(w.shape)) != int(np.prod(self.w_shape)):
            raise ValueError(
                f"{self.name}: weights shape {w.shape} incompatible with "
                f"expected {self.w_shape}")
        return w.reshape(-1)

    def __post_init__(self):
        if self.ndim not in (1, 3):
            raise ValueError(f"ndim must be 1 or 3, got {self.ndim}")
        if len(self.offsets) != len(self.w_index):
            raise ValueError("offsets and w_index must be parallel")
        if (len(self.radius) != 3
                or any(r < 0 for r in self.radius)):
            raise ValueError(f"radius must be 3 non-negative ints, got "
                             f"{self.radius}")
        if self.ndim == 1 and any(di or dj for di, dj, _ in self.offsets):
            raise ValueError("ndim=1 specs may only carry k-direction taps")
        for o in self.offsets:
            if any(abs(d) > r for d, r in zip(o, self.radius)):
                raise ValueError(
                    f"offset {o} out of range for radius {self.radius}")
        if sorted(self.offsets) != list(self.offsets):
            raise ValueError("offsets must be in lexicographic order")
        if self.w_index and max(self.w_index) >= self.n_weights:
            raise ValueError("w_index refers past n_weights")


_REGISTRY: Dict[str, StencilSpec] = {}


def register_stencil(spec: StencilSpec, aliases: Iterable[str] = ()) -> StencilSpec:
    for key in (spec.name, *aliases):
        _REGISTRY[str(key)] = spec
    return spec


def get_stencil(stencil: Union[str, int, StencilSpec]) -> StencilSpec:
    if isinstance(stencil, StencilSpec):
        return stencil
    key = str(stencil)
    if key not in _REGISTRY:
        raise KeyError(f"unknown stencil {stencil!r}; registered: "
                       f"{sorted(set(_REGISTRY))}")
    return _REGISTRY[key]


def list_stencils() -> Dict[str, StencilSpec]:
    return dict(_REGISTRY)


def spec_from_mask(name: str, mask, ndim: int = 3) -> StencilSpec:
    """Build a spec from an odd-shaped coefficient-index mask.

    ``mask`` has shape ``(2*ri + 1, 2*rj + 1, 2*rk + 1)`` (every extent odd;
    ``(3, 3, 3)`` is the radius-1 case) and ``mask[di + ri, dj + rj, dk +
    rk]`` is the weight index of the tap at offset ``(di, dj, dk)``; negative
    entries mean "no tap".  A boolean mask assigns every active tap its own
    weight in lexicographic order.  Integer masks must use the contiguous
    weight indices ``0..k-1`` -- a gap (e.g. ``{0, 2}``) would silently
    create a dangling unused weight, so it is rejected.
    """
    m = np.asarray(mask)
    if m.ndim != 3 or any(s < 1 or s % 2 == 0 for s in m.shape):
        raise ValueError(f"mask must be 3-D with odd extents "
                         f"(2r+1 per axis), got {m.shape}")
    ri, rj, rk = (s // 2 for s in m.shape)
    offsets, w_index = [], []
    next_w = 0
    for di in range(-ri, ri + 1):
        for dj in range(-rj, rj + 1):
            for dk in range(-rk, rk + 1):
                v = m[di + ri, dj + rj, dk + rk]
                if m.dtype == bool:
                    if not v:
                        continue
                    idx = next_w
                    next_w += 1
                else:
                    if v < 0:
                        continue
                    idx = int(v)
                offsets.append((di, dj, dk))
                w_index.append(idx)
    if m.dtype == bool:
        n_w = next_w
    else:
        used = sorted(set(w_index))
        if used and used != list(range(len(used))):
            missing = sorted(set(range(used[-1] + 1)) - set(used))
            raise ValueError(
                f"{name}: weight indices {used} skip {missing}; indices "
                f"must be contiguous 0..k-1 (a gap would leave an unused "
                f"dangling weight)")
        n_w = used[-1] + 1 if used else 0
    return StencilSpec(name=name, ndim=ndim, offsets=tuple(offsets),
                       w_index=tuple(w_index), n_weights=n_w, w_shape=(n_w,),
                       radius=(ri, rj, rk))


def _builtin_specs() -> None:
    # 3-point: w = (w_edge, w_center), k-only (paper's 1-D streaming kernel).
    register_stencil(StencilSpec(
        name="stencil3", ndim=1,
        offsets=((0, 0, -1), (0, 0, 0), (0, 0, 1)),
        w_index=(0, 1, 0), n_weights=2, w_shape=(2,)),
        aliases=("3",))
    # 7-point: w = (wc, wk, wj, wi), 4 unique coefficients (paper sect. 3.1).
    register_stencil(StencilSpec(
        name="stencil7", ndim=3,
        offsets=((-1, 0, 0), (0, -1, 0), (0, 0, -1), (0, 0, 0),
                 (0, 0, 1), (0, 1, 0), (1, 0, 0)),
        w_index=(3, 2, 1, 0, 1, 2, 3), n_weights=4, w_shape=(4,)),
        aliases=("7",))
    # 27-point: w[|di|, |dj|, |dk|], 8 unique coefficients; the tap order is
    # the legacy reference's nested (di, dj, dk) loop, so the f64 path is
    # bit-identical to the seed oracle.
    offs, widx = [], []
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            for dk in (-1, 0, 1):
                offs.append((di, dj, dk))
                widx.append(4 * abs(di) + 2 * abs(dj) + abs(dk))
    register_stencil(StencilSpec(
        name="stencil27", ndim=3, offsets=tuple(offs), w_index=tuple(widx),
        n_weights=8, w_shape=(2, 2, 2)),
        aliases=("27",))
    # star13: radius-2 axis star (the 4th-order Laplacian shape) -- one tap
    # at distance 1 and 2 along each axis plus the centre, weights shared per
    # distance: w = (w_center, w_dist1, w_dist2).
    offs, widx = [], []
    for di in range(-2, 3):
        for dj in range(-2, 3):
            for dk in range(-2, 3):
                nz = [abs(d) for d in (di, dj, dk) if d]
                if len(nz) > 1 or (nz and nz[0] > 2):
                    continue
                offs.append((di, dj, dk))
                widx.append(nz[0] if nz else 0)
    register_stencil(StencilSpec(
        name="star13", ndim=3, offsets=tuple(offs), w_index=tuple(widx),
        n_weights=3, w_shape=(3,), radius=(2, 2, 2)),
        aliases=("13",))
    # box125: the full 5x5x5 box, w[|di|, |dj|, |dk|] with shape (3, 3, 3)
    # (27 unique coefficients) -- the radius-2 analogue of stencil27.
    offs, widx = [], []
    for di in range(-2, 3):
        for dj in range(-2, 3):
            for dk in range(-2, 3):
                offs.append((di, dj, dk))
                widx.append(9 * abs(di) + 3 * abs(dj) + abs(dk))
    register_stencil(StencilSpec(
        name="box125", ndim=3, offsets=tuple(offs), w_index=tuple(widx),
        n_weights=27, w_shape=(3, 3, 3), radius=(2, 2, 2)),
        aliases=("125",))


_builtin_specs()
