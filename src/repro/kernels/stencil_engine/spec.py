"""Stencil specifications: radius-1 coefficient masks + the named registry.

A :class:`StencilSpec` describes a radius-1 stencil as a list of taps --
``(di, dj, dk)`` offsets in lexicographic order -- each tagged with an index
into a flat vector of unique coefficients.  The paper's three streaming
kernels (3-, 7-, 27-point, sect. 3.1) are three entries in the registry; any
other radius-1 operator is one :func:`spec_from_mask` call away.  The spec is
a frozen (hashable) dataclass so it can ride through ``jax.jit`` as a static
argument, and both the Pallas kernel body and the jnp reference expand the
same tap list, in the same order -- which is what makes the f64 paths agree
bit-for-bit.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Offset = Tuple[int, int, int]


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """A radius-1 stencil: taps in lexicographic ``(di, dj, dk)`` order.

    ``ndim == 3`` operates on ``(..., M, N, P)`` volumes with an i-direction
    halo; ``ndim == 1`` has k-only taps and operates on ``(..., P)`` rows
    (every leading dim is an independent row -- the paper's 3-point kernel).
    """

    name: str
    ndim: int                        # 3 (volumetric) or 1 (k-only rows)
    offsets: Tuple[Offset, ...]      # lexicographic tap order
    w_index: Tuple[int, ...]         # per-tap index into the flat weights
    n_weights: int                   # number of unique coefficients
    w_shape: Tuple[int, ...]         # user-facing weight array shape

    @property
    def taps(self) -> int:
        return len(self.offsets)

    def canon_weights(self, w: jax.Array) -> jax.Array:
        """Flatten a user weight array to the ``(n_weights,)`` canonical form."""
        w = jnp.asarray(w)
        if int(np.prod(w.shape)) != int(np.prod(self.w_shape)):
            raise ValueError(
                f"{self.name}: weights shape {w.shape} incompatible with "
                f"expected {self.w_shape}")
        return w.reshape(-1)

    def __post_init__(self):
        if self.ndim not in (1, 3):
            raise ValueError(f"ndim must be 1 or 3, got {self.ndim}")
        if len(self.offsets) != len(self.w_index):
            raise ValueError("offsets and w_index must be parallel")
        if self.ndim == 1 and any(di or dj for di, dj, _ in self.offsets):
            raise ValueError("ndim=1 specs may only carry k-direction taps")
        for o in self.offsets:
            if any(abs(d) > 1 for d in o):
                raise ValueError(f"radius-1 engine: offset {o} out of range")
        if sorted(self.offsets) != list(self.offsets):
            raise ValueError("offsets must be in lexicographic order")
        if self.w_index and max(self.w_index) >= self.n_weights:
            raise ValueError("w_index refers past n_weights")


_REGISTRY: Dict[str, StencilSpec] = {}


def register_stencil(spec: StencilSpec, aliases: Iterable[str] = ()) -> StencilSpec:
    for key in (spec.name, *aliases):
        _REGISTRY[str(key)] = spec
    return spec


def get_stencil(stencil: Union[str, int, StencilSpec]) -> StencilSpec:
    if isinstance(stencil, StencilSpec):
        return stencil
    key = str(stencil)
    if key not in _REGISTRY:
        raise KeyError(f"unknown stencil {stencil!r}; registered: "
                       f"{sorted(set(_REGISTRY))}")
    return _REGISTRY[key]


def list_stencils() -> Dict[str, StencilSpec]:
    return dict(_REGISTRY)


def spec_from_mask(name: str, mask, ndim: int = 3) -> StencilSpec:
    """Build a spec from a ``(3, 3, 3)`` coefficient-index mask.

    ``mask[di+1, dj+1, dk+1]`` is the weight index of the tap at offset
    ``(di, dj, dk)``; negative entries mean "no tap".  A boolean mask assigns
    every active tap its own weight in lexicographic order.
    """
    m = np.asarray(mask)
    if m.shape != (3, 3, 3):
        raise ValueError(f"mask must be (3, 3, 3), got {m.shape}")
    offsets, w_index = [], []
    next_w = 0
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            for dk in (-1, 0, 1):
                v = m[di + 1, dj + 1, dk + 1]
                if m.dtype == bool:
                    if not v:
                        continue
                    idx = next_w
                    next_w += 1
                else:
                    if v < 0:
                        continue
                    idx = int(v)
                offsets.append((di, dj, dk))
                w_index.append(idx)
    n_w = (next_w if m.dtype == bool
           else (max(w_index) + 1 if w_index else 0))
    return StencilSpec(name=name, ndim=ndim, offsets=tuple(offsets),
                      w_index=tuple(w_index), n_weights=n_w, w_shape=(n_w,))


def _builtin_specs() -> None:
    # 3-point: w = (w_edge, w_center), k-only (paper's 1-D streaming kernel).
    register_stencil(StencilSpec(
        name="stencil3", ndim=1,
        offsets=((0, 0, -1), (0, 0, 0), (0, 0, 1)),
        w_index=(0, 1, 0), n_weights=2, w_shape=(2,)),
        aliases=("3",))
    # 7-point: w = (wc, wk, wj, wi), 4 unique coefficients (paper sect. 3.1).
    register_stencil(StencilSpec(
        name="stencil7", ndim=3,
        offsets=((-1, 0, 0), (0, -1, 0), (0, 0, -1), (0, 0, 0),
                 (0, 0, 1), (0, 1, 0), (1, 0, 0)),
        w_index=(3, 2, 1, 0, 1, 2, 3), n_weights=4, w_shape=(4,)),
        aliases=("7",))
    # 27-point: w[|di|, |dj|, |dk|], 8 unique coefficients; the tap order is
    # the legacy reference's nested (di, dj, dk) loop, so the f64 path is
    # bit-identical to the seed oracle.
    offs, widx = [], []
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            for dk in (-1, 0, 1):
                offs.append((di, dj, dk))
                widx.append(4 * abs(di) + 2 * abs(dj) + abs(dk))
    register_stencil(StencilSpec(
        name="stencil27", ndim=3, offsets=tuple(offs), w_index=tuple(widx),
        n_weights=8, w_shape=(2, 2, 2)),
        aliases=("27",))


_builtin_specs()
