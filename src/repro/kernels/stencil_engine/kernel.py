"""The single Pallas kernel body behind every engine stencil.

One body serves 3-, 7-, 27-point and arbitrary radius-1 masks: the spec's tap
list is unrolled at trace time into an FMA chain (the paper's synthesis step,
retargeted from PPC450 SIMOMD slots to VPU lane shifts).  The same body also
fuses ``s`` Jacobi sweeps per grid step: each block is widened by ``s`` halo
rows on either side (read from the +-1 neighbour blocks), the sweep loop runs
register/VMEM-resident, and only the central ``bi`` rows are written back --
one HBM round-trip for ``s`` applications of the operator, the Pallas
analogue of the paper's register-resident steady-state stream.  Global
geometry (row offset, global M) arrives as a small int32 operand so the same
kernel runs unsharded (offset 0) and as the per-shard body of the halo-
exchange ``shard_map`` path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .spec import StencilSpec


def acc_dtype_for(dtype) -> jnp.dtype:
    """bf16/f32 accumulate in f32; the f64 reference path stays f64."""
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def accumulate_taps(u: jax.Array, w: jax.Array, spec: StencilSpec,
                    acc_dtype) -> jax.Array:
    """Expand the spec's tap list: ``acc[x] = sum_t w[t] * u[x + offset_t]``.

    Neighbour access is by ``jnp.roll`` on the trailing axes (the TPU
    load-copy strategy -- lane/sublane shifts of the resident block).  Rolled
    wrap-around values only ever land on rows the caller masks out.  Tap
    order is the spec's lexicographic order, which keeps the f64 path
    bit-identical to the jnp reference.
    """
    acc = jnp.zeros(u.shape, acc_dtype)
    for (di, dj, dk), wi in zip(spec.offsets, spec.w_index):
        t = u
        if di:
            t = jnp.roll(t, -di, axis=-3)
        if dj:
            t = jnp.roll(t, -dj, axis=-2)
        if dk:
            t = jnp.roll(t, -dk, axis=-1)
        acc = acc + w[wi] * t
    return acc


def stencil3d_kernel(a_prev, a_cur, a_next, geom_ref, w_ref, o_ref, *,
                     spec: StencilSpec, bi: int, sweeps: int, acc_dtype):
    """Fused-sweep volumetric kernel; blocks are ``(1, bi, N, P)``.

    ``geom_ref`` = (global row of this array's row 0, global M) -- both 0 and
    the local M for the single-device path; shard-dependent under shard_map.
    """
    i_blk = pl.program_id(1)
    s = sweeps
    prev, cur, nxt = a_prev[0], a_cur[0], a_next[0]        # (bi, N, P)
    # Extended working block: s halo rows each side, accumulation dtype.
    u = jnp.concatenate([prev[-s:], cur, nxt[:s]], axis=0).astype(acc_dtype)
    w = w_ref[...]
    n, p = cur.shape[-2], cur.shape[-1]
    ext = bi + 2 * s
    gi = (geom_ref[0] + i_blk * bi - s
          + jax.lax.broadcasted_iota(jnp.int32, (ext, n, p), 0))
    jj = jax.lax.broadcasted_iota(jnp.int32, (ext, n, p), 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, (ext, n, p), 2)
    interior = ((gi > 0) & (gi < geom_ref[1] - 1)
                & (jj > 0) & (jj < n - 1) & (kk > 0) & (kk < p - 1))
    # Jacobi sweeps, Dirichlet boundary re-zeroed after each; the valid
    # region shrinks one row per sweep from the extended edges, so the
    # central bi rows are exact after s sweeps (requires s <= bi).
    for _ in range(s):
        u = jnp.where(interior, accumulate_taps(u, w, spec, acc_dtype), 0)
    o_ref[0] = u[s:s + bi].astype(o_ref.dtype)


def stencil1d_kernel(a_ref, w_ref, o_ref, *, spec: StencilSpec, sweeps: int,
                     acc_dtype):
    """k-only kernel over ``(block_rows, P)`` blocks; rows are independent,
    so fused sweeps need no halo at all."""
    u = a_ref[...].astype(acc_dtype)
    w = w_ref[...]
    p = u.shape[-1]
    kk = jax.lax.broadcasted_iota(jnp.int32, u.shape, u.ndim - 1)
    interior = (kk > 0) & (kk < p - 1)
    for _ in range(sweeps):
        u = jnp.where(interior, accumulate_taps(u, w, spec, acc_dtype), 0)
    o_ref[...] = u.astype(o_ref.dtype)
