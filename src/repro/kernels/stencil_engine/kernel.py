"""The Pallas kernel bodies behind every engine stencil.

One compute core serves 3-, 7-, 27-point and arbitrary radius-1 masks: the
spec is first compiled to a :class:`~.plan.StencilPlan` (the paper's
synthesis step -- a factored partial-sum schedule for symmetric specs, a
CSE'd shift schedule for arbitrary masks, a naive ``direct`` escape hatch)
and the plan is unrolled at trace time.  Neighbour access is by static slice
+ zero pad on the resident block (:func:`~.plan.shift_slice`), never a
wrap-around roll, so no out-of-domain values are computed then masked.

Two volumetric bodies share that core:

``stencil3d_kernel`` (the *replicated* path, parity escape hatch)
    The input is passed 3x (untiled) or 9x (j-tiled) under +-1-shifted block
    index maps, so each grid step re-fetches its halo neighbours from HBM.
    Simple, stateless, and kept as the ``path="replicate"`` reference.

``stencil3d_stream_kernel`` (the *streaming* path, default)
    The paper's central optimization (sect. 3-4): stream along the i axis
    and keep the active planes resident so each loaded plane is reused by
    every output plane that needs it, instead of being re-fetched.  A single
    input operand walks i-blocks in order on a grid with one extra step; a
    VMEM ``scratch_shapes`` buffer carries a rotating window of ``bi + s``
    input planes (the previous block plus the ``s``-deep halo tail of the
    block before it) across grid steps.  Step ``t`` computes output block
    ``t - 1`` from ``[scratch | head s planes of block t]`` and then rotates
    the window -- so every input plane is fetched from HBM exactly once per
    call and written once: ~2 transfers per point, the paper's
    register-resident ideal (VMEM standing in for the register file).

Both bodies fuse ``s`` Jacobi sweeps per grid step: the working strip is
``s`` halo planes wider than the output block, the sweep loop runs
VMEM-resident via :func:`run_sweeps` (interior mask and zero fill built
once, not per unrolled sweep), and only the central planes are written back
-- one HBM round-trip for ``s`` applications of the operator.  Global
geometry (row offset, global M) arrives as a small int32 operand so the same
bodies run unsharded (offset 0) and as the per-shard body of the
halo-exchange ``shard_map`` path.  When ``bj`` is set the grid gains a j
dimension: the replicated body sees the 3x3 neighbour tiles; the streaming
body streams i within each j-tile (3 j-neighbour views, so planes are
fetched 3x instead of the replicated 9x -- exactly-once needs the full-N
strip in scratch, which is the one regime j-tiling exists to avoid).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .plan import StencilPlan, execute_plan


def acc_dtype_for(dtype) -> jnp.dtype:
    """bf16/f32 accumulate in f32; the f64 reference path stays f64."""
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def run_sweeps(u: jax.Array, interior: jax.Array, w: jax.Array,
               plan: StencilPlan, sweeps: int) -> jax.Array:
    """Fused Jacobi sweep loop with the loop-invariant Dirichlet select
    hoisted: the interior mask *and* the zero fill it selects against are
    materialized once and reused by every unrolled sweep (previously the
    scalar zero was re-broadcast to the full block per sweep).  The valid
    region shrinks one plane per sweep from the extended edges, so the
    central block is exact after ``sweeps`` applications."""
    zero = jnp.zeros(u.shape, u.dtype)
    for _ in range(sweeps):
        u = jnp.where(interior, execute_plan(plan, u, w), zero)
    return u


def _volumetric_interior(ext, gi0, j0, m_ref, n_global: int):
    """Interior (non-Dirichlet) mask of an extended working strip whose
    row 0 sits at global row ``gi0`` and column 0 at global column ``j0``;
    ``m_ref`` is the (traced) global M.  Built once per grid step and shared
    across every fused sweep."""
    gi = gi0 + jax.lax.broadcasted_iota(jnp.int32, ext, 0)
    jj = j0 + jax.lax.broadcasted_iota(jnp.int32, ext, 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, ext, 2)
    return ((gi > 0) & (gi < m_ref - 1)
            & (jj > 0) & (jj < n_global - 1)
            & (kk > 0) & (kk < ext[-1] - 1))


def stencil3d_kernel(*refs, plan: StencilPlan, bi: int, bj: Optional[int],
                     n_global: int, sweeps: int, acc_dtype):
    """Replicated-halo fused-sweep volumetric kernel (``path="replicate"``).

    ``refs`` is ``(*blocks, geom_ref, w_ref, o_ref)`` where ``blocks`` holds
    the 3 i-neighbour views (untiled, blocks ``(1, bi, N, P)``) or the 3x3
    i/j-neighbour views in row-major ``(di, dj)`` order (j-tiled, blocks
    ``(1, bi, bj, P)``).  ``geom_ref`` = (global row of this array's row 0,
    global M) -- both 0 and the local M for the single-device path;
    shard-dependent under shard_map.
    """
    o_ref = refs[-1]
    geom_ref, w_ref = refs[-3], refs[-2]
    blocks = refs[:-3]
    i_blk = pl.program_id(1)
    s = sweeps
    w = w_ref[...]
    if bj is None:
        prev, cur, nxt = (r[0] for r in blocks)            # (bi, N, P)
        u = jnp.concatenate([prev[-s:], cur, nxt[:s]],
                            axis=0).astype(acc_dtype)
        j0 = 0
    else:
        j_blk = pl.program_id(2)
        strips = []
        for ii in range(3):
            row = [blocks[3 * ii + 0][0][:, -s:],
                   blocks[3 * ii + 1][0],
                   blocks[3 * ii + 2][0][:, :s]]
            strip = jnp.concatenate(row, axis=1)           # (bi, bj + 2s, P)
            strips.append(strip[-s:] if ii == 0
                          else (strip if ii == 1 else strip[:s]))
        u = jnp.concatenate(strips, axis=0).astype(acc_dtype)
        j0 = j_blk * bj - s
    interior = _volumetric_interior(u.shape, geom_ref[0] + i_blk * bi - s,
                                    j0, geom_ref[1], n_global)
    u = run_sweeps(u, interior, w, plan, s)
    out = u[s:s + bi] if bj is None else u[s:s + bi, s:s + bj]
    o_ref[0] = out.astype(o_ref.dtype)


def stencil3d_stream_kernel(*refs, plan: StencilPlan, bi: int,
                            bj: Optional[int], n_global: int, sweeps: int,
                            acc_dtype):
    """Plane-streaming fused-sweep volumetric kernel (``path="stream"``).

    ``refs`` is ``(*views, geom_ref, w_ref, o_ref, scr_ref)``.  Untiled
    (``bj is None``): ``views`` is one identity-mapped block ``(1, bi, N,
    P)`` and the grid's trailing dim runs ``nbi + 1`` steps; j-tiled:
    ``views`` are the 3 j-neighbour tiles ``(1, bi, bj, P)`` and the grid is
    ``(B, nbj, nbi + 1)`` with i innermost, so the stream restarts per
    j-tile.  ``scr_ref`` is VMEM scratch of ``bi + s`` input planes carried
    across grid steps: planes ``[0, s)`` are the tail of block ``t - 2``
    (zeros above the domain), planes ``[s, s + bi)`` are block ``t - 1``.

    Step 0 primes the window; step ``t >= 1`` assembles the working strip
    ``[scratch | head s planes of block t]`` (at ``t == nbi`` the clamped
    index map re-presents block ``nbi - 1``, whose planes land only at
    ``gi >= M`` where the interior mask zeroes them -- and an unchanged
    block index costs no DMA under Pallas revisiting semantics), runs the
    fused sweeps, writes output block ``t - 1`` via the lagged output index
    map, and rotates the window.  Net HBM traffic: each input plane read
    once, each output plane written once.
    """
    o_ref, scr_ref = refs[-2], refs[-1]
    geom_ref, w_ref = refs[-4], refs[-3]
    views = refs[:-4]
    s = sweeps
    w = w_ref[...]
    if bj is None:
        t = pl.program_id(1)
        cur = views[0][0]                                  # (bi, N, P)
        j0 = 0
    else:
        t = pl.program_id(2)
        j_blk = pl.program_id(1)
        jm, jc, jp = (v[0] for v in views)                 # (bi, bj, P)
        cur = jnp.concatenate([jm[:, -s:], jc, jp[:, :s]],
                              axis=1)                      # (bi, bj + 2s, P)
        j0 = j_blk * bj - s

    @pl.when(t == 0)
    def _prime():
        # Window for output block 0: block "-1" is above the domain (zeros;
        # they only ever feed rows the interior mask zeroes), block 0 = cur.
        scr_ref[:s] = jnp.zeros((s,) + cur.shape[1:], cur.dtype)
        scr_ref[s:] = cur

    @pl.when(t > 0)
    def _compute():
        u = jnp.concatenate([scr_ref[...], cur[:s]],
                            axis=0).astype(acc_dtype)      # (bi + 2s, ·, P)
        interior = _volumetric_interior(
            u.shape, geom_ref[0] + (t - 1) * bi - s, j0, geom_ref[1],
            n_global)
        u = run_sweeps(u, interior, w, plan, s)
        out = u[s:s + bi] if bj is None else u[s:s + bi, s:s + bj]
        o_ref[0] = out.astype(o_ref.dtype)
        # Rotate the window: new tail = last s planes of block t - 1.
        tail = scr_ref[bi:bi + s]
        scr_ref[:s] = tail
        scr_ref[s:] = cur


def stencil1d_kernel(a_ref, w_ref, o_ref, *, plan: StencilPlan, sweeps: int,
                     acc_dtype):
    """k-only kernel over ``(block_rows, P)`` blocks; rows are independent,
    so fused sweeps need no halo at all."""
    u = a_ref[...].astype(acc_dtype)
    w = w_ref[...]
    p = u.shape[-1]
    kk = jax.lax.broadcasted_iota(jnp.int32, u.shape, u.ndim - 1)
    interior = (kk > 0) & (kk < p - 1)
    o_ref[...] = run_sweeps(u, interior, w, plan, sweeps).astype(o_ref.dtype)
