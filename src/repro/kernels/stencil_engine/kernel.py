"""The single Pallas kernel body behind every engine stencil.

One body serves 3-, 7-, 27-point and arbitrary radius-1 masks: the spec is
first compiled to a :class:`~.plan.StencilPlan` (the paper's synthesis step
-- a factored partial-sum schedule for symmetric specs, a CSE'd shift
schedule for arbitrary masks, a naive ``direct`` escape hatch) and the plan
is unrolled at trace time.  Neighbour access is by static slice + zero pad
on the resident block (:func:`~.plan.shift_slice`), never a wrap-around
roll, so no out-of-domain values are computed then masked.

The same body fuses ``s`` Jacobi sweeps per grid step: the working block is
widened by ``s`` halo rows (and, when j-tiled, ``s`` halo columns) read from
the neighbour blocks, the sweep loop runs register/VMEM-resident, and only
the central rows are written back -- one HBM round-trip for ``s``
applications of the operator, the Pallas analogue of the paper's
register-resident steady-state stream.  Global geometry (row offset, global
M) arrives as a small int32 operand so the same kernel runs unsharded
(offset 0) and as the per-shard body of the halo-exchange ``shard_map``
path.  When ``bj`` is set the grid gains a j dimension and each step sees a
``(bi + 2s, bj + 2s, P)`` working block assembled from the 3x3 neighbour
tiles -- grids whose full N x P slab exceeds the VMEM budget run anyway.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .plan import StencilPlan, execute_plan


def acc_dtype_for(dtype) -> jnp.dtype:
    """bf16/f32 accumulate in f32; the f64 reference path stays f64."""
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def stencil3d_kernel(*refs, plan: StencilPlan, bi: int, bj: Optional[int],
                     n_global: int, sweeps: int, acc_dtype):
    """Fused-sweep volumetric kernel.

    ``refs`` is ``(*blocks, geom_ref, w_ref, o_ref)`` where ``blocks`` holds
    the 3 i-neighbour views (untiled, blocks ``(1, bi, N, P)``) or the 3x3
    i/j-neighbour views in row-major ``(di, dj)`` order (j-tiled, blocks
    ``(1, bi, bj, P)``).  ``geom_ref`` = (global row of this array's row 0,
    global M) -- both 0 and the local M for the single-device path;
    shard-dependent under shard_map.
    """
    o_ref = refs[-1]
    geom_ref, w_ref = refs[-3], refs[-2]
    blocks = refs[:-3]
    i_blk = pl.program_id(1)
    s = sweeps
    w = w_ref[...]
    if bj is None:
        prev, cur, nxt = (r[0] for r in blocks)            # (bi, N, P)
        u = jnp.concatenate([prev[-s:], cur, nxt[:s]],
                            axis=0).astype(acc_dtype)
    else:
        j_blk = pl.program_id(2)
        strips = []
        for ii in range(3):
            row = [blocks[3 * ii + 0][0][:, -s:],
                   blocks[3 * ii + 1][0],
                   blocks[3 * ii + 2][0][:, :s]]
            strip = jnp.concatenate(row, axis=1)           # (bi, bj + 2s, P)
            strips.append(strip[-s:] if ii == 0
                          else (strip if ii == 1 else strip[:s]))
        u = jnp.concatenate(strips, axis=0).astype(acc_dtype)
    ext = u.shape
    n, p = ext[-2], ext[-1]
    gi = (geom_ref[0] + i_blk * bi - s
          + jax.lax.broadcasted_iota(jnp.int32, ext, 0))
    jj = jax.lax.broadcasted_iota(jnp.int32, ext, 1)
    if bj is not None:
        jj = j_blk * bj - s + jj                            # global j index
    kk = jax.lax.broadcasted_iota(jnp.int32, ext, 2)
    interior = ((gi > 0) & (gi < geom_ref[1] - 1)
                & (jj > 0) & (jj < n_global - 1) & (kk > 0) & (kk < p - 1))
    # Jacobi sweeps, Dirichlet boundary re-zeroed after each; the valid
    # region shrinks one row/column per sweep from the extended edges, so
    # the central block is exact after s sweeps (requires s <= bi, bj).
    for _ in range(s):
        u = jnp.where(interior, execute_plan(plan, u, w), 0)
    out = u[s:s + bi] if bj is None else u[s:s + bi, s:s + bj]
    o_ref[0] = out.astype(o_ref.dtype)


def stencil1d_kernel(a_ref, w_ref, o_ref, *, plan: StencilPlan, sweeps: int,
                     acc_dtype):
    """k-only kernel over ``(block_rows, P)`` blocks; rows are independent,
    so fused sweeps need no halo at all."""
    u = a_ref[...].astype(acc_dtype)
    w = w_ref[...]
    p = u.shape[-1]
    kk = jax.lax.broadcasted_iota(jnp.int32, u.shape, u.ndim - 1)
    interior = (kk > 0) & (kk < p - 1)
    for _ in range(sweeps):
        u = jnp.where(interior, execute_plan(plan, u, w), 0)
    o_ref[...] = u.astype(o_ref.dtype)
