"""The Pallas kernel bodies behind every engine stencil.

One compute core serves 3-, 7-, 27-point, the radius-2 star13/box125, and
arbitrary radius-R masks: the spec is first compiled to a
:class:`~.plan.StencilPlan` (the paper's synthesis step, now an explicit
pass pipeline -- a factored partial-sum schedule for symmetric specs, a
CSE'd shift schedule for arbitrary masks, a naive ``direct`` escape hatch,
all re-sequenced by the liveness-ordering pass) and the plan is unrolled at
trace time.  Neighbour access is by static slice + zero pad on the resident
block (:func:`~.plan.shift_slice`), never a wrap-around roll, so no
out-of-domain values are computed then masked.

Two volumetric bodies share that core; all geometry below is per-axis
radius-aware with halo widths ``h = radius * sweeps``:

``stencil3d_kernel`` (the *replicated* path, parity escape hatch)
    The input is passed ``2*ri + 1`` times (untiled) or ``(2*ri + 1) *
    (2*rj + 1)`` times (j-tiled) under block-shifted (clamped) index maps,
    so each grid step re-fetches its halo neighbours from HBM.  Simple,
    stateless, and kept as the ``path="replicate"`` reference.

``stencil3d_stream_kernel`` (the *streaming* path, default)
    The paper's central optimization (sect. 3-4): stream along the i axis
    and keep the active planes resident so each loaded plane is reused by
    every output plane that needs it, instead of being re-fetched.  A single
    input operand walks i-blocks in order on a grid with one extra step; a
    VMEM ``scratch_shapes`` buffer carries a rotating window of ``bi + h``
    input planes (the previous block plus the ``h = ri * sweeps``-deep halo
    tail of the block before it) across grid steps.  Step ``t`` computes
    output block ``t - 1`` from ``[scratch | head h planes of block t]`` and
    then rotates the window -- so every input plane is fetched from HBM
    exactly once per call and written once: ~2 transfers per point, the
    paper's register-resident ideal (VMEM standing in for the register
    file).

Both bodies fuse ``s`` Jacobi sweeps per grid step: the working strip is
``h`` halo planes wider than the output block per side, the sweep loop runs
VMEM-resident via :func:`run_sweeps` (interior mask and zero fill built
once, not per unrolled sweep), and only the central planes are written back
-- one HBM round-trip for ``s`` applications of the operator.  At radius
>= 2, clamped neighbour views can place *duplicated* edge data where the
out-of-domain zero halo belongs and interior points genuinely read those
positions, so the assembled strip is explicitly zeroed outside the global
domain (:func:`zero_outside_domain`; a no-op at radius 1, where clamp
garbage only ever feeds Dirichlet-masked rows).  Global geometry (row
offset, global M) arrives as a small int32 operand so the same bodies run
unsharded (offset 0) and as the per-shard body of the halo-exchange
``shard_map`` path.  When ``bj`` is set the grid gains a j dimension: the
replicated body sees the ``(2ri+1) x (2rj+1)`` neighbour tiles; the
streaming body streams i within each j-tile (``2rj + 1`` j-neighbour views,
so planes are fetched ``2rj + 1`` times instead of the replicated
``(2ri+1)(2rj+1)`` -- exactly-once needs the full-N strip in scratch, which
is the one regime j-tiling exists to avoid).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .plan import StencilPlan, execute_plan


def acc_dtype_for(dtype) -> jnp.dtype:
    """bf16/f32 accumulate in f32; the f64 reference path stays f64."""
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def run_sweeps(u: jax.Array, interior: jax.Array, w: jax.Array,
               plan: StencilPlan, sweeps: int) -> jax.Array:
    """Fused Jacobi sweep loop with the loop-invariant Dirichlet select
    hoisted: the interior mask *and* the zero fill it selects against are
    materialized once and reused by every unrolled sweep (previously the
    scalar zero was re-broadcast to the full block per sweep).  The valid
    region shrinks ``radius`` planes per sweep from the extended edges, so
    the central block is exact after ``sweeps`` applications under the
    ``h = radius * sweeps`` halo."""
    zero = jnp.zeros(u.shape, u.dtype)
    for _ in range(sweeps):
        u = jnp.where(interior, execute_plan(plan, u, w), zero)
    return u


def _volumetric_interior(ext, gi0, j0, m_ref, n_global: int):
    """Interior (non-Dirichlet) mask of an extended working strip whose
    row 0 sits at global row ``gi0`` and column 0 at global column ``j0``;
    ``m_ref`` is the (traced) global M.  The Dirichlet ring stays one point
    wide at every radius (out-of-domain reads are zeros, matching the
    reference's zero-fill shifts).  Built once per grid step and shared
    across every fused sweep."""
    gi = gi0 + jax.lax.broadcasted_iota(jnp.int32, ext, 0)
    jj = j0 + jax.lax.broadcasted_iota(jnp.int32, ext, 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, ext, 2)
    return ((gi > 0) & (gi < m_ref - 1)
            & (jj > 0) & (jj < n_global - 1)
            & (kk > 0) & (kk < ext[-1] - 1))


def zero_outside_domain(u: jax.Array, gi0, j0, m_ref, n_global: int,
                        radius: Tuple[int, int, int]) -> jax.Array:
    """Zero strip positions outside the global (M, N) domain.

    Clamped neighbour index maps duplicate edge blocks, so strip rows/
    columns beyond the domain hold copies of in-domain data instead of the
    zeros the reference's zero-fill shifts assume.  At radius 1 those
    positions only ever feed rows the Dirichlet mask zeroes (proved by the
    one-plane-per-sweep shrink argument), so this is skipped to keep the
    radius-1 programs byte-identical; at radius >= 2 an interior point at
    distance 1 from the boundary genuinely reads distance-2 neighbours
    across it, so the zeros must be materialized."""
    if radius[0] <= 1 and radius[1] <= 1:
        return u
    gi = gi0 + jax.lax.broadcasted_iota(jnp.int32, u.shape, 0)
    jj = j0 + jax.lax.broadcasted_iota(jnp.int32, u.shape, 1)
    ok = (gi >= 0) & (gi < m_ref) & (jj >= 0) & (jj < n_global)
    return jnp.where(ok, u, jnp.zeros(u.shape, u.dtype))


def _concat_halo(prev, cur, nxt, h: int, axis: int) -> jax.Array:
    """``[tail h of prev | cur | head h of nxt]`` along ``axis`` -- the halo
    slices are taken *before* concatenating so the temporary stays at
    ``block + 2h``, never the full staged neighbourhood.  ``h`` never
    exceeds the block extent (``block >= radius * sweeps`` is validated),
    so the +-1 neighbours always cover the halo; any outer views remain
    staged-but-unread (the replicated path's honest ``2r + 1`` cost)."""
    if h == 0:
        return cur
    src = [slice(None)] * cur.ndim
    src[axis] = slice(-h, None)
    head = [slice(None)] * cur.ndim
    head[axis] = slice(0, h)
    return jnp.concatenate([prev[tuple(src)], cur, nxt[tuple(head)]],
                           axis=axis)


def stencil3d_kernel(*refs, plan: StencilPlan, bi: int, bj: Optional[int],
                     n_global: int, sweeps: int, acc_dtype):
    """Replicated-halo fused-sweep volumetric kernel (``path="replicate"``).

    ``refs`` is ``(*blocks, geom_ref, w_ref, o_ref)`` where ``blocks`` holds
    the ``2ri + 1`` i-neighbour views (untiled, blocks ``(1, bi, N, P)``) or
    the ``(2ri + 1) x (2rj + 1)`` i/j-neighbour views in row-major
    ``(di, dj)`` order (j-tiled, blocks ``(1, bi, bj, P)``).  ``geom_ref`` =
    (global row of this array's row 0, global M) -- both 0 and the local M
    for the single-device path; shard-dependent under shard_map.
    """
    o_ref = refs[-1]
    geom_ref, w_ref = refs[-3], refs[-2]
    blocks = refs[:-3]
    ri, rj, _ = plan.spec.radius
    i_blk = pl.program_id(1)
    s = sweeps
    hi = ri * s
    w = w_ref[...]
    if bj is None:
        prev, cur, nxt = (blocks[ri + d][0] if hi else blocks[ri][0]
                          for d in (-1, 0, 1))
        u = _concat_halo(prev, cur, nxt, hi, 0).astype(acc_dtype)
        j0 = 0
    else:
        hj = rj * s
        j_blk = pl.program_id(2)
        nj = 2 * rj + 1

        def jrow(ii: int) -> jax.Array:
            tiles = [blocks[ii * nj + rj + (d if hj else 0)][0]
                     for d in (-1, 0, 1)]
            return _concat_halo(*tiles, hj, 1)     # (bi, bj + 2hj, P)

        mid = jrow(ri)
        rows = ((jrow(ri - 1), mid, jrow(ri + 1)) if hi
                else (mid, mid, mid))
        u = _concat_halo(*rows, hi, 0).astype(acc_dtype)
        j0 = j_blk * bj - hj
    gi0 = geom_ref[0] + i_blk * bi - hi
    u = zero_outside_domain(u, gi0, j0, geom_ref[1], n_global,
                            plan.spec.radius)
    interior = _volumetric_interior(u.shape, gi0, j0, geom_ref[1], n_global)
    u = run_sweeps(u, interior, w, plan, s)
    out = u[hi:hi + bi] if bj is None else u[hi:hi + bi, hj:hj + bj]
    o_ref[0] = out.astype(o_ref.dtype)


def stencil3d_stream_kernel(*refs, plan: StencilPlan, bi: int,
                            bj: Optional[int], n_global: int, sweeps: int,
                            acc_dtype):
    """Plane-streaming fused-sweep volumetric kernel (``path="stream"``).

    ``refs`` is ``(*views, geom_ref, w_ref, o_ref, scr_ref)``.  Untiled
    (``bj is None``): ``views`` is one identity-mapped block ``(1, bi, N,
    P)`` and the grid's trailing dim runs ``nbi + 1`` steps; j-tiled:
    ``views`` are the ``2rj + 1`` j-neighbour tiles ``(1, bi, bj, P)`` and
    the grid is ``(B, nbj, nbi + 1)`` with i innermost, so the stream
    restarts per j-tile.  ``scr_ref`` is VMEM scratch of ``bi + h`` input
    planes (``h = ri * sweeps``) carried across grid steps: planes
    ``[0, h)`` are the tail of block ``t - 2`` (zeros above the domain),
    planes ``[h, h + bi)`` are block ``t - 1``.

    Step 0 primes the window; step ``t >= 1`` assembles the working strip
    ``[scratch | head h planes of block t]`` (at ``t == nbi`` the clamped
    index map re-presents block ``nbi - 1``, whose planes land only at
    ``gi >= M`` where the domain zeroing / interior mask kills them -- and
    an unchanged block index costs no DMA under Pallas revisiting
    semantics), runs the fused sweeps, writes output block ``t - 1`` via
    the lagged output index map, and rotates the window.  Net HBM traffic:
    each input plane read once, each output plane written once.
    """
    o_ref, scr_ref = refs[-2], refs[-1]
    geom_ref, w_ref = refs[-4], refs[-3]
    views = refs[:-4]
    ri, rj, _ = plan.spec.radius
    s = sweeps
    hi = ri * s
    w = w_ref[...]
    if bj is None:
        t = pl.program_id(1)
        cur = views[0][0]                                  # (bi, N, P)
        j0 = 0
    else:
        hj = rj * s
        t = pl.program_id(2)
        j_blk = pl.program_id(1)
        jm, jc, jp = (views[rj + d][0] if hj else views[rj][0]
                      for d in (-1, 0, 1))
        cur = _concat_halo(jm, jc, jp, hj, 1)              # (bi, bj+2hj, P)
        j0 = j_blk * bj - hj

    @pl.when(t == 0)
    def _prime():
        # Window for output block 0: block "-1" is above the domain (zeros;
        # they only ever feed rows the interior mask zeroes), block 0 = cur.
        if hi:
            scr_ref[:hi] = jnp.zeros((hi,) + cur.shape[1:], cur.dtype)
        scr_ref[hi:] = cur

    @pl.when(t > 0)
    def _compute():
        u = (jnp.concatenate([scr_ref[...], cur[:hi]], axis=0) if hi
             else scr_ref[...]).astype(acc_dtype)          # (bi + 2hi, ., P)
        gi0 = geom_ref[0] + (t - 1) * bi - hi
        u = zero_outside_domain(u, gi0, j0, geom_ref[1], n_global,
                                plan.spec.radius)
        interior = _volumetric_interior(u.shape, gi0, j0, geom_ref[1],
                                        n_global)
        u = run_sweeps(u, interior, w, plan, s)
        out = u[hi:hi + bi] if bj is None else u[hi:hi + bi, hj:hj + bj]
        o_ref[0] = out.astype(o_ref.dtype)
        # Rotate the window: new tail = last hi planes of block t - 1.
        if hi:
            tail = scr_ref[bi:bi + hi]
            scr_ref[:hi] = tail
        scr_ref[hi:] = cur


def stencil1d_kernel(a_ref, w_ref, o_ref, *, plan: StencilPlan, sweeps: int,
                     acc_dtype):
    """k-only kernel over ``(block_rows, P)`` blocks; rows are independent,
    so fused sweeps need no halo at all (shift zero-fill covers any k
    radius)."""
    u = a_ref[...].astype(acc_dtype)
    w = w_ref[...]
    p = u.shape[-1]
    kk = jax.lax.broadcasted_iota(jnp.int32, u.shape, u.ndim - 1)
    interior = (kk > 0) & (kk < p - 1)
    o_ref[...] = run_sweeps(u, interior, w, plan, sweeps).astype(o_ref.dtype)
