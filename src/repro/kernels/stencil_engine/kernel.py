"""The Pallas kernel bodies behind every engine stencil.

One compute core serves 3-, 7-, 27-point, the radius-2 star13/box125, and
arbitrary radius-R masks: the spec is first compiled to a
:class:`~.plan.StencilPlan` (the paper's synthesis step, now an explicit
pass pipeline -- a factored partial-sum schedule for symmetric specs, a
CSE'd shift schedule for arbitrary masks, a naive ``direct`` escape hatch,
all re-sequenced by the liveness-ordering pass) and the plan is unrolled at
trace time.  Neighbour access is by static slice + zero pad on the resident
block (:func:`~.plan.shift_slice`), never a wrap-around roll, so no
out-of-domain values are computed then masked.

Two volumetric bodies share that core; all geometry below is per-axis
radius-aware with halo widths ``h = radius * sweeps``:

``stencil3d_kernel`` (the *replicated* path, parity escape hatch)
    The input is passed ``2*ri + 1`` times (untiled) or ``(2*ri + 1) *
    (2*rj + 1)`` times (j-tiled) under block-shifted (clamped) index maps,
    so each grid step re-fetches its halo neighbours from HBM.  Simple,
    stateless, and kept as the ``path="replicate"`` reference.

``stencil3d_stream_kernel`` (the *streaming* path, default)
    The paper's central optimization (sect. 3-4): stream along the i axis
    and keep the active planes resident so each loaded plane is reused by
    every output plane that needs it, instead of being re-fetched.  A single
    input operand walks i-blocks in order on a grid with one extra step; a
    VMEM ``scratch_shapes`` buffer carries a rotating window of ``bi + h``
    input planes (the previous block plus the ``h = ri * sweeps``-deep halo
    tail of the block before it) across grid steps.  Step ``t`` computes
    output block ``t - 1`` from ``[scratch | head h planes of block t]`` and
    then rotates the window -- so every input plane is fetched from HBM
    exactly once per call and written once: ~2 transfers per point, the
    paper's register-resident ideal (VMEM standing in for the register
    file).

Both bodies fuse ``s`` Jacobi sweeps per grid step: the working strip is
``h`` halo planes wider than the output block per side, the sweep loop runs
VMEM-resident via :func:`run_sweeps` (interior mask and zero fill built
once, not per unrolled sweep), and only the central planes are written back
-- one HBM round-trip for ``s`` applications of the operator.

Boundary conditions are a per-axis-side property of the spec
(:class:`~.spec.BC`) and are realized in three places, chosen per axis by
where that axis's ghost cells live (:func:`prepare_strip` wires all of it):

* **halo axes** (i always; j when tiled): the assembled strip's
  out-of-domain positions are *filled* (:func:`fill_ghosts`) -- zeros for
  clamp (pre-sweep only; the ring mask covers later sweeps, and the
  all-clamp default keeps the exact legacy :func:`zero_outside_domain` /
  ring-mask graphs), the constant for dirichlet, a symmetric mirror gather
  for neumann (re-applied after every fused sweep, the kernel form of the
  reference's per-sweep ``np.pad``); a periodic i axis instead *wraps* --
  block index maps reach around the domain and the streaming window gains
  a lead-in step (see ``stencil3d_stream_kernel``), after which the strip
  is contiguous in the periodic metric and needs no refill at all;
* **domain-resident axes** (k always; j untiled): the BC lives in the
  shift primitive's fill (:func:`~.plan.shift_slice_bc`);
* **dirichlet values** ride the linearity identity ``stencil(u) =
  stencil(u - v) + v * sum(w)`` (see :func:`run_sweeps`), since a constant
  fill inside a shift would be wrong for shifted partial sums.

Global geometry (row offset, global M) arrives as a small int32 operand so
the same bodies run unsharded (offset 0) and as the per-shard body of the
halo-exchange ``shard_map`` path -- which is also what makes dirichlet /
neumann ghosts materialize only on the boundary shards.  When ``bj`` is set the grid gains a j dimension: the
replicated body sees the ``(2ri+1) x (2rj+1)`` neighbour tiles; the
streaming body streams i within each j-tile (``2rj + 1`` j-neighbour views,
so planes are fetched ``2rj + 1`` times instead of the replicated
``(2ri+1)(2rj+1)`` -- exactly-once needs the full-N strip in scratch, which
is the one regime j-tiling exists to avoid).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .plan import StencilPlan, execute_plan, shift_slice, shift_slice_bc
from .spec import Boundary


@dataclasses.dataclass(frozen=True)
class KernelFault:
    """A static, hashable in-kernel fault descriptor (fault injection).

    Threaded through the jitted entry points as a static argument (default
    ``None`` -- the traced program is byte-identical to the historical one)
    and realized inside the kernel body, so the fault genuinely lives in
    compiled/interpreted kernel state rather than being patched onto the
    output afterwards.  ``kind="nan_scratch"`` poisons one in-domain plane
    of the stream kernel's rotating VMEM scratch window at prime time
    (``plane`` is taken modulo the block height).  Only :mod:`.faults`
    constructs these."""

    kind: str = "nan_scratch"
    plane: int = 0


def acc_dtype_for(dtype) -> jnp.dtype:
    """bf16/f32 accumulate in f32; the f64 reference path stays f64."""
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def bc_all_clamp(bc: Boundary) -> bool:
    return all(s.kind == "clamp" for ax in bc for s in ax)


def make_shift(bc: Boundary, j_in_shift: bool,
               k_in_shift: bool = True) -> Callable:
    """The plan executor's shift primitive for this BC configuration.

    Axes whose strip extent *is* the domain extent (k unless its halo is
    externally materialized; j on untiled volumetric blocks / the 1-D path)
    realize their BC inside the shift fill (:func:`~.plan.shift_slice_bc`);
    halo axes keep zero fill -- their BC is realized by :func:`fill_ghosts`
    on the assembled strip.  ``k_in_shift=False`` (a k-sharded slab whose
    ghost planes arrived by exchange) moves k to the fill side too.
    All-clamp configurations keep the exact legacy
    :func:`~.plan.shift_slice` (same traced graph, byte-identical
    programs)."""
    bc_axes = (False, j_in_shift, k_in_shift)
    if all(bc[ax][side].kind in ("clamp", "dirichlet")
           for ax in (1, 2) if bc_axes[ax] for side in (0, 1)):
        return shift_slice          # dirichlet ghosts are zero-fill too
    return lambda t, off: shift_slice_bc(t, off, bc, bc_axes)


def ghost_offset(bc: Boundary) -> float:
    """The shared dirichlet ghost value (0.0 when no side is dirichlet --
    the single-value-per-spec rule is validated at spec construction)."""
    for ax in bc:
        for s in ax:
            if s.kind == "dirichlet":
                return s.value
    return 0.0


def run_sweeps(u: jax.Array, interior: Optional[jax.Array], w: jax.Array,
               plan: StencilPlan, sweeps: int, shift: Callable = shift_slice,
               refill: Optional[Callable] = None,
               parity: Optional[jax.Array] = None) -> jax.Array:
    """Fused Jacobi sweep loop with the loop-invariant clamp-ring select
    hoisted: the interior mask *and* the zero fill it selects against are
    materialized once and reused by every unrolled sweep.  ``interior`` is
    the clamp-side ring mask (``None`` when no side is clamp), ``shift``
    carries the in-shift BCs of the domain-resident axes, and ``refill``
    (when the halo axes carry dirichlet/neumann sides) re-fills the
    out-of-domain ghost strip after every application -- the fused-kernel
    form of the reference's per-sweep ``np.pad``.

    A dirichlet ghost value ``v != 0`` is realized by linearity: the plan
    runs on the offset field ``u - v`` (whose dirichlet ghosts are exactly
    the shifts' zero fill) and ``v * sum(w)`` is added back -- a constant
    fill inside the shifts would be wrong for intermediate partial sums.
    The correction is elementwise: on a variable-coefficient spec ``w[k]``
    is a strip-shaped coefficient plane stack and ``v * sum(w)`` a field.
    A red-black (Gauss-Seidel) spec supplies ``parity`` -- the *global*
    checkerboard ``(i + j + k) % 2 == 0`` of the strip (built once in
    :func:`prepare_strip`) -- and every sweep becomes two masked
    half-applications: the operator is applied and merged at the red
    parity first, then at the black parity reading the red-updated field.
    Information therefore propagates ``2 * radius`` planes per sweep, and
    the halo depth is ``radius * sweeps * spec.sweep_apps``.

    The valid region shrinks ``radius`` planes per application from the
    extended edges, so the central block is exact after ``sweeps``
    applications under the ``h = radius * sweeps * sweep_apps`` halo."""
    zero = None if interior is None else jnp.zeros(u.shape, u.dtype)
    v = ghost_offset(plan.spec.bc)
    off = corr = None
    if v != 0.0:
        off = jnp.asarray(v, u.dtype)
        counts: dict = {}
        for k in plan.spec.w_index:          # static multiplicity per weight
            counts[k] = counts.get(k, 0) + 1
        sumw = sum((w[k] * c for k, c in sorted(counts.items())),
                   jnp.zeros((), u.dtype))
        corr = off * sumw

    def apply_once(x):
        if off is None:
            x = execute_plan(plan, x, w, shift=shift)
        else:
            x = execute_plan(plan, x - off, w, shift=shift) + corr
        if interior is not None:
            x = jnp.where(interior, x, zero)
        return x

    halves = None if parity is None else (parity, ~parity)
    for _ in range(sweeps):
        if halves is None:
            u = apply_once(u)
            if refill is not None:
                u = refill(u)
        else:
            for half in halves:
                u = jnp.where(half, apply_once(u), u)
                if refill is not None:
                    u = refill(u)
    return u


def _volumetric_interior(ext, gi0, j0, m_ref, n_global: int, k0=0,
                         p_top=None):
    """Interior (non-clamp-ring) mask of an extended working strip whose
    row 0 sits at global row ``gi0`` and column 0 at global column ``j0``;
    ``m_ref`` is the (traced) global M.  ``k0``/``p_top`` generalize the
    k axis for k-sharded slabs (default: local k *is* global k).  The
    clamp ring stays one point wide at every radius (out-of-domain reads
    are zeros, matching the reference's zero-fill shifts).  Built once per
    grid step and shared across every fused sweep."""
    if p_top is None:
        p_top = ext[-1]
    gi = gi0 + jax.lax.broadcasted_iota(jnp.int32, ext, 0)
    jj = j0 + jax.lax.broadcasted_iota(jnp.int32, ext, 1)
    kk = k0 + jax.lax.broadcasted_iota(jnp.int32, ext, 2)
    return ((gi > 0) & (gi < m_ref - 1)
            & (jj > 0) & (jj < n_global - 1)
            & (kk > 0) & (kk < p_top - 1))


def _clamp_interior(ext, gi0, j0, m_ref, n_global: int, bc: Boundary,
                    k0=0, p_top=None):
    """Per-side generalization of :func:`_volumetric_interior`: one ring
    constraint per *clamp* side (other BCs apply the operator everywhere and
    realize their ghosts by fill/wrap instead).  ``None`` when no side is
    clamp -- the per-sweep select is skipped entirely."""
    if p_top is None:
        p_top = ext[-1]
    coords = {}

    def coord(axis):
        if axis not in coords:
            base = (gi0, j0, k0)[axis]
            coords[axis] = base + jax.lax.broadcasted_iota(jnp.int32, ext,
                                                           axis)
        return coords[axis]

    tops = (m_ref, n_global, p_top)
    mask = None
    for axis in range(3):
        lo, hi = bc[axis]
        if lo.kind == "clamp":
            t = coord(axis) > 0
            mask = t if mask is None else mask & t
        if hi.kind == "clamp":
            t = coord(axis) < tops[axis] - 1
            mask = t if mask is None else mask & t
    return mask


def _fill_axis(u: jax.Array, axis: int, c0, top, lo, hi,
               include_clamp: bool) -> jax.Array:
    """Fill the out-of-domain positions along one halo axis of the strip:
    ``c0`` is the global coordinate of index 0 and ``top`` the (possibly
    traced) domain extent.  neumann gathers the symmetric mirror of the
    in-domain data (``ghost[-1-q] = u[q]``; the mirror source is always
    resident -- ``block >= radius * sweeps`` is validated); dirichlet is a
    constant select; clamp zeros are applied only pre-sweep
    (``include_clamp`` -- the per-sweep ring mask covers them after every
    application); periodic leaves the strip alone (its halo already holds
    wrapped data)."""
    n_ax = u.shape[axis]
    ii = jax.lax.broadcasted_iota(jnp.int32, (n_ax,), 0)
    g = c0 + ii

    def on_axis(vec):
        return jnp.expand_dims(vec, tuple(a for a in range(u.ndim)
                                          if a != axis))

    if lo.kind == "neumann" or hi.kind == "neumann":
        src = g
        mask = None
        if lo.kind == "neumann":
            src = jnp.where(g < 0, -1 - g, src)
            mask = g < 0
        if hi.kind == "neumann":
            src = jnp.where(g >= top, 2 * top - 1 - g, src)
            m = g >= top
            mask = m if mask is None else mask | m
        local = jnp.clip(src - c0, 0, n_ax - 1)
        u = jnp.where(on_axis(mask), jnp.take(u, local, axis=axis), u)
    for side, oob in ((lo, g < 0), (hi, g >= top)):
        if side.kind == "dirichlet":
            u = jnp.where(on_axis(oob), jnp.asarray(side.value, u.dtype), u)
        elif side.kind == "clamp" and include_clamp:
            u = jnp.where(on_axis(oob), jnp.zeros((), u.dtype), u)
    return u


def fill_ghosts(u: jax.Array, gi0, j0, m_ref, n_global: int, bc: Boundary,
                fill_j: bool, include_clamp: bool, k0=0, p_top=None,
                fill_k: bool = False) -> jax.Array:
    """Realize the halo axes' BCs on an assembled working strip: axis i
    always (its halo is staged/streamed), axis j only when tiled or its
    halo arrived by exchange (untiled single-device strips span the full
    N, so j is an in-shift axis), axis k only when its halo arrived by
    exchange (``fill_k``).  i is filled before j before k, so at ghost
    corners the later axis wins -- the same corner convention as the
    reference's sequential ``np.pad`` (i, then j, then k)."""
    u = _fill_axis(u, u.ndim - 3, gi0, m_ref, *bc[0], include_clamp)
    if fill_j:
        u = _fill_axis(u, u.ndim - 2, j0, n_global, *bc[1], include_clamp)
    if fill_k:
        u = _fill_axis(u, u.ndim - 1, k0, p_top, *bc[2], include_clamp)
    return u


def _needs_refill(bc: Boundary, fill_j: bool, fill_k: bool = False) -> bool:
    axes = (0,) + ((1,) if fill_j else ()) + ((2,) if fill_k else ())
    return any(bc[ax][side].kind in ("dirichlet", "neumann")
               for ax in axes for side in (0, 1))


def _strip_parity(ext, gi0, j0, k0=0) -> jax.Array:
    """Global checkerboard parity ``(i + j + k) % 2 == 0`` ("red") of a
    volumetric working strip whose row 0 sits at global row ``gi0``,
    column 0 at global column ``j0``, and lane 0 at global lane ``k0``
    (0 unless the k axis is sharded -- local k is then global k).  Built
    once per grid step and shared by both half-applications of every
    red-black sweep."""
    gi = gi0 + jax.lax.broadcasted_iota(jnp.int32, ext, 0)
    jj = j0 + jax.lax.broadcasted_iota(jnp.int32, ext, 1)
    kk = k0 + jax.lax.broadcasted_iota(jnp.int32, ext, 2)
    return ((gi + jj + kk) % 2) == 0


def prepare_strip(u: jax.Array, gi0, j0, m_ref, n_global: int,
                  plan: StencilPlan, tiled_j: bool, k0=0, p_top=None,
                  fill_k: bool = False):
    """Shared BC set-up for the volumetric kernel bodies: fill the assembled
    strip's out-of-domain ghosts, and return the per-sweep machinery
    ``(u, interior, shift, refill, parity)`` for :func:`run_sweeps`
    (``parity`` is the global red checkerboard for red-black specs, else
    ``None``).  ``k0``/``p_top``/``fill_k`` describe a k axis whose ghost
    planes were materialized externally (the k-sharded exchange): k then
    leaves the shift primitive and its BC is realized by fill at *global*
    k coordinates, exactly like a tiled j.  All-clamp specs take the exact
    legacy path (zero fill at radius >= 2 only, the ring mask, plain
    zero-fill shifts) so default-BC programs stay byte-identical."""
    bc = plan.spec.bc
    parity = (_strip_parity(u.shape, gi0, j0, k0)
              if plan.spec.ordering == "redblack" else None)
    if bc_all_clamp(bc):
        u = zero_outside_domain(u, gi0, j0, m_ref, n_global,
                                plan.spec.radius, k0, p_top, fill_k)
        return (u, _volumetric_interior(u.shape, gi0, j0, m_ref, n_global,
                                        k0, p_top),
                shift_slice, None, parity)
    u = fill_ghosts(u, gi0, j0, m_ref, n_global, bc, fill_j=tiled_j,
                    include_clamp=True, k0=k0, p_top=p_top, fill_k=fill_k)
    interior = _clamp_interior(u.shape, gi0, j0, m_ref, n_global, bc,
                               k0, p_top)
    shift = make_shift(bc, j_in_shift=not tiled_j, k_in_shift=not fill_k)
    refill = None
    if _needs_refill(bc, fill_j=tiled_j, fill_k=fill_k):
        def refill(v):
            return fill_ghosts(v, gi0, j0, m_ref, n_global, bc,
                               fill_j=tiled_j, include_clamp=False,
                               k0=k0, p_top=p_top, fill_k=fill_k)
    return u, interior, shift, refill, parity


def zero_outside_domain(u: jax.Array, gi0, j0, m_ref, n_global: int,
                        radius: Tuple[int, int, int], k0=0, p_top=None,
                        zero_k: bool = False) -> jax.Array:
    """Zero strip positions outside the global (M, N[, P]) domain.

    Clamped neighbour index maps duplicate edge blocks, so strip rows/
    columns beyond the domain hold copies of in-domain data instead of the
    zeros the reference's zero-fill shifts assume.  At radius 1 those
    positions only ever feed rows the Dirichlet mask zeroes (proved by the
    one-plane-per-sweep shrink argument), so this is skipped to keep the
    radius-1 programs byte-identical; at radius >= 2 an interior point at
    distance 1 from the boundary genuinely reads distance-2 neighbours
    across it, so the zeros must be materialized.  ``zero_k`` extends the
    check to a k axis with externally materialized ghosts (a chain-edge
    exchange already delivers genuine zeros there, so this is defensive)."""
    if (radius[0] <= 1 and radius[1] <= 1
            and (not zero_k or radius[2] <= 1)):
        return u
    gi = gi0 + jax.lax.broadcasted_iota(jnp.int32, u.shape, 0)
    jj = j0 + jax.lax.broadcasted_iota(jnp.int32, u.shape, 1)
    ok = (gi >= 0) & (gi < m_ref) & (jj >= 0) & (jj < n_global)
    if zero_k:
        kk = k0 + jax.lax.broadcasted_iota(jnp.int32, u.shape, 2)
        ok = ok & (kk >= 0) & (kk < p_top)
    return jnp.where(ok, u, jnp.zeros(u.shape, u.dtype))


def _concat_halo(prev, cur, nxt, h: int, axis: int) -> jax.Array:
    """``[tail h of prev | cur | head h of nxt]`` along ``axis`` -- the halo
    slices are taken *before* concatenating so the temporary stays at
    ``block + 2h``, never the full staged neighbourhood.  ``h`` never
    exceeds the block extent (``block >= radius * sweeps`` is validated),
    so the +-1 neighbours always cover the halo; any outer views remain
    staged-but-unread (the replicated path's honest ``2r + 1`` cost)."""
    if h == 0:
        return cur
    src = [slice(None)] * cur.ndim
    src[axis] = slice(-h, None)
    head = [slice(None)] * cur.ndim
    head[axis] = slice(0, h)
    return jnp.concatenate([prev[tuple(src)], cur, nxt[tuple(head)]],
                           axis=axis)


def _assemble_strip(tiles, ri: int, rj: int, hi: int, hj: int,
                    bj: Optional[int], ax: int) -> jax.Array:
    """Build the halo-extended working strip from staged neighbour tiles.

    ``tiles`` is the flat ``2ri + 1`` (untiled) or row-major ``(2ri + 1) x
    (2rj + 1)`` (j-tiled) view list with block axes already stripped; ``ax``
    is the position of the i axis within each tile (0 for the field, 1 for
    a coefficient stack with its leading weight axis)."""
    if bj is None:
        prev, cur, nxt = (tiles[ri + d] if hi else tiles[ri]
                          for d in (-1, 0, 1))
        return _concat_halo(prev, cur, nxt, hi, ax)
    nj = 2 * rj + 1

    def jrow(ii: int) -> jax.Array:
        row = [tiles[ii * nj + rj + (d if hj else 0)] for d in (-1, 0, 1)]
        return _concat_halo(*row, hj, ax + 1)

    mid = jrow(ri)
    rows = (jrow(ri - 1), mid, jrow(ri + 1)) if hi else (mid, mid, mid)
    return _concat_halo(*rows, hi, ax)


def stencil3d_kernel(*refs, plan: StencilPlan, bi: int, bj: Optional[int],
                     n_global: int, sweeps: int, acc_dtype,
                     ext_j: bool = False, ext_k: bool = False,
                     p_global: Optional[int] = None):
    """Replicated-halo fused-sweep volumetric kernel (``path="replicate"``).

    ``refs`` is ``(*blocks, geom_ref, w_ref, o_ref)`` where ``blocks`` holds
    the ``2ri + 1`` i-neighbour views (untiled, blocks ``(1, bi, N, P)``) or
    the ``(2ri + 1) x (2rj + 1)`` i/j-neighbour views in row-major
    ``(di, dj)`` order (j-tiled, blocks ``(1, bi, bj, P)``).  ``geom_ref`` =
    (global row of this array's row 0, global M) -- both 0 and the local M
    for the single-device path; shard-dependent under shard_map.  A
    multi-axis-sharded slab extends ``geom_ref`` with the global j/k
    coordinates of its column/lane 0 (``ext_j``/``ext_k`` mark those axes'
    ghosts as externally materialized; ``p_global`` is then the global P).

    Variable-coefficient specs replace the single resident ``w_ref`` with a
    full parallel set of coefficient views (``refs`` becomes ``(*blocks,
    geom_ref, *wblocks, o_ref)``, blocks ``(n_weights, bi, ., P)`` under the
    same index maps), and the coefficient strip is assembled exactly like the
    field strip -- coefficients are evaluated at the *output* point, so every
    in-domain strip position sees its true coefficients; out-of-domain
    positions only feed outputs the ghost fill / interior mask overwrites.
    """
    var = plan.spec.coef == "var"
    o_ref = refs[-1]
    if var:
        nv = (len(refs) - 2) // 2
        blocks, geom_ref, wblocks = refs[:nv], refs[nv], refs[nv + 1:-1]
    else:
        geom_ref, w_ref = refs[-3], refs[-2]
        blocks = refs[:-3]
    ri, rj, _ = plan.spec.radius
    i_blk = pl.program_id(1)
    s = sweeps
    apps = plan.spec.sweep_apps
    hi = ri * s * apps
    hj = rj * s * apps
    if bj is None:
        j0 = geom_ref[2] if ext_j else 0
    else:
        j_blk = pl.program_id(2)
        j0 = j_blk * bj - hj
    k0 = geom_ref[3] if ext_k else 0
    u = _assemble_strip([blk[0] for blk in blocks], ri, rj, hi, hj, bj,
                        0).astype(acc_dtype)
    if var:
        w = _assemble_strip([wb[...] for wb in wblocks], ri, rj, hi, hj,
                            bj, 1)
    else:
        w = w_ref[...]
    gi0 = geom_ref[0] + i_blk * bi - hi
    u, interior, shift, refill, parity = prepare_strip(
        u, gi0, j0, geom_ref[1], n_global, plan, bj is not None or ext_j,
        k0=k0, p_top=p_global if ext_k else None, fill_k=ext_k)
    u = run_sweeps(u, interior, w, plan, s, shift=shift, refill=refill,
                   parity=parity)
    out = u[hi:hi + bi] if bj is None else u[hi:hi + bi, hj:hj + bj]
    o_ref[0] = out.astype(o_ref.dtype)


def stencil3d_stream_kernel(*refs, plan: StencilPlan, bi: int,
                            bj: Optional[int], n_global: int, sweeps: int,
                            acc_dtype, wrap_i: bool = False,
                            fault: Optional[KernelFault] = None,
                            ext_j: bool = False, ext_k: bool = False,
                            p_global: Optional[int] = None):
    """Plane-streaming fused-sweep volumetric kernel (``path="stream"``).

    ``refs`` is ``(*views, geom_ref, w_ref, o_ref, scr_ref)``.  Untiled
    (``bj is None``): ``views`` is one identity-mapped block ``(1, bi, N,
    P)`` and the grid's trailing dim runs ``nbi + 1`` steps; j-tiled:
    ``views`` are the ``2rj + 1`` j-neighbour tiles ``(1, bi, bj, P)`` and
    the grid is ``(B, nbj, nbi + 1)`` with i innermost, so the stream
    restarts per j-tile.  ``scr_ref`` is VMEM scratch of ``bi + h`` input
    planes (``h = ri * sweeps``) carried across grid steps: planes
    ``[0, h)`` are the tail of the block before the previous one (zeros
    above the domain), planes ``[h, h + bi)`` are the previous block.

    Step 0 primes the window; step ``t >= 1`` assembles the working strip
    ``[scratch | head h planes of block t]`` (at ``t == nbi`` the clamped
    index map re-presents block ``nbi - 1``, whose planes land only at
    ``gi >= M`` where the domain zeroing / interior mask kills them -- and
    an unchanged block index costs no DMA under Pallas revisiting
    semantics), runs the fused sweeps, writes output block ``t - 1`` via
    the lagged output index map, and rotates the window.  Net HBM traffic:
    each input plane read once, each output plane written once.

    ``wrap_i=True`` (the i axis is periodic, realized here rather than by a
    pre-exchanged shard halo): the stream gains one more lead-in step and
    walks the *wrapped* block sequence ``nbi-1, 0, 1, ..., nbi-1, 0``
    (``i_src(t) = (t + nbi - 1) % nbi``).  Step 0 stages only the tail
    ``h`` planes of the last block (the ghost rows below global row 0),
    step 1 stages block 0, and step ``t >= 2`` computes output block
    ``t - 2``; the final step re-fetches block 0's head planes for the tail
    of the sweep -- the periodic case's only extra HBM traffic (~2 extra
    block reads per call).

    Variable-coefficient specs co-stream the coefficient planes: ``refs``
    becomes ``(*views, geom_ref, *wviews, o_ref, scr_ref, wscr_ref)`` with
    the coefficient views ``(n_weights, bi, ., P)`` walking the same block
    sequence as the field views, and ``wscr_ref`` a second VMEM rotating
    window ``(n_weights, bi + h, ., P)`` primed and rotated in lockstep with
    ``scr_ref`` -- so coefficient planes, like field planes, are fetched
    from HBM exactly once per call.  Coefficients are evaluated at the
    *output* point; the above-domain lead-in planes are zero-primed and
    only ever feed discarded ghost outputs.
    """
    var = plan.spec.coef == "var"
    if var:
        o_ref, scr_ref, wscr_ref = refs[-3], refs[-2], refs[-1]
        nv = (len(refs) - 4) // 2
        views, geom_ref = refs[:nv], refs[nv]
        wviews = refs[nv + 1:nv + 1 + nv]
    else:
        o_ref, scr_ref = refs[-2], refs[-1]
        geom_ref, w_ref = refs[-4], refs[-3]
        views = refs[:-4]
    ri, rj, _ = plan.spec.radius
    s = sweeps
    apps = plan.spec.sweep_apps
    hi = ri * s * apps
    lag = 2 if wrap_i else 1
    k0 = geom_ref[3] if ext_k else 0
    if bj is None:
        t = pl.program_id(1)
        cur = views[0][0]                                  # (bi, N, P)
        if var:
            wcur = wviews[0][...]                          # (nw, bi, N, P)
        j0 = geom_ref[2] if ext_j else 0
    else:
        hj = rj * s * apps
        t = pl.program_id(2)
        j_blk = pl.program_id(1)
        jm, jc, jp = (views[rj + d][0] if hj else views[rj][0]
                      for d in (-1, 0, 1))
        cur = _concat_halo(jm, jc, jp, hj, 1)              # (bi, bj+2hj, P)
        if var:
            wjm, wjc, wjp = (wviews[rj + d][...] if hj else wviews[rj][...]
                             for d in (-1, 0, 1))
            wcur = _concat_halo(wjm, wjc, wjp, hj, 2)      # (nw, bi, bj+2hj, P)
        j0 = j_blk * bj - hj

    if wrap_i:
        @pl.when(t == 0)
        def _prime_ghost():
            # cur is the *last* block: its tail h planes are the wrapped
            # ghost rows below global row 0.
            scr_ref[:hi] = cur[bi - hi:bi]
            if var:
                wscr_ref[:, :hi] = wcur[:, bi - hi:bi]

        @pl.when(t == 1)
        def _prime_first():
            scr_ref[hi:] = cur                             # block 0
            if var:
                wscr_ref[:, hi:] = wcur
    else:
        @pl.when(t == 0)
        def _prime():
            # Window for output block 0: block "-1" is above the domain
            # (zeros; the strip fill / interior mask handles them), block
            # 0 = cur.
            if hi:
                scr_ref[:hi] = jnp.zeros((hi,) + cur.shape[1:], cur.dtype)
                if var:
                    wscr_ref[:, :hi] = jnp.zeros(
                        wcur.shape[:1] + (hi,) + wcur.shape[2:], wcur.dtype)
            scr_ref[hi:] = cur
            if var:
                wscr_ref[:, hi:] = wcur

    if (fault is not None and fault.kind == "nan_scratch"
            and jnp.issubdtype(jnp.dtype(scr_ref.dtype), jnp.inexact)):
        # Fault injection (tests): poison one in-domain scratch plane right
        # after priming, so the NaN rides the rotating window into the
        # first computed output block.
        @pl.when(t == lag - 1)
        def _inject_fault():
            fp = hi + (fault.plane % bi)
            scr_ref[fp] = jnp.full(scr_ref.shape[1:], jnp.nan, scr_ref.dtype)

    @pl.when(t >= lag)
    def _compute():
        u = (jnp.concatenate([scr_ref[...], cur[:hi]], axis=0) if hi
             else scr_ref[...]).astype(acc_dtype)          # (bi + 2hi, ., P)
        if var:
            w = (jnp.concatenate([wscr_ref[...], wcur[:, :hi]], axis=1)
                 if hi else wscr_ref[...])                 # (nw, bi + 2hi, ., P)
        else:
            w = w_ref[...]
        gi0 = geom_ref[0] + (t - lag) * bi - hi
        u, interior, shift, refill, parity = prepare_strip(
            u, gi0, j0, geom_ref[1], n_global, plan, bj is not None or ext_j,
            k0=k0, p_top=p_global if ext_k else None, fill_k=ext_k)
        u = run_sweeps(u, interior, w, plan, s, shift=shift, refill=refill,
                       parity=parity)
        out = u[hi:hi + bi] if bj is None else u[hi:hi + bi, hj:hj + bj]
        o_ref[0] = out.astype(o_ref.dtype)
        # Rotate the window: new tail = last hi planes of the block the
        # scratch currently holds.
        if hi:
            tail = scr_ref[bi:bi + hi]
            scr_ref[:hi] = tail
            if var:
                wtail = wscr_ref[:, bi:bi + hi]
                wscr_ref[:, :hi] = wtail
        scr_ref[hi:] = cur
        if var:
            wscr_ref[:, hi:] = wcur


def stencil3d_wavefront_kernel(*refs, plan: StencilPlan, bi: int,
                               n_global: int, sweeps: int, acc_dtype,
                               ext_j: bool = False, ext_k: bool = False,
                               p_global: Optional[int] = None):
    """Temporal wavefront-tiled volumetric kernel: ``s = sweeps`` *pipelined*
    sweep stages ride one pass over the i-blocks, each input plane fetched
    from HBM once per ``s`` sweeps (vs once per sweep chained, and vs a
    ``radius * s``-deep fused halo).

    ``refs`` is ``(view, geom_ref, w_ref, o_ref, scr_in, *stage_scrs)``:
    one identity-mapped input block ``(1, bi, N, P)`` on a grid of
    ``nbi + s`` steps, plus ``s`` rotating VMEM windows of ``bi + ha``
    planes each (``ha = radius * sweep_apps``, the *single-sweep* halo --
    the wavefront's VMEM advantage over the fused path's ``radius * s``).
    ``scr_in`` holds input-dtype planes for stage 1; ``stage_scrs[q-2]``
    holds stage ``q-1``'s accumulation-dtype output planes for stage ``q``.

    The pipeline is *skewed*: at step ``t``, stage ``q`` computes its block
    ``t - q`` from ``[window | head ha planes of stage q-1's block
    t - q + 1]`` -- stage ``q`` consumes planes stage ``q - 1`` produced
    exactly one step (= ``bi`` >= ``ha`` planes) earlier, so every stage
    runs the full single-sweep BC machinery (:func:`prepare_strip` +
    :func:`run_sweeps`) at its own global geometry and the final stage's
    central block is exact.  Blocks with out-of-domain indices (the ``s``
    pipeline fill/drain steps) only ever produce planes at out-of-domain
    global rows, which the ghost fill / clamp masking of the *consuming*
    stage overwrites -- the same shrink argument as the fused halo, applied
    per stage.  The lagged output map writes stage ``s``'s block ``t - s``;
    steps ``t < s`` write pipeline-fill garbage that is overwritten at
    ``t = s`` before the block index advances (Pallas revisiting
    semantics, the same trick as the streaming kernel's lead-in).

    A periodic i axis is handled by the *caller* (HBM pre-extension with
    ``radius * sweep_apps * s`` wrapped rows and external-halo geometry --
    see :func:`~.sweeps.stencil_wavefront`), so this body never wraps;
    variable-coefficient specs take the fused/chained paths instead (their
    coefficient planes would need an ``s``-block-deep window here).
    """
    view, geom_ref, w_ref, o_ref, scr_in = refs[:5]
    stage_scrs = refs[5:]
    ri, _, _ = plan.spec.radius
    ha = ri * plan.spec.sweep_apps
    s = sweeps
    t = pl.program_id(1)
    cur = view[0]                                          # (bi, N, P)

    @pl.when(t == 0)
    def _prime():
        # Stage 1's window for block 0: block "-1" is above the domain
        # (zeros; strip fill / interior mask of every stage handles them).
        if ha:
            scr_in[:ha] = jnp.zeros((ha,) + cur.shape[1:], cur.dtype)
        scr_in[ha:] = cur

    @pl.when(t >= 1)
    def _compute():
        w = w_ref[...]
        j0 = geom_ref[2] if ext_j else 0
        k0 = geom_ref[3] if ext_k else 0

        def stage(win_ref, nxt, blk):
            u = (jnp.concatenate([win_ref[...], nxt[:ha]], axis=0) if ha
                 else win_ref[...]).astype(acc_dtype)      # (bi + 2ha, N, P)
            gi0 = geom_ref[0] + blk * bi - ha
            u, interior, shift, refill, parity = prepare_strip(
                u, gi0, j0, geom_ref[1], n_global, plan, ext_j, k0=k0,
                p_top=p_global if ext_k else None, fill_k=ext_k)
            u = run_sweeps(u, interior, w, plan, 1, shift=shift,
                           refill=refill, parity=parity)
            return u[ha:ha + bi]

        nxt = cur            # stage q's "next block" = stage q-1's block t-q+1
        for q in range(1, s + 1):
            win = scr_in if q == 1 else stage_scrs[q - 2]
            val = stage(win, nxt, t - q)
            # rotate window q-1 forward with its freshly arrived block
            if ha:
                tail = win[bi:bi + ha]
                win[:ha] = tail
            win[ha:] = nxt
            nxt = val
        o_ref[0] = nxt.astype(o_ref.dtype)


def stencil3d_strip_kernel(*refs, plan: StencilPlan, h: int, n_global: int,
                           sweeps: int, acc_dtype, ext_j: bool = False,
                           ext_k: bool = False,
                           p_global: Optional[int] = None):
    """Boundary-strip fused-sweep kernel: one fully pre-extended i-strip.

    The compute/communication-overlap executor splits a shard's sweep into
    an interior pass (no i ghosts needed, runs while the i-axis ppermutes
    are in flight) and two thin boundary strips computed from the arrived
    ghost slabs.  This body is the strip entry: ``refs`` is ``(u_ref,
    geom_ref, w_ref, o_ref)`` with a single identity-mapped block ``(1,
    rows, N, P)`` whose ``rows = out_rows + 2h`` i-planes *already include*
    the ``h`` exchanged ghost planes per side (``h = radius * sweeps *
    sweep_apps``), so no staging, streaming window, or neighbour views are
    involved -- the strip runs :func:`prepare_strip` + :func:`run_sweeps`
    at its global geometry and writes the central ``rows - 2h`` planes.
    (The replicated path cannot serve here: at a single i-block its clamped
    index maps would duplicate resident data into halo positions that are
    genuinely interior on a sharded slab.)  Variable-coefficient specs pass
    the matching pre-extended coefficient strip as ``w_ref``."""
    u_ref, geom_ref, w_ref, o_ref = refs
    u = u_ref[0].astype(acc_dtype)
    w = w_ref[...]          # var: the whole (n_weights, rows, N, P) strip
    gi0 = geom_ref[0]
    j0 = geom_ref[2] if ext_j else 0
    k0 = geom_ref[3] if ext_k else 0
    u, interior, shift, refill, parity = prepare_strip(
        u, gi0, j0, geom_ref[1], n_global, plan, ext_j, k0=k0,
        p_top=p_global if ext_k else None, fill_k=ext_k)
    u = run_sweeps(u, interior, w, plan, sweeps, shift=shift, refill=refill,
                   parity=parity)
    rows = u.shape[0]
    o_ref[0] = u[h:rows - h].astype(o_ref.dtype)


def stencil1d_kernel(a_ref, w_ref, o_ref, *, plan: StencilPlan, sweeps: int,
                     acc_dtype):
    """k-only kernel over ``(block_rows, P)`` blocks; rows are independent,
    so fused sweeps need no halo at all (the k axis is fully resident and
    its BC -- wrap / constant / mirror / zero fill -- lives in the shift
    primitive)."""
    u = a_ref[...].astype(acc_dtype)
    w = w_ref[...]
    p = u.shape[-1]
    klo, khi = plan.spec.bc[2]
    interior = None
    if klo.kind == "clamp" or khi.kind == "clamp":
        kk = jax.lax.broadcasted_iota(jnp.int32, u.shape, u.ndim - 1)
        interior = ((kk > 0) & (kk < p - 1) if klo.kind == khi.kind
                    else (kk > 0) if klo.kind == "clamp" else (kk < p - 1))
    parity = None
    if plan.spec.ordering == "redblack":
        kk = jax.lax.broadcasted_iota(jnp.int32, u.shape, u.ndim - 1)
        parity = (kk % 2) == 0       # rows are independent: parity is k-only
    shift = make_shift(plan.spec.bc, j_in_shift=False)
    o_ref[...] = run_sweeps(u, interior, w, plan, sweeps, shift=shift,
                            parity=parity).astype(o_ref.dtype)
