"""Jitted public entry point: one configurable stencil executor.

``stencil_apply`` runs any registered (or ad-hoc) radius-1 spec over batched,
multi-dtype inputs, with optional fused Jacobi sweeps, via the single kernel
body in :mod:`.kernel`.  See the package docstring for the full tour.
"""

from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .autotune import autotune_block_i, pick_block_rows
from .kernel import acc_dtype_for, stencil1d_kernel, stencil3d_kernel
from .spec import StencilSpec, get_stencil


def call_3d(a4: jax.Array, wf: jax.Array, geom: jax.Array, spec: StencilSpec,
            bi: int, sweeps: int, interpret: bool) -> jax.Array:
    """Wire the fused volumetric kernel: ``a4`` is ``(B, M, N, P)``; the
    i-halo comes from passing ``a4`` three times under +-1-shifted (clamped)
    block index maps.  ``geom`` = (global row offset, global M) int32."""
    b, m, n, p = a4.shape
    if m % bi != 0:
        raise ValueError(f"block size {bi} must divide M={m}")
    if sweeps > bi:
        raise ValueError(f"fused sweeps={sweeps} exceed the +-1-block halo; "
                         f"need block_i >= sweeps (block_i={bi})")
    nblk = m // bi
    block = (1, bi, n, p)
    acc = acc_dtype_for(a4.dtype)
    in_specs = [
        pl.BlockSpec(block, lambda bb, i: (bb, jnp.maximum(i - 1, 0), 0, 0)),
        pl.BlockSpec(block, lambda bb, i: (bb, i, 0, 0)),
        pl.BlockSpec(block, functools.partial(
            lambda bb, i, top: (bb, jnp.minimum(i + 1, top), 0, 0),
            top=nblk - 1)),
        pl.BlockSpec(geom.shape, lambda bb, i: (0,)),
        pl.BlockSpec(wf.shape, lambda bb, i: (0,)),
    ]
    return pl.pallas_call(
        functools.partial(stencil3d_kernel, spec=spec, bi=bi, sweeps=sweeps,
                          acc_dtype=acc),
        grid=(b, nblk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(block, lambda bb, i: (bb, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(a4.shape, a4.dtype),
        interpret=interpret,
    )(a4, a4, a4, geom, wf)


def _call_1d(a2: jax.Array, wf: jax.Array, spec: StencilSpec, block_rows: int,
             sweeps: int, interpret: bool) -> jax.Array:
    rows, p = a2.shape
    if rows % block_rows != 0:
        raise ValueError(f"block_rows {block_rows} must divide rows={rows}")
    return pl.pallas_call(
        functools.partial(stencil1d_kernel, spec=spec, sweeps=sweeps,
                          acc_dtype=acc_dtype_for(a2.dtype)),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
                  pl.BlockSpec(wf.shape, lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(a2.shape, a2.dtype),
        interpret=interpret,
    )(a2, wf)


@functools.partial(jax.jit,
                   static_argnames=("stencil", "block_i", "sweeps",
                                    "interpret"))
def stencil_apply(a: jax.Array, w: jax.Array,
                  stencil: Union[str, int, StencilSpec] = "stencil27",
                  block_i: Optional[int] = None, sweeps: int = 1,
                  interpret: bool = True) -> jax.Array:
    """Apply a registered stencil: ``sweeps`` fused Jacobi applications.

    * volumetric specs: ``a`` is ``(..., M, N, P)`` -- leading dims batch;
    * k-only specs: ``a`` is ``(..., P)`` -- leading dims are rows;
    * bf16/f32 inputs accumulate in f32, f64 stays f64 (reference path);
    * ``block_i`` (i-block / row-block size) defaults to the cost model.
    """
    if sweeps < 1:
        raise ValueError(f"sweeps must be >= 1, got {sweeps}")
    spec = get_stencil(stencil)
    acc = acc_dtype_for(a.dtype)
    wf = spec.canon_weights(w).astype(acc)

    if spec.ndim == 1:
        if a.ndim < 2:
            raise ValueError(f"{spec.name}: need (..., rows, P), got {a.shape}")
        rows = int(np.prod(a.shape[:-1]))
        a2 = a.reshape(rows, a.shape[-1])
        br = block_i or pick_block_rows(rows, a.shape[-1], a.dtype.itemsize)
        return _call_1d(a2, wf, spec, br, sweeps, interpret).reshape(a.shape)

    if a.ndim < 3:
        raise ValueError(f"{spec.name}: need (..., M, N, P), got {a.shape}")
    m, n, p = a.shape[-3:]
    batch = int(np.prod(a.shape[:-3])) if a.ndim > 3 else 1
    a4 = a.reshape(batch, m, n, p)
    bi = block_i or autotune_block_i(m, n, p, a.dtype.itemsize,
                                     sweeps=sweeps, taps=spec.taps)
    geom = jnp.array([0, m], jnp.int32)
    out = call_3d(a4, wf, geom, spec, bi, sweeps, interpret)
    return out.reshape(a.shape)
