"""Jitted public entry point: one configurable stencil executor.

``stencil_apply`` runs any registered (or ad-hoc) radius-1 spec over batched,
multi-dtype inputs, with optional fused Jacobi sweeps, via the single kernel
body in :mod:`.kernel`.  The spec is compiled to an execution plan
(:mod:`.plan` -- ``auto``/``factored``/``cse``/``direct``) before tracing,
and blocks may be tiled along j as well as i when the full N x P slab would
not fit VMEM.  See the package docstring for the full tour.
"""

from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .autotune import autotune_blocks, pick_block_rows
from .kernel import acc_dtype_for, stencil1d_kernel, stencil3d_kernel
from .plan import StencilPlan, compile_plan
from .spec import StencilSpec, get_stencil


def _clamped_imap(di: int, dj: int, top_i: int, top_j: int):
    """Index map for the (di, dj) neighbour view of a (1, bi, bj, P) block
    grid, clamped at the domain edges (the clamped duplicate data only ever
    lands on rows/columns the global interior mask zeroes)."""
    def f(bb, i, j):
        ii = i if di == 0 else (jnp.maximum(i - 1, 0) if di < 0
                                else jnp.minimum(i + 1, top_i))
        jj = j if dj == 0 else (jnp.maximum(j - 1, 0) if dj < 0
                                else jnp.minimum(j + 1, top_j))
        return (bb, ii, jj, 0)
    return f


def call_3d(a4: jax.Array, wf: jax.Array, geom: jax.Array, plan: StencilPlan,
            bi: int, bj: Optional[int], sweeps: int,
            interpret: bool) -> jax.Array:
    """Wire the fused volumetric kernel: ``a4`` is ``(B, M, N, P)``.

    Untiled (``bj is None``): blocks are ``(1, bi, N, P)`` and the i-halo
    comes from passing ``a4`` three times under +-1-shifted (clamped) block
    index maps.  j-tiled: blocks are ``(1, bi, bj, P)`` and the kernel sees
    all 3x3 neighbour views, so the working slab never exceeds
    ``(bi + 2s)(bj + 2s)P`` whatever N is.  ``geom`` = (global row offset,
    global M) int32.
    """
    b, m, n, p = a4.shape
    if m % bi != 0:
        raise ValueError(f"block size {bi} must divide M={m}")
    if sweeps > bi:
        raise ValueError(f"fused sweeps={sweeps} exceed the +-1-block halo; "
                         f"need block_i >= sweeps (block_i={bi})")
    nbi = m // bi
    kern = functools.partial(stencil3d_kernel, plan=plan, bi=bi, bj=bj,
                             n_global=n, sweeps=sweeps,
                             acc_dtype=acc_dtype_for(a4.dtype))
    if bj is None:
        block = (1, bi, n, p)
        in_specs = [
            pl.BlockSpec(block,
                         lambda bb, i: (bb, jnp.maximum(i - 1, 0), 0, 0)),
            pl.BlockSpec(block, lambda bb, i: (bb, i, 0, 0)),
            pl.BlockSpec(block, functools.partial(
                lambda bb, i, top: (bb, jnp.minimum(i + 1, top), 0, 0),
                top=nbi - 1)),
            pl.BlockSpec(geom.shape, lambda bb, i: (0,)),
            pl.BlockSpec(wf.shape, lambda bb, i: (0,)),
        ]
        return pl.pallas_call(
            kern,
            grid=(b, nbi),
            in_specs=in_specs,
            out_specs=pl.BlockSpec(block, lambda bb, i: (bb, i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct(a4.shape, a4.dtype),
            interpret=interpret,
        )(a4, a4, a4, geom, wf)

    if n % bj != 0:
        raise ValueError(f"block size {bj} must divide N={n}")
    if sweeps > bj:
        raise ValueError(f"fused sweeps={sweeps} exceed the +-1-block halo; "
                         f"need block_j >= sweeps (block_j={bj})")
    nbj = n // bj
    block = (1, bi, bj, p)
    in_specs = [pl.BlockSpec(block, _clamped_imap(di, dj, nbi - 1, nbj - 1))
                for di in (-1, 0, 1) for dj in (-1, 0, 1)]
    in_specs += [pl.BlockSpec(geom.shape, lambda bb, i, j: (0,)),
                 pl.BlockSpec(wf.shape, lambda bb, i, j: (0,))]
    return pl.pallas_call(
        kern,
        grid=(b, nbi, nbj),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(block, lambda bb, i, j: (bb, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(a4.shape, a4.dtype),
        interpret=interpret,
    )(*([a4] * 9), geom, wf)


def _call_1d(a2: jax.Array, wf: jax.Array, plan: StencilPlan, block_rows: int,
             sweeps: int, interpret: bool) -> jax.Array:
    rows, p = a2.shape
    if rows % block_rows != 0:
        raise ValueError(f"block_rows {block_rows} must divide rows={rows}")
    return pl.pallas_call(
        functools.partial(stencil1d_kernel, plan=plan, sweeps=sweeps,
                          acc_dtype=acc_dtype_for(a2.dtype)),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
                  pl.BlockSpec(wf.shape, lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(a2.shape, a2.dtype),
        interpret=interpret,
    )(a2, wf)


@functools.partial(jax.jit,
                   static_argnames=("stencil", "block_i", "block_j", "plan",
                                    "sweeps", "interpret"))
def stencil_apply(a: jax.Array, w: jax.Array,
                  stencil: Union[str, int, StencilSpec] = "stencil27",
                  block_i: Optional[int] = None,
                  block_j: Optional[int] = None, plan: str = "auto",
                  sweeps: int = 1, interpret: bool = True) -> jax.Array:
    """Apply a registered stencil: ``sweeps`` fused Jacobi applications.

    * volumetric specs: ``a`` is ``(..., M, N, P)`` -- leading dims batch;
    * k-only specs: ``a`` is ``(..., P)`` -- leading dims are rows;
    * bf16/f32 inputs accumulate in f32, f64 stays f64 (reference path);
    * ``plan`` picks the execution schedule (``auto`` -> ``factored`` for
      mirror-symmetric specs, ``cse`` otherwise; ``direct`` is the naive
      parity escape hatch) -- same-plan runs execute the identical op walk
      as :func:`stencil_ref` (f64 bit-parity on the reference
      configurations; exact blocking-invariance on integer-valued data --
      see :mod:`.plan` on fma contraction);
    * ``block_i``/``block_j`` (i-block rows / j-tile columns) default to the
      plan-aware cost model, which engages j-tiling only when the full
      N x P slab would blow the VMEM budget.
    """
    if sweeps < 1:
        raise ValueError(f"sweeps must be >= 1, got {sweeps}")
    spec = get_stencil(stencil)
    cplan = compile_plan(spec, plan)
    acc = acc_dtype_for(a.dtype)
    wf = spec.canon_weights(w).astype(acc)

    if spec.ndim == 1:
        if a.ndim < 2:
            raise ValueError(f"{spec.name}: need (..., rows, P), got {a.shape}")
        rows = int(np.prod(a.shape[:-1]))
        a2 = a.reshape(rows, a.shape[-1])
        br = block_i or pick_block_rows(rows, a.shape[-1], a.dtype.itemsize)
        return _call_1d(a2, wf, cplan, br, sweeps, interpret).reshape(a.shape)

    if a.ndim < 3:
        raise ValueError(f"{spec.name}: need (..., M, N, P), got {a.shape}")
    m, n, p = a.shape[-3:]
    batch = int(np.prod(a.shape[:-3])) if a.ndim > 3 else 1
    a4 = a.reshape(batch, m, n, p)
    bi, bj = block_i, block_j
    if bi is None:
        bi, bj_auto = autotune_blocks(m, n, p, a.dtype.itemsize,
                                      sweeps=sweeps, plan=cplan, block_j=bj)
        bj = bj if bj is not None else bj_auto
    geom = jnp.array([0, m], jnp.int32)
    out = call_3d(a4, wf, geom, cplan, bi, bj, sweeps, interpret)
    return out.reshape(a.shape)
