"""Jitted public entry point: one configurable stencil executor.

``stencil_apply`` runs any registered (or ad-hoc) radius-R spec over batched,
multi-dtype inputs, with optional fused Jacobi sweeps, via the kernel bodies
in :mod:`.kernel`.  The spec is compiled to an execution plan (:mod:`.plan`
-- a pass pipeline; ``auto``/``factored``/``cse``/``direct`` presets) before
tracing; the volumetric hot path is the *plane-streaming* kernel
(``path="stream"``, each input plane fetched from HBM once, the
``radius * sweeps``-deep halo carried in VMEM scratch across grid steps)
with the halo-*replicated* kernel kept as a parity escape hatch
(``path="replicate"``, ``2r + 1`` neighbour views, like ``plan="direct"``);
and blocks may be tiled along j as well as i when the full N x P slab would
not fit VMEM.  See the package docstring for the full tour.
"""

from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .autotune import (PATH_KINDS, autotune_blocks, autotune_engine,
                       pick_block_rows)
from .kernel import (KernelFault, acc_dtype_for, stencil1d_kernel,
                     stencil3d_kernel, stencil3d_stream_kernel,
                     stencil3d_strip_kernel, stencil3d_wavefront_kernel)
from .plan import StencilPlan, compile_plan
from .spec import StencilSpec, get_stencil


def _periodic_axes(spec: StencilSpec):
    """(i, j) axis periodicity (periodic is validated as paired)."""
    return (spec.bc[0][0].kind == "periodic",
            spec.bc[1][0].kind == "periodic")


@functools.lru_cache(maxsize=None)
def default_interpret() -> bool:
    """Resolve ``interpret=None``: interpret the Pallas kernels only when no
    compiled backend for *these kernels* is available -- i.e. run compiled
    on TPU and interpreted elsewhere -- so the same call site works
    everywhere.  The kernel bodies are Mosaic-TPU-shaped (``pltpu.VMEM``
    scratch windows carried across a sequential grid), which the GPU
    (Triton / Mosaic-GPU) lowerings do not provide, so GPU hosts stay on
    the interpreter too."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def _edge_index(x, d: int, nb: int, wrap: bool):
    """A neighbour block index at the domain edge: wrapped for a periodic
    axis (the halo genuinely comes from the far side), clamped otherwise
    (the duplicate data lands on positions the kernel's ghost fill /
    interior mask overwrites)."""
    if d == 0:
        return x
    return (x + d) % nb if wrap else jnp.clip(x + d, 0, nb - 1)


def _neighbor_imap(di: int, dj: int, nbi: int, nbj: int,
                   wrap_i: bool, wrap_j: bool):
    """Index map for the (di, dj) neighbour view of a (1, bi, bj, P) block
    grid, per-axis wrapped (periodic) or clamped at the domain edges."""
    def f(bb, i, j):
        return (bb, _edge_index(i, di, nbi, wrap_i),
                _edge_index(j, dj, nbj, wrap_j), 0)
    return f


def _validate_blocks(m: int, n: int, bi: int, bj: Optional[int],
                     sweeps: int, radius, apps: int = 1) -> None:
    """``apps`` is the spec's applications per sweep (2 for red-black
    Gauss-Seidel) -- the carried halo is ``radius * sweeps * apps`` deep."""
    ri, rj, _ = radius
    if m % bi != 0:
        raise ValueError(f"block size {bi} must divide M={m}")
    if ri * sweeps * apps > bi:
        raise ValueError(f"fused sweeps={sweeps} exceed the carried halo; "
                         f"need block_i >= sweeps*r_i*sweep_apps "
                         f"(block_i={bi}, r_i={ri}, sweep_apps={apps})")
    if bj is not None:
        if n % bj != 0:
            raise ValueError(f"block size {bj} must divide N={n}")
        if rj * sweeps * apps > bj:
            raise ValueError(f"fused sweeps={sweeps} exceed the carried "
                             f"halo; need block_j >= sweeps*r_j*sweep_apps "
                             f"(block_j={bj}, r_j={rj}, sweep_apps={apps})")


def _call_3d_stream(a4: jax.Array, wf: jax.Array, geom: jax.Array,
                    plan: StencilPlan, bi: int, bj: Optional[int],
                    sweeps: int, interpret: bool,
                    external_i_halo: bool = False,
                    fault: Optional[KernelFault] = None,
                    ext_j: bool = False, ext_k: bool = False,
                    n_global: Optional[int] = None,
                    p_global: Optional[int] = None) -> jax.Array:
    """Wire the plane-streaming kernel: one pass over the i-blocks with one
    extra grid step, a lagged output index map, and a VMEM scratch window of
    ``bi + ri * sweeps`` input planes carried across steps.  Untiled, the
    input is a single identity-mapped operand -- each plane is fetched from
    HBM exactly once per call (the final clamped step re-presents the last
    block, which Pallas revisiting semantics keep DMA-free); j-tiled, the
    ``2rj + 1`` j-neighbour views stream i within each j-tile (``2rj + 1``
    fetches per plane vs the replicated path's ``(2ri+1)(2rj+1)``).

    A periodic i axis (unless ``external_i_halo`` -- the sharded ring
    already materialized the wrap) adds one more lead-in step and walks the
    wrapped block sequence ``(t + nbi - 1) % nbi``: the last block's tail
    planes are staged first (the ghost rows below row 0) and the first
    block's head planes are re-fetched at the end -- the ``r * sweeps``
    lead/tail planes are the only re-fetched HBM traffic.

    Variable-coefficient specs (``wf`` is ``(n_weights, M, N, P)``) add a
    parallel set of coefficient views under the *same* block walk plus a
    second co-rotating VMEM scratch window, so coefficient planes stream
    exactly like field planes -- fetched once per call."""
    b, m, n, p = a4.shape
    nbi = m // bi
    ri, rj, _ = plan.spec.radius
    hi = ri * sweeps * plan.spec.sweep_apps
    var = plan.spec.coef == "var"
    per_i, per_j = _periodic_axes(plan.spec)
    wrap_i = per_i and not external_i_halo and hi > 0
    steps = nbi + (2 if wrap_i else 1)
    lag = 2 if wrap_i else 1
    kern = functools.partial(stencil3d_stream_kernel, plan=plan, bi=bi,
                             bj=bj, n_global=n_global if ext_j else n,
                             sweeps=sweeps,
                             acc_dtype=acc_dtype_for(a4.dtype),
                             wrap_i=wrap_i, fault=fault, ext_j=ext_j,
                             ext_k=ext_k,
                             p_global=p_global if ext_k else None)
    if wrap_i:
        def imap_t(t):
            return (t + nbi - 1) % nbi
    else:
        def imap_t(t):
            return jnp.minimum(t, nbi - 1)

    def omap_t(t):
        return jnp.clip(t - lag, 0, nbi - 1)

    if bj is None:
        block = (1, bi, n, p)
        in_specs = [
            pl.BlockSpec(block, lambda bb, t: (bb, imap_t(t), 0, 0)),
            pl.BlockSpec(geom.shape, lambda bb, t: (0,)),
        ]
        scratch = [pltpu.VMEM((bi + hi, n, p), a4.dtype)]
        if var:
            in_specs.append(pl.BlockSpec((wf.shape[0], bi, n, p),
                                         lambda bb, t: (0, imap_t(t), 0, 0)))
            scratch.append(pltpu.VMEM((wf.shape[0], bi + hi, n, p), wf.dtype))
        else:
            in_specs.append(pl.BlockSpec(wf.shape, lambda bb, t: (0,)))
        return pl.pallas_call(
            kern,
            grid=(b, steps),
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                block, lambda bb, t: (bb, omap_t(t), 0, 0)),
            out_shape=jax.ShapeDtypeStruct(a4.shape, a4.dtype),
            scratch_shapes=scratch,
            interpret=interpret,
        )(a4, geom, wf)

    nbj = n // bj
    hj = rj * sweeps * plan.spec.sweep_apps
    block = (1, bi, bj, p)

    def jmap(dj: int):
        def f(bb, j, t):
            return (bb, imap_t(t), _edge_index(j, dj, nbj, per_j), 0)
        return f

    def wjmap(dj: int):
        def f(bb, j, t):
            return (0, imap_t(t), _edge_index(j, dj, nbj, per_j), 0)
        return f

    # The full 2rj+1 j-neighbourhood is staged (the cost model's canonical
    # j-tiled streaming traffic, (2rj+2) bytes/pt); with bj >= rj*sweeps
    # validated, the kernel body only reads the +-1 tiles' halo slices --
    # narrowing the staging to match is a possible future optimization that
    # would also have to move bytes_per_point/_views off their
    # radius-canonical accounting.
    in_specs = [pl.BlockSpec(block, jmap(dj))
                for dj in range(-rj, rj + 1)]
    in_specs += [pl.BlockSpec(geom.shape, lambda bb, j, t: (0,))]
    scratch = [pltpu.VMEM((bi + hi, bj + 2 * hj, p), a4.dtype)]
    if var:
        in_specs += [pl.BlockSpec((wf.shape[0], bi, bj, p), wjmap(dj))
                     for dj in range(-rj, rj + 1)]
        scratch.append(pltpu.VMEM((wf.shape[0], bi + hi, bj + 2 * hj, p),
                                  wf.dtype))
        w_args = [wf] * (2 * rj + 1)
    else:
        in_specs += [pl.BlockSpec(wf.shape, lambda bb, j, t: (0,))]
        w_args = [wf]
    return pl.pallas_call(
        kern,
        grid=(b, nbj, steps),          # i innermost: the stream restarts
        in_specs=in_specs,             # (and re-primes) per j-tile
        out_specs=pl.BlockSpec(
            block, lambda bb, j, t: (bb, omap_t(t), j, 0)),
        out_shape=jax.ShapeDtypeStruct(a4.shape, a4.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*([a4] * (2 * rj + 1)), geom, *w_args)


def call_3d(a4: jax.Array, wf: jax.Array, geom: jax.Array, plan: StencilPlan,
            bi: int, bj: Optional[int], sweeps: int, interpret: bool,
            path: str = "stream", external_i_halo: bool = False,
            fault: Optional[KernelFault] = None,
            ext_j: bool = False, ext_k: bool = False,
            n_global: Optional[int] = None,
            p_global: Optional[int] = None) -> jax.Array:
    """Wire a fused volumetric kernel: ``a4`` is ``(B, M, N, P)``.

    ``path="stream"`` (default) walks the i-blocks in order and carries the
    halo in VMEM scratch -- each input plane is fetched once.
    ``path="replicate"`` is the stateless parity escape hatch: the i-halo
    comes from passing ``a4`` ``2ri + 1`` times under block-shifted
    index maps (untiled) or the full ``(2ri+1) x (2rj+1)``
    neighbour views (j-tiled) -- edge blocks clamp, except on periodic axes
    where they wrap to the far side.  Both paths share block geometry:
    untiled blocks are ``(1, bi, N, P)``; j-tiled blocks ``(1, bi, bj, P)``,
    so the working slab never exceeds ``(bi + 2*hi)(bj + 2*hj)P`` whatever
    N is (``h = radius * sweeps``).  ``geom`` = (global row offset, global
    M) int32.  ``external_i_halo=True`` (the sharded path) marks the i-axis
    halo as already materialized in ``a4`` -- a periodic i BC is then *not*
    wrapped locally (the ring exchange supplied the wrapped rows).

    ``ext_j``/``ext_k`` (the multi-axis-sharded path) mark the j/k ghosts
    as externally materialized too: ``a4`` is the per-shard slab already
    extended along those axes, ``geom`` grows to ``(gi0, M, j0, k0)``, and
    the kernels realize the j/k BCs at the *global* edges from
    ``n_global``/``p_global`` (the global N/P).  External j is
    incompatible with j-tiling (the tile walk would re-wrap the exchanged
    columns), so ``bj`` must be ``None``.
    """
    b, m, n, p = a4.shape
    if (ext_j or ext_k) and bj is not None:
        raise ValueError("call_3d: block_j tiling is incompatible with an "
                         "externally materialized j/k halo (ext_j/ext_k); "
                         "pass block_j=None on j/k-sharded slabs")
    _validate_blocks(m, n, bi, bj, sweeps, plan.spec.radius,
                     plan.spec.sweep_apps)
    if path == "stream":
        return _call_3d_stream(a4, wf, geom, plan, bi, bj, sweeps, interpret,
                               external_i_halo, fault, ext_j=ext_j,
                               ext_k=ext_k, n_global=n_global,
                               p_global=p_global)
    if path != "replicate":
        raise ValueError(f"unknown path {path!r}; expected 'stream' or "
                         f"'replicate'")
    nbi = m // bi
    ri, rj, _ = plan.spec.radius
    var = plan.spec.coef == "var"
    per_i, per_j = _periodic_axes(plan.spec)
    wrap_i = per_i and not external_i_halo
    kern = functools.partial(stencil3d_kernel, plan=plan, bi=bi, bj=bj,
                             n_global=n_global if ext_j else n,
                             sweeps=sweeps,
                             acc_dtype=acc_dtype_for(a4.dtype),
                             ext_j=ext_j, ext_k=ext_k,
                             p_global=p_global if ext_k else None)
    if bj is None:
        block = (1, bi, n, p)

        def imap_i(di: int, lead: Optional[int] = None):
            def f(bb, i):
                return (bb if lead is None else lead,
                        _edge_index(i, di, nbi, wrap_i), 0, 0)
            return f

        # 2ri+1 staged views = the replicated path's canonical per-radius
        # cost ((2ri+2) bytes/pt -- what makes the stream-vs-replicate race
        # honest); only the +-1 views' halo slices are read by the body.
        in_specs = [pl.BlockSpec(block, imap_i(di))
                    for di in range(-ri, ri + 1)]
        in_specs += [pl.BlockSpec(geom.shape, lambda bb, i: (0,))]
        if var:
            # a full parallel set of coefficient views under the same walk
            in_specs += [pl.BlockSpec((wf.shape[0], bi, n, p),
                                      imap_i(di, lead=0))
                         for di in range(-ri, ri + 1)]
            w_args = [wf] * (2 * ri + 1)
        else:
            in_specs += [pl.BlockSpec(wf.shape, lambda bb, i: (0,))]
            w_args = [wf]
        return pl.pallas_call(
            kern,
            grid=(b, nbi),
            in_specs=in_specs,
            out_specs=pl.BlockSpec(block, lambda bb, i: (bb, i, 0, 0)),
            out_shape=jax.ShapeDtypeStruct(a4.shape, a4.dtype),
            interpret=interpret,
        )(*([a4] * (2 * ri + 1)), geom, *w_args)

    nbj = n // bj
    block = (1, bi, bj, p)
    in_specs = [pl.BlockSpec(block,
                             _neighbor_imap(di, dj, nbi, nbj, wrap_i, per_j))
                for di in range(-ri, ri + 1) for dj in range(-rj, rj + 1)]
    in_specs += [pl.BlockSpec(geom.shape, lambda bb, i, j: (0,))]
    n_views = (2 * ri + 1) * (2 * rj + 1)
    if var:
        def wmap(di: int, dj: int):
            inner = _neighbor_imap(di, dj, nbi, nbj, wrap_i, per_j)

            def f(bb, i, j):
                return (0,) + inner(bb, i, j)[1:]
            return f

        in_specs += [pl.BlockSpec((wf.shape[0], bi, bj, p), wmap(di, dj))
                     for di in range(-ri, ri + 1)
                     for dj in range(-rj, rj + 1)]
        w_args = [wf] * n_views
    else:
        in_specs += [pl.BlockSpec(wf.shape, lambda bb, i, j: (0,))]
        w_args = [wf]
    return pl.pallas_call(
        kern,
        grid=(b, nbi, nbj),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(block, lambda bb, i, j: (bb, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(a4.shape, a4.dtype),
        interpret=interpret,
    )(*([a4] * n_views), geom, *w_args)


def call_3d_wavefront(a4: jax.Array, wf: jax.Array, geom: jax.Array,
                      plan: StencilPlan, bi: int, sweeps: int,
                      interpret: bool, ext_j: bool = False,
                      ext_k: bool = False, n_global: Optional[int] = None,
                      p_global: Optional[int] = None) -> jax.Array:
    """Wire the temporal-wavefront kernel: ``sweeps`` pipelined sweep stages
    ride one pass over the i-blocks on a grid of ``nbi + sweeps`` steps with
    an ``s``-lagged output map, so each input plane is fetched from HBM once
    per ``sweeps`` applications (~``2 / sweeps`` transfers per point) while
    every stage carries only the *single-sweep* halo ``ha = radius *
    sweep_apps`` in its rotating VMEM window -- ``sweeps`` windows of
    ``bi + ha`` planes (stage 1 in the input dtype, later stages in the
    accumulation dtype) instead of the fused path's one ``bi + radius *
    sweeps * sweep_apps`` window and matching VPU-redundant strip.

    Untiled (full-N blocks), constant coefficients only.  A periodic i axis
    must arrive pre-extended (``radius * sweep_apps * sweeps`` wrapped rows
    per side + external-halo ``geom``); :func:`~.sweeps.stencil_wavefront`
    and the sharded deep-halo exchange both do exactly that.
    """
    b, m, n, p = a4.shape
    spec = plan.spec
    if spec.coef == "var":
        raise ValueError(
            f"{spec.name}: the wavefront path needs constant coefficients "
            f"(variable-coefficient planes would need an s-block-deep "
            f"window); use the fused or chained mode")
    ri = spec.radius[0]
    ha = ri * spec.sweep_apps
    if m % bi != 0:
        raise ValueError(f"wavefront block size {bi} must divide M={m}")
    if ha > bi:
        raise ValueError(f"wavefront needs block_i >= radius*sweep_apps "
                         f"(block_i={bi}, r_i={ri}, "
                         f"sweep_apps={spec.sweep_apps})")
    nbi = m // bi
    s = sweeps
    acc = acc_dtype_for(a4.dtype)
    kern = functools.partial(stencil3d_wavefront_kernel, plan=plan, bi=bi,
                             n_global=n_global if ext_j else n, sweeps=s,
                             acc_dtype=acc, ext_j=ext_j, ext_k=ext_k,
                             p_global=p_global if ext_k else None)
    block = (1, bi, n, p)
    in_specs = [
        pl.BlockSpec(block, lambda bb, t: (bb, jnp.minimum(t, nbi - 1), 0, 0)),
        pl.BlockSpec(geom.shape, lambda bb, t: (0,)),
        pl.BlockSpec(wf.shape, lambda bb, t: (0,)),
    ]
    scratch = [pltpu.VMEM((bi + ha, n, p), a4.dtype)]
    scratch += [pltpu.VMEM((bi + ha, n, p), acc) for _ in range(s - 1)]
    return pl.pallas_call(
        kern,
        grid=(b, nbi + s),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            block, lambda bb, t: (bb, jnp.clip(t - s, 0, nbi - 1), 0, 0)),
        out_shape=jax.ShapeDtypeStruct(a4.shape, a4.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(a4, geom, wf)


def call_3d_strip(a4: jax.Array, wf: jax.Array, geom: jax.Array,
                  plan: StencilPlan, sweeps: int, interpret: bool, h: int,
                  ext_j: bool = False, ext_k: bool = False,
                  n_global: Optional[int] = None,
                  p_global: Optional[int] = None) -> jax.Array:
    """Wire the boundary-strip kernel for the overlap executor: ``a4`` is
    ``(B, rows, N, P)`` with ``rows = out_rows + 2h`` i-planes that already
    include the ``h`` exchanged ghost planes per side (``h = radius *
    sweeps * sweep_apps``).  One identity-mapped block per batch entry --
    the strip is thin by construction (``3h`` planes for the overlap
    executor's edge strips), so no streaming window or neighbour views are
    staged.  Returns the central ``(B, rows - 2h, N, P)`` planes.  On a
    variable-coefficient spec ``wf`` is the matching pre-extended
    ``(n_weights, rows, N, P)`` coefficient strip."""
    b, rows, n, p = a4.shape
    if rows <= 2 * h:
        raise ValueError(f"call_3d_strip: strip of {rows} planes has no "
                         f"interior under the {h}-plane halo")
    kern = functools.partial(stencil3d_strip_kernel, plan=plan, h=h,
                             n_global=n_global if ext_j else n,
                             sweeps=sweeps,
                             acc_dtype=acc_dtype_for(a4.dtype),
                             ext_j=ext_j, ext_k=ext_k,
                             p_global=p_global if ext_k else None)
    in_specs = [
        pl.BlockSpec((1, rows, n, p), lambda bb: (bb, 0, 0, 0)),
        pl.BlockSpec(geom.shape, lambda bb: (0,)),
        pl.BlockSpec(wf.shape, lambda bb: (0,) * wf.ndim),
    ]
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rows - 2 * h, n, p),
                               lambda bb: (bb, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, rows - 2 * h, n, p), a4.dtype),
        interpret=interpret,
    )(a4, geom, wf)


def _call_1d(a2: jax.Array, wf: jax.Array, plan: StencilPlan, block_rows: int,
             sweeps: int, interpret: bool) -> jax.Array:
    rows, p = a2.shape
    if rows % block_rows != 0:
        raise ValueError(f"block_rows {block_rows} must divide rows={rows}")
    return pl.pallas_call(
        functools.partial(stencil1d_kernel, plan=plan, sweeps=sweeps,
                          acc_dtype=acc_dtype_for(a2.dtype)),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
                  pl.BlockSpec(wf.shape,
                               lambda i: (0,) * wf.ndim)],
        out_specs=pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(a2.shape, a2.dtype),
        interpret=interpret,
    )(a2, wf)


def _stencil_apply_impl(a: jax.Array, w: jax.Array,
                        stencil: Union[str, int, StencilSpec] = "stencil27",
                        block_i: Optional[int] = None,
                        block_j: Optional[int] = None, plan: str = "auto",
                        sweeps: int = 1, path: str = "auto", bc=None,
                        interpret: Optional[bool] = None,
                        _fault: Optional[KernelFault] = None) -> jax.Array:
    """The jittable body of :func:`stencil_apply` (see its docstring).

    ``_fault`` is the static in-kernel fault-injection descriptor
    (:class:`~.kernel.KernelFault`; tests only) -- ``None``, the default,
    traces the byte-identical historical program."""
    if sweeps < 1:
        raise ValueError(f"sweeps must be >= 1, got {sweeps}")
    if path not in PATH_KINDS:
        raise ValueError(f"unknown path {path!r}; expected one of "
                         f"{PATH_KINDS}")
    spec = get_stencil(stencil)
    if spec.guard != "off":
        # The guard never reaches the traced program: strip it so guarded
        # and unguarded calls share plan and jit caches.
        spec = spec.with_guard("off")
    if bc is not None:
        spec = spec.with_bc(bc)
    cplan = compile_plan(spec, plan)
    acc = acc_dtype_for(a.dtype)
    var = spec.coef == "var"
    interp = resolve_interpret(interpret)

    if spec.ndim == 1:
        if a.ndim < 2:
            raise ValueError(f"{spec.name}: need (..., rows, P), got {a.shape}")
        wf = spec.canon_weights(w, a.shape[-1:] if var else None).astype(acc)
        rows = int(np.prod(a.shape[:-1]))
        a2 = a.reshape(rows, a.shape[-1])
        br = block_i or pick_block_rows(rows, a.shape[-1], a.dtype.itemsize)
        return _call_1d(a2, wf, cplan, br, sweeps, interp).reshape(a.shape)

    if a.ndim < 3:
        raise ValueError(f"{spec.name}: need (..., M, N, P), got {a.shape}")
    m, n, p = a.shape[-3:]
    wf = spec.canon_weights(w, (m, n, p) if var else None).astype(acc)
    batch = int(np.prod(a.shape[:-3])) if a.ndim > 3 else 1
    a4 = a.reshape(batch, m, n, p)
    bi, bj, rpath = block_i, block_j, path
    if bi is None:
        rpath, bi, bj_auto = autotune_engine(m, n, p, a.dtype.itemsize,
                                             sweeps=sweeps, plan=cplan,
                                             block_j=bj, path=path)
        bj = bj if bj is not None else bj_auto
    elif rpath == "auto":
        rpath = "stream"            # pinned blocks: stream is strictly
    geom = jnp.array([0, m], jnp.int32)  # fewer HBM bytes at equal blocks
    out = call_3d(a4, wf, geom, cplan, bi, bj, sweeps, interp, rpath,
                  fault=_fault)
    return out.reshape(a.shape)


stencil_apply_jit = jax.jit(
    _stencil_apply_impl,
    static_argnames=("stencil", "block_i", "block_j", "plan", "sweeps",
                     "path", "bc", "interpret", "_fault"))
"""The jitted unguarded executor -- exactly the historical ``stencil_apply``
program (``_fault=None`` adds nothing to the trace); the guarded wrapper and
the degradation ladder call this."""


def stencil_apply(a: jax.Array, w: jax.Array,
                  stencil: Union[str, int, StencilSpec] = "stencil27",
                  block_i: Optional[int] = None,
                  block_j: Optional[int] = None, plan: str = "auto",
                  sweeps: int = 1, path: str = "auto", bc=None,
                  interpret: Optional[bool] = None,
                  guard=None) -> jax.Array:
    """Apply a registered stencil: ``sweeps`` fused Jacobi applications.

    * volumetric specs: ``a`` is ``(..., M, N, P)`` -- leading dims batch;
    * k-only specs: ``a`` is ``(..., P)`` -- leading dims are rows;
    * variable-coefficient specs (``spec.coef == "var"``): ``w`` carries a
      leading ``(n_weights,)`` axis with trailing dims broadcast over the
      domain (``out[x] = sum_t w_t(x) * u[x + off_t]``, coefficients
      evaluated at the output point); the coefficient planes ride the same
      staging as the field -- co-streamed through a second VMEM rotating
      window on the streaming path, replicated views on the other;
    * bf16/f32 inputs accumulate in f32, f64 stays f64 (reference path);
    * ``plan`` picks the execution schedule (``auto`` -> ``factored`` for
      mirror-symmetric specs, ``cse`` otherwise; ``direct`` is the naive
      parity escape hatch) -- same-plan runs execute the identical op walk
      as :func:`stencil_ref` (f64 bit-parity on the reference
      configurations; exact blocking-invariance on integer-valued data --
      see :mod:`.plan` on fma contraction);
    * ``path`` picks the data-movement strategy for volumetric specs:
      ``"stream"`` fetches each input plane from HBM once and carries the
      ``radius * sweeps``-deep halo in VMEM scratch across grid steps (the
      paper's plane-streaming ideal, ~2 transfers per point at any radius);
      ``"replicate"`` re-fetches the ``2r + 1`` halo neighbours per block
      (the parity escape hatch).  ``"auto"`` streams whenever feasible,
      falling back to the replicated roofline choice per shape;
    * ``block_i``/``block_j`` (i-block rows / j-tile columns) default to the
      plan-, path-, and radius-aware cost model, which engages j-tiling
      only when the full N x P slab would blow the VMEM budget;
    * ``bc`` overrides the spec's per-axis-side boundary conditions (any
      :func:`~.spec.as_boundary` spelling -- a kind string, a
      :class:`~.spec.BC` / :func:`~.spec.dirichlet` value, or 3 per-axis
      entries, each optionally a ``(lo, hi)`` pair; hashable forms only,
      it rides through jit as a static argument).  ``None`` keeps the
      spec's own BCs (all-clamp for the plain builtins);
    * ``interpret=None`` (default) interprets the kernel only when no
      compiled Pallas backend exists for the platform (CPU/CI) and compiles
      on TPU (the kernels are Mosaic-TPU-shaped; GPU stays interpreted);
      pass an explicit bool to force either mode;
    * ``guard`` selects runtime verification + the degradation ladder
      (:mod:`.guard`): ``None`` defers to the spec's own ``guard`` field
      (``"off"`` for every builtin -- this call then *is* the historical
      jitted program, byte-identical); a :data:`~.spec.GUARD_KINDS` string
      or a :class:`~.guard.GuardPolicy` runs the checks on the result and,
      on a detected failure or a raised kernel error, retries then walks
      fused -> chained -> stream -> replicate -> oracle, returning the
      first verified result (see ``last_guard_report()``).
    """
    spec = get_stencil(stencil)
    policy_src = spec.guard if guard is None else guard
    if policy_src is None or policy_src == "off":
        return stencil_apply_jit(a, w, stencil, block_i=block_i,
                                 block_j=block_j, plan=plan, sweeps=sweeps,
                                 path=path, bc=bc, interpret=interpret)
    from .guard import as_guard, guarded_apply
    policy = as_guard(policy_src)
    if policy is None:              # e.g. an explicit guard="off" string
        return stencil_apply_jit(a, w, stencil, block_i=block_i,
                                 block_j=block_j, plan=plan, sweeps=sweeps,
                                 path=path, bc=bc, interpret=interpret)
    if bc is not None:
        spec = spec.with_bc(bc)
    return guarded_apply(a, w, spec, policy, block_i=block_i,
                         block_j=block_j, plan=plan, sweeps=sweeps,
                         path=path, interpret=interpret)
