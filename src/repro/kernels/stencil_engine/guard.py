"""Guarded execution: runtime verification + the path-degradation ladder.

The paper's loop is synthesize -> schedule -> simulate -> **verify** ->
select, but until this module the engine only verified at synthesis/test
time: at run time any of the fast paths (stream / replicate / wavefront x
BCs x orderings x sharded deep-halo) could silently diverge, OOM VMEM, or
propagate NaN with no detection and no recovery.  This module is the
runtime half of that loop:

:class:`GuardPolicy` -- what to check
    * **NaN/Inf screening** (``nan``): ``isfinite`` over the output (or a
      sampled set of i-planes).
    * **Weight-sum invariant** (``invariant``): the operator is linear with
      constant row sums, so under all-periodic BCs
      ``sum(out) == sum(w)**sweeps * sum(in)`` to dtype tolerance -- checked
      globally, or per sampled plane via the i-marginal identity
      ``q_out[i] == (W_i ** sweeps)(q_in)[i]`` where ``q`` is the
      plane-marginal sum and ``W_i[di] = sum of taps at offset di`` (a 1-D
      stencil on the marginals).  Non-periodic BCs get the *interior-only
      residual*: over output windows at least ``max(radius, 1)`` from every
      boundary, ``sum(out_window) == sum_t w_t * sum(in_window + off_t)``
      exactly (free space; single-sweep Jacobi).
    * **Sampled-plane oracle spot check** (``oracle``): sampled output
      planes recomputed exactly from thin gathered strips
      (:func:`~.ref.stencil_ref_planes`) -- or, unsampled, a full
      :func:`~.ref.stencil_ref` comparison.

    ``sample = k`` runs every enabled check on ``k`` stratified i-planes
    (first/last valid plane always included): the whole guard then reads
    ``~k * (2 * halo + 2)`` planes per call instead of the full volume --
    :func:`guard_bytes_per_point` is the modeled cost the benchmark's
    guard-overhead row gates at < 10% of the streaming path's
    ``2 * itemsize``.  ``sample = 0`` checks everything (test/debug grade).

Degradation ladder -- what happens on failure
    On a detected check failure or a raised kernel error the guard retries
    the same rung (``retries`` times, default once -- transient faults
    clear), then walks ``wavefront -> fused -> chained -> stream ->
    replicate -> oracle``, re-checking each rung; the final rung is the
    NumPy/jnp oracle itself (trusted by definition -- it is the verifier).
    A rung whose *kernel raised* (after its retry) is blacklisted in
    :mod:`.autotune` (:func:`~.autotune.blacklist_candidate`) so future
    ``auto`` races skip it -- previously a raising candidate was fatal on
    every call.  Every demotion is recorded with its fault class, the path
    taken, and the retry count in :meth:`GuardReport.describe`'s
    ``["guard"]`` record (:func:`last_guard_report` returns the most recent
    one), mirroring ``SweepSelection.describe()["selection"]``.

``guard="off"`` (the default everywhere) bypasses this module entirely --
the public entry points dispatch straight to the historical jitted
programs, byte-identical to the pre-guard engine.  Fault injection hooks
(:data:`_OUT_HOOKS` / :data:`_RUN_HOOKS` / :data:`_KERNEL_HOOKS`) are
installed only by :mod:`.faults`' seedable harness, which is how every
detector and every ladder rung is proven against a real fault in tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from . import autotune
from .kernel import acc_dtype_for
from .plan import compile_plan
from .ref import stencil_ref, stencil_ref_planes
from .spec import GUARD_KINDS, StencilSpec, get_stencil

LADDER = ("wavefront", "fused", "chained", "stream", "replicate", "oracle")

# Fault-injection hooks -- empty unless .faults installs them (tests only).
_OUT_HOOKS: List[Callable] = []     # f(out, ctx) -> out, after a rung runs
_RUN_HOOKS: List[Callable] = []     # f(ctx) -> None, may raise, before a rung
_KERNEL_HOOKS: List[Callable] = []  # f(ctx) -> Optional[KernelFault]

# Monotone counters (tests assert the off path never touches the guard).
CHECK_RUNS = [0]


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """What the guard checks, how much it samples, how hard it retries.

    ``rtol=None`` picks a dtype default (f64 1e-9, f32 1e-4, bf16/f16
    2e-2); integer data is compared exactly.  Hashable/frozen so a policy
    can ride anywhere a spec can."""

    nan: bool = True                # isfinite screen on the output
    invariant: bool = True          # weight-sum conservation check
    oracle: bool = False            # sampled-plane oracle spot check
    sample: int = 4                 # checked i-planes; 0 = full-array checks
    retries: int = 1                # same-rung retries before demotion
    rtol: Optional[float] = None    # None = dtype default

    def __post_init__(self):
        if self.sample < 0 or self.retries < 0:
            raise ValueError("GuardPolicy sample/retries must be >= 0")


def as_guard(guard) -> Optional[GuardPolicy]:
    """Canonicalize a guard spelling: ``None``/``"off"`` -> no guard; a
    :data:`~.spec.GUARD_KINDS` string -> its preset policy; a
    :class:`GuardPolicy` passes through."""
    if guard is None or guard == "off":
        return None
    if isinstance(guard, GuardPolicy):
        return guard
    if guard == "nan":
        return GuardPolicy(nan=True, invariant=False, oracle=False, sample=0)
    if guard == "invariant":
        return GuardPolicy(nan=True, invariant=True, oracle=False)
    if guard == "oracle":
        return GuardPolicy(nan=True, invariant=True, oracle=True)
    if guard == "full":
        return GuardPolicy(nan=True, invariant=True, oracle=True, sample=0)
    raise ValueError(f"unknown guard {guard!r}; expected one of "
                     f"{GUARD_KINDS} or a GuardPolicy")


def default_rtol(dtype) -> float:
    dt = jnp.dtype(dtype)
    if not jnp.issubdtype(dt, jnp.inexact):
        return 0.0
    if dt == jnp.dtype("float64") or dt == jnp.dtype("complex128"):
        return 1e-9
    if dt.itemsize <= 2:            # bf16 / f16
        return 2e-2
    return 1e-4


def guard_bytes_per_point(policy: Optional[GuardPolicy], itemsize: int,
                          m: int, radius: int = 1, sweeps: int = 1,
                          apps: int = 1) -> float:
    """Modeled HBM bytes per output point the *checks* add to one call.

    The sampled checks share their plane reads: the guard gathers each
    sampled output plane once (1 plane) plus, when the invariant or the
    oracle check is on, the ``2 * halo + 1`` input strip feeding it --
    ``sample * (2 * halo + 2)`` plane-reads per ``m``-plane call, amortized
    over ``sweeps`` like the traffic it guards.  Unsampled (``sample=0``)
    checks read the full output (+ the full input for the invariant /
    oracle), which is debug-grade: the benchmark's guard-overhead gate
    prices the default *sampled* policy."""
    if policy is None:
        return 0.0
    needs_strip = policy.invariant or policy.oracle
    full = float(m) * (2.0 if needs_strip else 1.0)
    if policy.sample == 0:
        planes = full
    else:
        h = radius * apps * sweeps
        per_plane = (2 * h + 2) if needs_strip else 1
        # Overlapping strips share reads: oversampling never costs more
        # than one full pass over output (+ input, for the strip checks).
        planes = min(min(policy.sample, m) * float(per_plane), full)
    return planes / m * itemsize / sweeps


# ---------------------------------------------------------------------------
# Checks.
# ---------------------------------------------------------------------------

def _sampled_planes(policy: GuardPolicy, m: int, h: int,
                    periodic_i: bool) -> Optional[np.ndarray]:
    """The checked i-plane indices: ``None`` = full-array checks; an empty
    array when nothing is sampleable (halo swallows the interior).  First
    and last valid planes always included; the rest stratified."""
    if policy.sample <= 0:
        return None
    lo, hi = (0, m - 1) if periodic_i else (h, m - 1 - h)
    if hi < lo:
        return np.array([], dtype=int)
    k = min(policy.sample, hi - lo + 1)
    return np.unique(np.round(np.linspace(lo, hi, k)).astype(int))


def _close(got, want, rtol: float) -> bool:
    got = jnp.asarray(got)
    want = jnp.asarray(want)
    if rtol == 0.0:
        return bool(jnp.array_equal(got, want))
    scale = float(jnp.max(jnp.abs(want))) if want.size else 0.0
    return bool(jnp.allclose(got, want, rtol=rtol,
                             atol=rtol * max(scale, 1e-30)))


def _all_periodic(spec: StencilSpec) -> bool:
    return all(spec.bc[ax][0].kind == "periodic"
               for ax in range(3 - spec.ndim, 3))


def _nan_check(out, spec: StencilSpec, planes) -> Dict[str, object]:
    if not jnp.issubdtype(out.dtype, jnp.inexact):
        return {"check": "nan", "ok": True, "skipped": True,
                "detail": "integer dtype is always finite"}
    view = out
    if planes is not None and spec.ndim == 3:
        if planes.size == 0:
            return {"check": "nan", "ok": True, "skipped": True,
                    "detail": "no sampleable planes"}
        view = jnp.take(out, jnp.asarray(planes), axis=out.ndim - 3)
    ok = bool(jnp.isfinite(view).all())
    return {"check": "nan", "ok": ok, "skipped": False,
            "detail": "" if ok else "non-finite values in the output"}


def _marginal_weights(spec: StencilSpec, wf) -> np.ndarray:
    """``W_i[di + r_i]``: the i-marginal 1-D stencil -- summing a
    (wrap-around) plane marginal commutes with the operator."""
    r = spec.radius[0]
    w = np.asarray(wf, dtype=np.float64)
    wi = np.zeros(2 * r + 1)
    for (di, _, _), t in zip(spec.offsets, spec.w_index):
        wi[di + r] += w[t]
    return wi


def _invariant_check(out, a, wf, spec: StencilSpec, sweeps: int,
                     rtol: float, planes) -> Dict[str, object]:
    skip = None
    if spec.coef != "const":
        skip = "variable coefficients have no constant row sum"
    elif spec.ordering != "jacobi":
        skip = "red-black half-sweeps mix old and new values"
    elif not jnp.issubdtype(out.dtype, jnp.inexact):
        skip = "integer data is covered by the exact checks"
    if skip:
        return {"check": "invariant", "ok": True, "skipped": True,
                "detail": skip}
    sum_dt = acc_dtype_for(out.dtype)
    w = np.asarray(wf, dtype=np.float64)
    sw = float(w[list(spec.w_index)].sum())
    sw_abs = float(np.abs(w[list(spec.w_index)]).sum())
    if _all_periodic(spec):
        if planes is None or spec.ndim != 3:
            so = float(jnp.sum(out.astype(sum_dt)))
            si = float(jnp.sum(a.astype(sum_dt)))
            sa = float(jnp.sum(jnp.abs(a.astype(sum_dt))))
            pred = (sw ** sweeps) * si
            tol = rtol * max((sw_abs ** sweeps) * sa, 1e-30)
            ok = abs(so - pred) <= tol
            return {"check": "invariant", "ok": ok, "skipped": False,
                    "detail": "" if ok else
                    f"global weight-sum drift |{so:g} - {pred:g}| > {tol:g}"}
        if planes.size == 0:
            return {"check": "invariant", "ok": True, "skipped": True,
                    "detail": "no sampleable planes"}
        # Per sampled plane: the i-marginal identity on a wrapped strip.
        wi = _marginal_weights(spec, wf)
        r = spec.radius[0]
        h = r * sweeps
        m = out.shape[-3]
        axis = out.ndim - 3
        other = tuple(ax for ax in range(out.ndim) if ax != axis)
        for i in planes:
            idx = jnp.asarray(np.arange(i - h, i + h + 1) % m)
            strip = jnp.take(a, idx, axis=axis).astype(sum_dt)
            q = np.asarray(jnp.sum(strip, axis=other), dtype=np.float64)
            qa = np.abs(q)
            for _ in range(sweeps):
                q = np.convolve(q, wi[::-1], mode="valid")
                qa = np.convolve(qa, np.abs(wi)[::-1], mode="valid")
            qo = float(jnp.sum(jnp.take(out, jnp.asarray([int(i)]),
                                        axis=axis).astype(sum_dt)))
            tol = rtol * max(float(qa[0]), 1e-30)
            if abs(qo - float(q[0])) > tol:
                return {"check": "invariant", "ok": False, "skipped": False,
                        "detail": f"plane {int(i)}: marginal weight-sum "
                                  f"drift |{qo:g} - {float(q[0]):g}| > "
                                  f"{tol:g}"}
        return {"check": "invariant", "ok": True, "skipped": False,
                "detail": ""}
    # Non-periodic BCs: interior-only residual, exact in free space for a
    # single Jacobi application; deeper sweeps are the oracle check's job.
    if sweeps != 1 or spec.ndim != 3:
        return {"check": "invariant", "ok": True, "skipped": True,
                "detail": "interior residual covers single volumetric "
                          "Jacobi sweeps; rely on the oracle check"}
    m, n, p = out.shape[-3:]
    margins = []
    for ax in range(3):
        r = spec.radius[ax]
        lo, hi = spec.bc[ax]
        margins.append((max(r, 1) if lo.kind == "clamp" else r,
                        max(r, 1) if hi.kind == "clamp" else r))
    (ilo, ihi), (jlo, jhi), (klo, khi) = margins
    if planes is None:
        cand = np.arange(max(ilo, spec.radius[0]), m - max(ihi, spec.radius[0]))
    else:
        cand = planes[(planes >= max(ilo, spec.radius[0]))
                      & (planes < m - max(ihi, spec.radius[0]))]
    if (cand.size == 0 or jlo + jhi + spec.radius[1] * 2 >= n
            or klo + khi + spec.radius[2] * 2 >= p):
        return {"check": "invariant", "ok": True, "skipped": True,
                "detail": "domain too small for an interior window"}
    axis = out.ndim - 3
    w64 = np.asarray(wf, dtype=np.float64)
    for i in cand:
        i = int(i)
        pred = 0.0
        scale = 0.0
        for (di, dj, dk), t in zip(spec.offsets, spec.w_index):
            win = jnp.take(a, jnp.asarray([i + di]), axis=axis)[
                ..., 0, jlo + dj:n - jhi + dj, klo + dk:p - khi + dk]
            s = float(jnp.sum(win.astype(acc_dtype_for(out.dtype))))
            sa = float(jnp.sum(jnp.abs(win.astype(
                acc_dtype_for(out.dtype)))))
            pred += float(w64[t]) * s
            scale += abs(float(w64[t])) * sa
        qo = float(jnp.sum(jnp.take(out, jnp.asarray([i]), axis=axis)[
            ..., 0, jlo:n - jhi, klo:p - khi].astype(
                acc_dtype_for(out.dtype))))
        tol = rtol * max(scale, 1e-30)
        if abs(qo - pred) > tol:
            return {"check": "invariant", "ok": False, "skipped": False,
                    "detail": f"plane {i}: interior residual "
                              f"|{qo:g} - {pred:g}| > {tol:g}"}
    return {"check": "invariant", "ok": True, "skipped": False, "detail": ""}


def _oracle_check(out, a, w, spec: StencilSpec, sweeps: int, rtol: float,
                  planes, plan: str) -> Dict[str, object]:
    if spec.coef != "const":
        return {"check": "oracle", "ok": True, "skipped": True,
                "detail": "strip oracle needs constant coefficients"}
    if planes is None or spec.ndim != 3:
        ref = stencil_ref(a, w, spec, sweeps=sweeps, plan=plan)
        ok = _close(out, ref, rtol)
        return {"check": "oracle", "ok": ok, "skipped": False,
                "detail": "" if ok else "full oracle mismatch"}
    if planes.size == 0:
        return {"check": "oracle", "ok": True, "skipped": True,
                "detail": "no sampleable planes"}
    pred = stencil_ref_planes(a, w, spec, planes, sweeps=sweeps, plan=plan)
    got = jnp.take(out, jnp.asarray(planes), axis=out.ndim - 3)
    ok = _close(got, pred, rtol)
    return {"check": "oracle", "ok": ok, "skipped": False,
            "detail": "" if ok else
            f"sampled planes {list(map(int, planes))} mismatch the strip "
            f"oracle"}


def run_guard_checks(out, a, w, spec: StencilSpec, sweeps: int,
                     policy: GuardPolicy,
                     plan: str = "auto") -> List[Dict[str, object]]:
    """Run the enabled checks on one call's (input, output) pair; returns
    one record per enabled check: ``{"check", "ok", "skipped", "detail"}``.
    Exposed for tests and for external callers guarding their own
    executors (the sharded guard routes through here too)."""
    CHECK_RUNS[0] += 1
    rtol = policy.rtol if policy.rtol is not None else default_rtol(out.dtype)
    h = spec.radius[0] * spec.sweep_apps * sweeps if spec.ndim == 3 else 0
    periodic_i = spec.ndim == 3 and spec.bc[0][0].kind == "periodic"
    planes = (None if spec.ndim != 3
              else _sampled_planes(policy, out.shape[-3], h, periodic_i))
    results = []
    if policy.nan:
        results.append(_nan_check(out, spec, planes))
    if policy.invariant:
        wf = (spec.canon_weights(w) if spec.coef == "const" else None)
        results.append(_invariant_check(out, a, wf, spec, sweeps, rtol,
                                        planes))
    if policy.oracle:
        results.append(_oracle_check(out, a, w, spec, sweeps, rtol, planes,
                                     plan))
    return results


# ---------------------------------------------------------------------------
# The degradation ladder.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GuardCtx:
    """What a rung execution looks like to the fault hooks."""
    rung: str
    path: str
    attempt: int
    spec: StencilSpec
    sweeps: int
    entry: str                      # "apply" | "driver" | "sharded"


@dataclasses.dataclass
class GuardReport:
    """The run record of one guarded call (``describe()["guard"]``)."""

    spec: str
    sweeps: int
    entry: str
    start: str
    policy: GuardPolicy
    final: Optional[str] = None
    attempts: List[Dict[str, object]] = dataclasses.field(default_factory=list)
    demotions: List[Dict[str, object]] = dataclasses.field(
        default_factory=list)
    blacklisted: List[Tuple[str, str]] = dataclasses.field(
        default_factory=list)

    def describe(self) -> Dict[str, object]:
        """Machine-readable guard record, shaped like
        ``SweepSelection.describe()["selection"]``: the policy knobs, every
        attempt with its check verdicts, and every demotion with fault
        class / path taken / retry count."""
        return {"guard": {
            "spec": self.spec, "sweeps": self.sweeps, "entry": self.entry,
            "start": self.start, "final": self.final,
            "policy": dataclasses.asdict(self.policy),
            "attempts": list(self.attempts),
            "demotions": list(self.demotions),
            "blacklisted": [{"kind": k, "value": v}
                            for k, v in self.blacklisted],
        }}


_LAST_REPORT: List[Optional[GuardReport]] = [None]


def last_guard_report() -> Optional[GuardReport]:
    """The :class:`GuardReport` of the most recent guarded call (any entry
    point), or ``None`` when nothing guarded has run yet."""
    return _LAST_REPORT[0]


class GuardError(RuntimeError):
    """Every ladder rung failed -- including the oracle."""


def _fault_label(exc: BaseException) -> str:
    return f"exception:{type(exc).__name__}"


def _kernel_fault(ctx: GuardCtx):
    for hook in _KERNEL_HOOKS:
        f = hook(ctx)
        if f is not None:
            return f
    return None


def run_ladder(a, w, spec: StencilSpec, policy: GuardPolicy, sweeps: int,
               start: str, runner: Callable, entry: str,
               plan: str = "auto",
               feasible: Optional[Callable[[str], bool]] = None):
    """Execute ``runner(rung, ctx)`` down the ladder from ``start``.

    Checks every non-oracle rung's output with ``run_guard_checks``,
    retries a failed rung ``policy.retries`` times, demotes past it
    otherwise, and blacklists a rung whose kernel *raised* after its retry.
    Returns the first output that passes (the oracle's unconditionally) and
    stores the :class:`GuardReport`."""
    rungs = [r for r in LADDER[LADDER.index(start):]
             if feasible is None or r == "oracle" or feasible(r)]
    report = GuardReport(spec=spec.name, sweeps=sweeps, entry=entry,
                         start=start, policy=policy)
    _LAST_REPORT[0] = report
    last_exc = None
    for pos, rung in enumerate(rungs):
        fault = None
        retries_used = 0
        for attempt in range(policy.retries + 1):
            ctx = GuardCtx(rung=rung, path=rung, attempt=attempt, spec=spec,
                           sweeps=sweeps, entry=entry)
            rec = {"rung": rung, "attempt": attempt, "checks": [],
                   "fault": None}
            report.attempts.append(rec)
            try:
                for hook in _RUN_HOOKS:
                    hook(ctx)
                out = runner(rung, ctx)
                for hook in _OUT_HOOKS:
                    out = hook(out, ctx)
            except Exception as exc:  # noqa: BLE001 - any kernel failure
                fault = _fault_label(exc)
                rec["fault"] = fault
                last_exc = exc
                retries_used = attempt
                continue
            if rung == "oracle":
                rec["checks"] = [{"check": "oracle", "ok": True,
                                  "skipped": True,
                                  "detail": "the oracle is the verifier"}]
                report.final = rung
                return out
            checks = run_guard_checks(out, a, w, spec, sweeps, policy, plan)
            rec["checks"] = checks
            bad = [c for c in checks if not c["ok"]]
            if not bad:
                report.final = rung
                return out
            fault = bad[0]["check"]
            rec["fault"] = fault
            retries_used = attempt
        # Retries exhausted: demote (and blacklist a raising candidate --
        # a reproducible crash; check failures may be transient data
        # faults, so they demote without condemning the path).
        nxt = rungs[pos + 1] if pos + 1 < len(rungs) else None
        report.demotions.append({"from": rung, "to": nxt, "fault": fault,
                                 "retries": retries_used})
        if fault and fault.startswith("exception:") and rung != "oracle":
            if rung in ("wavefront", "fused", "chained"):
                autotune.blacklist_candidate(spec.name, mode=rung)
                report.blacklisted.append(("mode", rung))
            else:
                autotune.blacklist_candidate(spec.name, path=rung)
                report.blacklisted.append(("path", rung))
    raise GuardError(
        f"{spec.name}: every ladder rung from {start!r} failed "
        f"(demotions: {report.demotions})") from last_exc


# ---------------------------------------------------------------------------
# Guarded entry points (reached from ops/sweeps/sharded when guard != off).
# ---------------------------------------------------------------------------

def _strip(spec: StencilSpec) -> StencilSpec:
    """The spec with the guard field removed, so plans/kernels/jit caches
    are shared with unguarded calls."""
    return spec.with_guard("off") if spec.guard != "off" else spec


def resolve_guard(stencil, guard) -> Tuple[StencilSpec,
                                           Optional[GuardPolicy]]:
    """(spec, active policy): an explicit ``guard`` argument overrides the
    spec's own ``guard`` field; ``None`` defers to it."""
    spec = get_stencil(stencil)
    return spec, as_guard(spec.guard if guard is None else guard)


def _wavefront_ok(spec: StencilSpec, a, sweeps: int,
                  block_j) -> bool:
    if spec.ndim != 3 or spec.coef != "const" or block_j is not None:
        return False
    h = spec.radius[0] * spec.sweep_apps * sweeps
    return not (spec.bc[0][0].kind == "periodic" and h > a.shape[-3])


def guarded_apply(a, w, spec: StencilSpec, policy: GuardPolicy, *,
                  block_i=None, block_j=None, plan: str = "auto",
                  sweeps: int = 1, path: str = "auto", interpret=None):
    """The guarded body of ``stencil_apply``: start at the fused rung (one
    call IS the fused execution), walk down on failure."""
    from .ops import stencil_apply_jit
    spec = _strip(spec)

    def runner(rung: str, ctx: GuardCtx):
        kf = _kernel_fault(ctx)
        if rung == "oracle":
            return stencil_ref(a, w, spec, sweeps=sweeps, plan=plan)
        if rung == "fused":
            return stencil_apply_jit(a, w, spec, block_i=block_i,
                                     block_j=block_j, plan=plan,
                                     sweeps=sweeps, path=path,
                                     interpret=interpret, _fault=kf)
        rpath = {"chained": path, "stream": "stream",
                 "replicate": "replicate"}[rung]
        u = a
        for _ in range(sweeps):
            u = stencil_apply_jit(u, w, spec, block_i=block_i,
                                  block_j=block_j, plan=plan, sweeps=1,
                                  path=rpath, interpret=interpret, _fault=kf)
        return u

    return run_ladder(a, w, spec, policy, sweeps, "fused", runner, "apply",
                      plan=plan)


def guarded_driver(a, w, spec: StencilSpec, policy: GuardPolicy, *,
                   sweeps: int = 1, mode: str = "auto", block_i=None,
                   block_j=None, plan: str = "auto", path: str = "auto",
                   interpret=None):
    """The guarded body of ``stencil_sweep_driver``: start at the raced (or
    pinned) mode's rung and walk the full ladder."""
    from .ops import stencil_apply_jit
    from .sweeps import stencil_wavefront
    spec = _strip(spec)
    start = mode
    if mode == "auto":
        if sweeps == 1 or spec.ndim != 3:
            start = "fused"
        else:
            cplan = compile_plan(spec, plan)
            m, n, p = a.shape[-3:]
            sel = autotune.autotune_sweeps(m, n, p, a.dtype.itemsize, sweeps,
                                           cplan, block_j=block_j, path=path)
            start = sel.mode
    if start == "wavefront" and not _wavefront_ok(spec, a, sweeps, block_j):
        start = "fused"

    def runner(rung: str, ctx: GuardCtx):
        kf = _kernel_fault(ctx)
        if rung == "oracle":
            return stencil_ref(a, w, spec, sweeps=sweeps, plan=plan)
        if rung == "wavefront":
            return stencil_wavefront(a, w, spec, block_i=block_i,
                                     sweeps=sweeps, plan=plan,
                                     interpret=interpret)
        if rung == "fused":
            return stencil_apply_jit(a, w, spec, block_i=block_i,
                                     block_j=block_j, plan=plan,
                                     sweeps=sweeps, path=path,
                                     interpret=interpret, _fault=kf)
        rpath = {"chained": path, "stream": "stream",
                 "replicate": "replicate"}[rung]
        u = a
        for _ in range(sweeps):
            u = stencil_apply_jit(u, w, spec, block_i=block_i,
                                  block_j=block_j, plan=plan, sweeps=1,
                                  path=rpath, interpret=interpret, _fault=kf)
        return u

    def feasible(rung: str) -> bool:
        if rung == "wavefront":
            return start == "wavefront"
        return True

    return run_ladder(a, w, spec, policy, sweeps, start, runner, "driver",
                      plan=plan, feasible=feasible)


def guarded_sharded(a, w, spec: StencilSpec, policy: GuardPolicy, *,
                    mesh=None, axis: str = "data", block_i=None,
                    block_j=None, plan: str = "auto", sweeps: int = 1,
                    path: str = "auto", mode: str = "fused", interpret=None,
                    shard_plan=None, axes=None, overlap: str = "off"):
    """The guarded body of ``stencil_sharded``: the sharded wavefront /
    fused rungs first, then *off the sharded path entirely* -- the chained /
    stream / replicate rungs re-run single-device, so a corrupted halo
    exchange cannot reach them.  ``axes``/``overlap`` ride through to the
    sharded rungs (the multi-axis grid and the compute/communication
    overlap are properties of the sharded execution only; the
    single-device recovery rungs never exchange)."""
    from .ops import stencil_apply_jit
    from .sharded import stencil_sharded
    spec = _strip(spec)
    start = "wavefront" if mode == "wavefront" else "fused"
    if start == "wavefront" and not _wavefront_ok(spec, a, sweeps, block_j):
        start = "fused"

    def runner(rung: str, ctx: GuardCtx):
        if rung == "oracle":
            return stencil_ref(a, w, spec, sweeps=sweeps, plan=plan)
        if rung in ("wavefront", "fused"):
            return stencil_sharded(a, w, spec, mesh=mesh, axis=axis,
                                   block_i=block_i, block_j=block_j,
                                   plan=plan, sweeps=sweeps, path=path,
                                   mode=rung, interpret=interpret,
                                   shard_plan=shard_plan, guard="off",
                                   axes=axes, overlap=overlap)
        rpath = {"chained": path, "stream": "stream",
                 "replicate": "replicate"}[rung]
        kf = _kernel_fault(ctx)
        u = a
        for _ in range(sweeps):
            u = stencil_apply_jit(u, w, spec, plan=plan, sweeps=1,
                                  path=rpath, interpret=interpret, _fault=kf)
        return u

    def feasible(rung: str) -> bool:
        if rung == "wavefront":
            return start == "wavefront"
        return True

    return run_ladder(a, w, spec, policy, sweeps, start, runner, "sharded",
                      plan=plan, feasible=feasible)
