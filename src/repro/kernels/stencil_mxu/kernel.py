"""Beyond-paper: the 27-point stencil as banded matmuls on the MXU.

The paper notes (sect. 6.2) that "free flops" change the optimal kernel
shape.  On TPU the MXU (197 TFLOP/s) idles during VPU stencils (~3 TFLOP/s
elementwise): recast the k-direction 3-point as multiplication by a
tridiagonal band matrix T_c[k',k] = w(c,|k'-k|), grouped by the four
(|di|,|dj|) symmetry classes:

    R = sum_c  S_c @ T_c,   S_c = plane-sum of the class (cheap VPU adds)

Per point: 4 class-sums (5 VPU adds) + 4 (rows x P x P) matmuls = 8P MXU
flops vs 54 VPU flops.  At P=128 the MXU form trades 19x more flops for
~60x higher unit throughput => ~3x napkin speedup, and the (8k, 128m)-
aligned matmuls are exactly the MXU's native tile.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..stencil_engine.common import interior_mask, shifted_planes


def band_matrices(w: jax.Array, p: int) -> jax.Array:
    """(4, P, P) tridiagonal band matrices, one per (|di|,|dj|) class."""
    eye = jnp.eye(p, dtype=jnp.float32)
    off = (jnp.eye(p, k=1, dtype=jnp.float32)
           + jnp.eye(p, k=-1, dtype=jnp.float32))
    mats = []
    for (di, dj) in ((0, 0), (0, 1), (1, 0), (1, 1)):
        mats.append(w[di, dj, 0] * eye + w[di, dj, 1] * off)
    return jnp.stack(mats)


def stencil27_mxu_kernel(a_prev, a_cur, a_next, t_ref, o_ref, *, bi: int,
                         m_total: int):
    i_blk = pl.program_id(0)
    t = t_ref[...]                                   # (4, P, P)
    up, mid, down = shifted_planes(a_prev[...].astype(jnp.float32),
                                   a_cur[...].astype(jnp.float32),
                                   a_next[...].astype(jnp.float32))
    ud = up + down
    s00 = mid
    s01 = jnp.roll(mid, 1, axis=-2) + jnp.roll(mid, -1, axis=-2)
    s10 = ud
    s11 = jnp.roll(ud, 1, axis=-2) + jnp.roll(ud, -1, axis=-2)
    # four (BI*N, P) x (P, P) matmuls -- MXU-native
    acc = (jax.lax.dot_general(s00, t[0], (((2,), (0,)), ((), ())))
           + jax.lax.dot_general(s01, t[1], (((2,), (0,)), ((), ())))
           + jax.lax.dot_general(s10, t[2], (((2,), (0,)), ((), ())))
           + jax.lax.dot_general(s11, t[3], (((2,), (0,)), ((), ()))))
    n, p = mid.shape[1], mid.shape[2]
    mask = interior_mask(bi, n, p, i_blk, m_total)
    o_ref[...] = jnp.where(mask, acc, 0.0).astype(o_ref.dtype)
