from .ops import stencil27_mxu  # noqa: F401
from .ref import stencil27_mxu_ref  # noqa: F401
