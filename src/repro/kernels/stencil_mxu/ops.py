"""Jitted entry point for the MXU-form 27-point stencil."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..stencil_engine.autotune import pick_block_i
from ..stencil_engine.common import stencil_pallas_call
from .kernel import band_matrices, stencil27_mxu_kernel


@functools.partial(jax.jit, static_argnames=("block_i", "interpret"))
def stencil27_mxu(a: jax.Array, w: jax.Array, block_i: int | None = None,
                  interpret: bool = True) -> jax.Array:
    """27-point stencil via banded MXU matmuls; w: (2, 2, 2) as stencil27.

    w[.,.,0] is the k-centre weight, w[.,.,1] the k-edge weight.
    """
    if block_i is None:
        block_i = pick_block_i(*a.shape, a.dtype.itemsize)
    t = band_matrices(w.astype(jnp.float32), a.shape[-1])
    return stencil_pallas_call(stencil27_mxu_kernel, a, t, block_i, interpret)
