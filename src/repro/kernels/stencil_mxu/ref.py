"""Oracle for the MXU-form 27-point stencil == the standard 27-point ref."""

from ..stencil_engine.compat import stencil27_ref as stencil27_mxu_ref  # noqa: F401
