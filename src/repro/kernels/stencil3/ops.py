"""Jitted public entry point for the batched 1-D 3-point Pallas stencil."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .kernel import stencil3_kernel


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stencil3(a: jax.Array, w: jax.Array, block_rows: int | None = None,
             interpret: bool = True) -> jax.Array:
    """Apply the symmetric 3-point stencil along the last axis.

    ``a``: (rows, P) (flatten higher dims first); ``w`` = (w_edge, w_center).
    """
    rows, p = a.shape
    if block_rows is None:
        block_rows = rows
        for cand in (256, 128, 64, 32, 16, 8):
            if rows % cand == 0 and cand * p * a.dtype.itemsize <= 4 << 20:
                block_rows = cand
                break
    if rows % block_rows != 0:
        raise ValueError(f"block_rows {block_rows} must divide rows={rows}")
    w = w.astype(jnp.float32)
    return pl.pallas_call(
        stencil3_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
                  pl.BlockSpec(w.shape, lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, w)
