from .ops import stencil3  # noqa: F401
from .ref import stencil3_ref  # noqa: F401
