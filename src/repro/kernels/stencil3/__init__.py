"""Thin shim: the 3-point stencil lives in ``repro.kernels.stencil_engine``
(registry name ``"stencil3"``; wrapper built in ``repro.kernels._compat``)."""

from .._compat import stencil3, stencil3_ref  # noqa: F401
