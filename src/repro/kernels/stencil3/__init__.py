"""Thin shim: the 3-point stencil lives in ``repro.kernels.stencil_engine``
(registry name ``"stencil3"``)."""

from ..stencil_engine.compat import stencil3, stencil3_ref  # noqa: F401
