"""Pure-jnp oracle for the symmetric 3-point stencil along the last axis."""

from __future__ import annotations

import jax.numpy as jnp


def stencil3_ref(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """w = (w_edge, w_center); boundary (first/last k) left zero."""
    core = w[0] * a[..., :-2] + w[1] * a[..., 1:-1] + w[0] * a[..., 2:]
    return jnp.zeros_like(a).at[..., 1:-1].set(core)
