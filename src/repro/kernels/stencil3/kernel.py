"""Pallas TPU kernel for the 1-D 3-point stencil over batched rows.

The paper's 3-point building block: rows on the sublane axis (the jam), k on
the lane axis.  Neighbours are lane shifts of the resident block -- the
load-copy strategy; no halo is needed because each block holds whole rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def stencil3_kernel(a_ref, w_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)
    w = w_ref[...]
    acc = (w[1] * a
           + w[0] * (jnp.roll(a, 1, axis=-1) + jnp.roll(a, -1, axis=-1)))
    p = a.shape[-1]
    kk = jax.lax.broadcasted_iota(jnp.int32, a.shape, a.ndim - 1)
    mask = (kk > 0) & (kk < p - 1)
    o_ref[...] = jnp.where(mask, acc, 0.0).astype(o_ref.dtype)
