from .ops import mamba_scan  # noqa: F401
from .ref import mamba_scan_ref  # noqa: F401
