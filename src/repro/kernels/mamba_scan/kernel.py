"""Pallas TPU kernel for the Mamba-1 selective scan.

Streaming structure following the paper's steady-state loop: the grid walks
(batch, L/chunk) with the chunk axis sequential; the carried state h lives in
a VMEM scratch buffer across grid steps (the PPC450 kernels' persistent
stream registers).  Within a chunk the linear recurrence is solved by an
associative scan over (decay, input) pairs -- log-depth dense VPU work with
decays in (0, 1] (numerically stable), leaving one sequential dependency per
chunk instead of per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def mamba_scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref,
                      h_scratch):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0].astype(jnp.float32)        # (Lc, D)
    dt = dt_ref[0].astype(jnp.float32)      # (Lc, D)
    a = a_ref[...].astype(jnp.float32)      # (D, N)
    bm = b_ref[0].astype(jnp.float32)       # (Lc, N)
    c = c_ref[0].astype(jnp.float32)        # (Lc, N)
    d = d_ref[...].astype(jnp.float32)      # (D,)
    h0 = h_scratch[...]                     # (D, N)

    # per-step decay and driven input: h_t = decay_t * h_{t-1} + u_t
    decay = jnp.exp(dt[:, :, None] * a[None])               # (Lc, D, N)
    u = (dt * x)[:, :, None] * bm[:, None, :]               # (Lc, D, N)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    cum_a, cum_b = jax.lax.associative_scan(combine, (decay, u), axis=0)
    h = cum_a * h0[None] + cum_b                            # (Lc, D, N)

    y = jnp.einsum("ldn,ln->ld", h, c) + d[None] * x
    y_ref[0] = y.astype(y_ref.dtype)
    h_scratch[...] = h[-1]
