"""Jitted public entry point for the Mamba selective-scan Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams
from .kernel import mamba_scan_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
               c: jax.Array, d: jax.Array, chunk: int = 64,
               interpret: bool = True) -> jax.Array:
    """Selective scan: x, dt (B, L, D); a (D, N); bm, c (B, L, N); d (D,)."""
    bsz, seq, dim = x.shape
    n = a.shape[1]
    chunk = min(chunk, seq)
    if seq % chunk != 0:
        raise ValueError(f"chunk {chunk} must divide L={seq}")
    nchunk = seq // chunk
    grid = (bsz, nchunk)
    ld = lambda b, i: (b, i, 0)
    return pl.pallas_call(
        mamba_scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dim), ld),
            pl.BlockSpec((1, chunk, dim), ld),
            pl.BlockSpec((dim, n), lambda b, i: (0, 0)),
            pl.BlockSpec((1, chunk, n), ld),
            pl.BlockSpec((1, chunk, n), ld),
            pl.BlockSpec((dim,), lambda b, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dim), ld),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((dim, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, bm, c, d)
