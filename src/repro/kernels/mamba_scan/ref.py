"""Pure-jnp oracle for the Mamba-1 selective scan (diagonal A).

h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t          (per channel d, state n)
y_t = C_t . h_t + D * x_t

Shapes: x, dt (B, L, D); A (D, N); Bm, C (B, L, N); D (D,).
This is a streaming numerical kernel in the paper's exact sense: O(L) work
over sequentially accessed data with a small carried state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(x, dt, a, bm, c, d):
    bsz, seq, dim = x.shape
    n = a.shape[1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t[:, :, None] * a[None])          # (B, D, N)
        h = decay * h + (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + d[None] * x_t
        return h, y

    h0 = jnp.zeros((bsz, dim, n), dtype=jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
