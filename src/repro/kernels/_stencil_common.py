"""Back-compat shim: the shared stencil machinery moved to
``repro.kernels.stencil_engine`` (``common`` for the Pallas plumbing,
``autotune`` for block selection); ``repro.kernels._compat`` hosts the
re-export table."""

from ._compat import (pick_block_i, interior_mask,  # noqa: F401
                      shifted_planes, stencil_pallas_call)
