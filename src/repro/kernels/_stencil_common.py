"""Back-compat shim: the shared stencil machinery moved to
``repro.kernels.stencil_engine`` (``common`` for the Pallas plumbing,
``autotune`` for block selection)."""

from .stencil_engine.autotune import pick_block_i  # noqa: F401
from .stencil_engine.common import (interior_mask, shifted_planes,  # noqa: F401
                                    stencil_pallas_call)
