"""Shared machinery for Pallas 3-D stencil kernels (TPU adaptation layer).

The paper's unroll-and-jam becomes VMEM block tiling: one grid step computes a
(BI, N, P) output tile; the i-direction halo is realized by passing the input
array three times with i-shifted BlockSpec index maps (clamped at the array
ends -- the affected rows are Dirichlet boundary and masked to zero).  The
k (fastest) dimension lies on the 128-wide lane axis, the paper's two-way
SIMD packing scaled to the VPU's vector width; unaligned k +- 1 neighbours are
in-VMEM lane shifts (the load-copy strategy -- TPUs have no partial-register
mutate).  Grid iteration along i is the pipelined steady-state stream: Pallas
double-buffers the HBM->VMEM DMAs against VPU compute exactly where the
PPC450 kernels interleaved LSU and FPU slots.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def shifted_planes(prev_blk: jax.Array, cur: jax.Array, nxt_blk: jax.Array):
    """Rows (i-1, i, i+1) for every row i of the current block."""
    up = jnp.concatenate([prev_blk[-1:], cur[:-1]], axis=0)
    down = jnp.concatenate([cur[1:], nxt_blk[:1]], axis=0)
    return up, cur, down


def sym_neighbor_sums(plane: jax.Array):
    """(centre, j-edge sum, k-edge sum, jk-corner sum) with zero boundaries.

    All four share the plane's shape; j/k boundary entries are garbage that
    the caller masks (Dirichlet).
    """
    jm = jnp.roll(plane, 1, axis=-2)
    jp = jnp.roll(plane, -1, axis=-2)
    km = jnp.roll(plane, 1, axis=-1)
    kp = jnp.roll(plane, -1, axis=-1)
    cj = jm + jp
    ck = km + kp
    cjk = (jnp.roll(jm, 1, axis=-1) + jnp.roll(jm, -1, axis=-1)
           + jnp.roll(jp, 1, axis=-1) + jnp.roll(jp, -1, axis=-1))
    return plane, cj, ck, cjk


def interior_mask(bi: int, n: int, p: int, i_blk, m_total: int) -> jax.Array:
    """True on interior points of the global (M, N, P) grid for this block."""
    gi = i_blk * bi + jax.lax.broadcasted_iota(jnp.int32, (bi, n, p), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (bi, n, p), 1)
    kk = jax.lax.broadcasted_iota(jnp.int32, (bi, n, p), 2)
    return ((gi > 0) & (gi < m_total - 1)
            & (jj > 0) & (jj < n - 1)
            & (kk > 0) & (kk < p - 1))


def stencil_pallas_call(kernel_body: Callable, a: jax.Array, weights: jax.Array,
                        bi: int, interpret: bool) -> jax.Array:
    """Common pallas_call wiring: 3 shifted views of ``a`` + weights in SMEM."""
    m, n, p = a.shape
    if m % bi != 0:
        raise ValueError(f"block size {bi} must divide M={m}")
    nblk = m // bi
    block = (bi, n, p)
    grid = (nblk,)
    in_specs = [
        pl.BlockSpec(block, lambda i: (jnp.maximum(i - 1, 0), 0, 0)),
        pl.BlockSpec(block, lambda i: (i, 0, 0)),
        pl.BlockSpec(block, functools.partial(
            lambda i, top: (jnp.minimum(i + 1, top), 0, 0), top=nblk - 1)),
        pl.BlockSpec(weights.shape, lambda i: tuple(0 for _ in weights.shape)),
    ]
    return pl.pallas_call(
        functools.partial(kernel_body, bi=bi, m_total=m),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(block, lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, a, a, weights)


def pick_block_i(m: int, n: int, p: int, itemsize: int,
                 vmem_budget: int = 8 * 1024 * 1024) -> int:
    """Model-driven jam-factor selection (the paper's Table-2 reasoning on
    TPU terms): the largest i-block whose 4 resident tiles + output fit the
    VMEM budget, preferring multiples of 8 (sublane count)."""
    per_row = n * p * itemsize
    max_bi = max(1, vmem_budget // (5 * per_row))
    bi = min(m, max_bi)
    for cand in range(bi, 0, -1):
        if m % cand == 0 and (cand % 8 == 0 or cand < 8):
            return cand
    return 1
