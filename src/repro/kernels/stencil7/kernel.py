"""Pallas TPU kernel for the symmetric 7-point stencil.

The centre plane carries the k-direction 3-point plus the j-edge sum; the
i +- 1 planes contribute only their centres (the paper's aligned-quad side
streams).  7 FMAs per point, k on the lane axis.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._stencil_common import interior_mask, shifted_planes


def stencil7_kernel(a_prev, a_cur, a_next, w_ref, o_ref, *, bi: int,
                    m_total: int):
    i_blk = pl.program_id(0)
    w = w_ref[...]
    wc, wk, wj, wi = w[0], w[1], w[2], w[3]
    up, mid, down = shifted_planes(a_prev[...], a_cur[...], a_next[...])
    mid32 = mid.astype(jnp.float32)
    acc = (wc * mid32
           + wk * (jnp.roll(mid32, 1, axis=-1) + jnp.roll(mid32, -1, axis=-1))
           + wj * (jnp.roll(mid32, 1, axis=-2) + jnp.roll(mid32, -1, axis=-2))
           + wi * (up.astype(jnp.float32) + down.astype(jnp.float32)))
    n, p = mid.shape[1], mid.shape[2]
    mask = interior_mask(bi, n, p, i_blk, m_total)
    o_ref[...] = jnp.where(mask, acc, 0.0).astype(o_ref.dtype)
