"""Thin shim: the 7-point stencil lives in ``repro.kernels.stencil_engine``
(registry name ``"stencil7"``; wrapper built in ``repro.kernels._compat``)."""

from .._compat import stencil7, stencil7_ref  # noqa: F401
