"""Thin shim: the 7-point stencil lives in ``repro.kernels.stencil_engine``
(registry name ``"stencil7"``)."""

from ..stencil_engine.compat import stencil7, stencil7_ref  # noqa: F401
