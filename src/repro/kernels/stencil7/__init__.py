from .ops import stencil7  # noqa: F401
from .ref import stencil7_ref  # noqa: F401
