"""Pure-jnp oracle for the symmetric 7-point stencil (Dirichlet boundary).

Weights (wc, wk, wj, wi) -- 4 unique coefficients (paper sect. 3.1).
"""

from __future__ import annotations

import jax.numpy as jnp


def stencil7_ref(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    wc, wk, wj, wi = w[0], w[1], w[2], w[3]
    core = (wc * a[1:-1, 1:-1, 1:-1]
            + wk * (a[1:-1, 1:-1, :-2] + a[1:-1, 1:-1, 2:])
            + wj * (a[1:-1, :-2, 1:-1] + a[1:-1, 2:, 1:-1])
            + wi * (a[:-2, 1:-1, 1:-1] + a[2:, 1:-1, 1:-1]))
    return jnp.zeros_like(a).at[1:-1, 1:-1, 1:-1].set(core)
