"""Jitted public entry point for the 7-point Pallas stencil."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .._stencil_common import pick_block_i, stencil_pallas_call
from .kernel import stencil7_kernel


@functools.partial(jax.jit, static_argnames=("block_i", "interpret"))
def stencil7(a: jax.Array, w: jax.Array, block_i: int | None = None,
             interpret: bool = True) -> jax.Array:
    """Apply the symmetric 7-point stencil; w = (wc, wk, wj, wi)."""
    if block_i is None:
        block_i = pick_block_i(*a.shape, a.dtype.itemsize)
    w = w.astype(jnp.float32)
    return stencil_pallas_call(stencil7_kernel, a, w, block_i, interpret)
