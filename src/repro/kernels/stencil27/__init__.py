from .ops import stencil27  # noqa: F401
from .ref import stencil27_ref  # noqa: F401
