"""Thin shim: the 27-point stencil lives in ``repro.kernels.stencil_engine``
(registry name ``"stencil27"``; wrapper built in ``repro.kernels._compat``)."""

from .._compat import stencil27, stencil27_ref  # noqa: F401
