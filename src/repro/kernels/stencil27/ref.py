"""Pure-jnp oracle for the symmetric 27-point stencil (Dirichlet boundary).

Weights w[|di|, |dj|, |dk|] -- 8 unique coefficients (paper sect. 3.1):
symmetry along but not between the three dimensions.
"""

from __future__ import annotations

import jax.numpy as jnp


def stencil27_ref(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    assert w.shape == (2, 2, 2)
    acc = jnp.zeros_like(a[1:-1, 1:-1, 1:-1])
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            for dk in (-1, 0, 1):
                sl = a[1 + di:a.shape[0] - 1 + di,
                       1 + dj:a.shape[1] - 1 + dj,
                       1 + dk:a.shape[2] - 1 + dk]
                acc = acc + w[abs(di), abs(dj), abs(dk)] * sl
    return jnp.zeros_like(a).at[1:-1, 1:-1, 1:-1].set(acc)
