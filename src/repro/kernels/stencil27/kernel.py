"""Pallas TPU kernel for the symmetric 27-point stencil.

Decomposition mirrors the paper's synthesis: the 27-point operator is nine
3-point k-kernels summed over the (di, dj) plane neighbourhood (sect. 3.1).
On TPU each (i +- 1) plane contributes through its four symmetric neighbour
sums (centre / j-edges / k-edges / jk-corners), weighted by
w[|di|] x {(0,0), (1,0), (0,1), (1,1)} -- 12 FMAs per point over three
planes, all on the VPU with k on the lane axis.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._stencil_common import (interior_mask, shifted_planes,
                               sym_neighbor_sums)


def stencil27_kernel(a_prev, a_cur, a_next, w_ref, o_ref, *, bi: int,
                     m_total: int):
    i_blk = pl.program_id(0)
    w = w_ref[...]
    up, mid, down = shifted_planes(a_prev[...], a_cur[...], a_next[...])
    acc = jnp.zeros(mid.shape, dtype=jnp.float32)
    for plane, wi in ((mid, 0), (up, 1), (down, 1)):
        c0, cj, ck, cjk = sym_neighbor_sums(plane.astype(jnp.float32))
        acc = (acc + w[wi, 0, 0] * c0 + w[wi, 1, 0] * cj
               + w[wi, 0, 1] * ck + w[wi, 1, 1] * cjk)
    n, p = mid.shape[1], mid.shape[2]
    mask = interior_mask(bi, n, p, i_blk, m_total)
    o_ref[...] = jnp.where(mask, acc, 0.0).astype(o_ref.dtype)
