"""Jitted public entry point for the 27-point Pallas stencil."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .._stencil_common import pick_block_i, stencil_pallas_call
from .kernel import stencil27_kernel


@functools.partial(jax.jit, static_argnames=("block_i", "interpret"))
def stencil27(a: jax.Array, w: jax.Array, block_i: int | None = None,
              interpret: bool = True) -> jax.Array:
    """Apply the symmetric 27-point stencil; w has shape (2, 2, 2)."""
    if block_i is None:
        block_i = pick_block_i(*a.shape, a.dtype.itemsize)
    w = w.astype(jnp.float32)
    return stencil_pallas_call(stencil27_kernel, a, w, block_i, interpret)
