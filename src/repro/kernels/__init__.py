"""Pallas TPU kernels (validated in interpret mode on CPU; TPU is the target)."""

from .flash_attention import attention_ref, flash_attention  # noqa: F401
from .mamba_scan import mamba_scan, mamba_scan_ref  # noqa: F401
from .stencil3 import stencil3, stencil3_ref  # noqa: F401
from .stencil7 import stencil7, stencil7_ref  # noqa: F401
from .stencil27 import stencil27, stencil27_ref  # noqa: F401
from .stencil_mxu import stencil27_mxu, stencil27_mxu_ref  # noqa: F401
