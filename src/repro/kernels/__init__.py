"""Pallas TPU kernels (validated in interpret mode on CPU; TPU is the target)."""

from .flash_attention import attention_ref, flash_attention  # noqa: F401
from .mamba_scan import mamba_scan, mamba_scan_ref  # noqa: F401
from .stencil_engine import (BC, SWEEP_MODES, GuardPolicy,  # noqa: F401
                             StencilPlan, StencilSpec, SweepSelection,
                             as_boundary, autotune_block_i, autotune_blocks,
                             autotune_engine, autotune_sweeps,
                             bytes_per_point, compile_plan, dirichlet,
                             exchange_bytes_per_point,
                             get_stencil, guard_bytes_per_point,
                             last_guard_report, list_stencils,
                             register_stencil, spec_from_mask, stencil_apply,
                             stencil_ref, stencil_sharded,
                             stencil_sweep_driver, stencil_wavefront,
                             stencil3, stencil3_ref, stencil7, stencil7_ref,
                             stencil27, stencil27_ref, wavefront_block_i)
from .stencil_mxu import stencil27_mxu, stencil27_mxu_ref  # noqa: F401
