"""Optimizers: AdamW (fp32 moments) and Adafactor (factored second moment).

AdamW is the default; Adafactor is selected for >=100B-parameter archs
(arctic-480b) where full fp32 moments would not fit the per-device HBM
budget -- the optimizer-state sizing is part of the dry-run memory analysis.
Pure pytree implementation (no optax dependency in this environment).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params, jax.Array], Tuple[Params, Any]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            upd_ = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
            upd_ = upd_ + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd_).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"mu": new_m, "nu": new_v, "step": step}

    return Optimizer("adamw", init, update)


def adafactor(eps: float = 1e-30, decay: float = 0.8,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern, 2018), no momentum.

    State per (.., R, C) matrix: one R-vector + one C-vector instead of R*C
    fp32 moments: ~O(sqrt) memory, the enabling trick for the 480B dry-run.
    """
    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"m": jax.tree.map(one, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32)) ** -decay

        def upd(g, st, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if p.ndim >= 2:
                vr = beta * st["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * st["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1, keepdims=True)
                                       [..., None], eps))
                u = g32 * jax.lax.rsqrt(denom + eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(v + eps)
                new_st = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_st

        is_state = lambda t: isinstance(t, dict) and ("v" in t or "vr" in t)
        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        leaves_st = jax.tree.flatten(state["m"], is_leaf=is_state)[0]
        out = [upd(g, st, p)
               for g, st, p in zip(leaves_g, leaves_st, leaves_p)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        return new_p, {"m": new_m, "step": step}

    return Optimizer("adafactor", init, update)


def build_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(name)
