from .optimizers import (Optimizer, adafactor, adamw, build_optimizer,  # noqa: F401
                         clip_by_global_norm)
from .schedules import warmup_cosine  # noqa: F401
