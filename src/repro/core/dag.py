"""Dependency-DAG construction for PPC450 instruction blocks (paper sect. 3.3).

Nodes are instruction indices; a RAW edge i->j is weighted with the producer's
result latency, WAR/WAW edges carry weight 1 (the paper's convention).
Memory dependencies are tracked symbolically by (alias-space, base GPR
version, byte range); distinct alias spaces (input array A vs output R) never
conflict, matching the kernels' no-alias guarantee.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import networkx as nx

from .isa import Instr, Unit


def build_dag(instrs: List[Instr], war: bool = True) -> nx.DiGraph:
    """Build the dependency DAG.

    ``war=True`` (default) emits WAR/WAW edges (weight 1, the paper's eq. 5
    convention) -- required for code that must run on the in-order PPC450
    as-emitted.  ``war=False`` models the paper's simulator semantics: an
    "infinite-lookahead out-of-order execution unit" (sect. 4.4), i.e.
    implicit register renaming, keeping only true (RAW) and memory
    dependencies.  Table 3's simulated column is only reachable in this mode;
    see EXPERIMENTS.md for the analysis.
    """
    g = nx.DiGraph()
    for i, ins in enumerate(instrs):
        g.add_node(i, instr=ins)

    last_writer: Dict[str, int] = {}
    readers_since_write: Dict[str, List[int]] = {}
    gpr_version: Dict[str, int] = {}
    # memory ops: list of (idx, space, base, version, lo, hi, is_store)
    mem_ops: List[Tuple[int, str, str, int, int, int, bool]] = []

    def add_edge(u: int, v: int, w: int) -> None:
        if u == v:
            return
        if g.has_edge(u, v):
            if g[u][v]["weight"] < w:
                g[u][v]["weight"] = w
        else:
            g.add_edge(u, v, weight=w)

    for j, ins in enumerate(instrs):
        # Register RAW
        for r in ins.srcs:
            if r in last_writer:
                i = last_writer[r]
                add_edge(i, j, max(1, instrs[i].latency))
            readers_since_write.setdefault(r, []).append(j)
        # Register WAR / WAW.  Mutate loads and half-copies *merge* into their
        # destination (dest also appears in srcs): the RAW edge above already
        # orders them, so they stay dependent even in OOO mode.
        if ins.dest is not None:
            if war:
                for rdr in readers_since_write.get(ins.dest, []):
                    add_edge(rdr, j, 1)
                if ins.dest in last_writer:
                    add_edge(last_writer[ins.dest], j, 1)
            last_writer[ins.dest] = j
            readers_since_write[ins.dest] = [j] if ins.dest in ins.srcs else []
        # Memory dependencies
        if ins.mem is not None:
            m = ins.mem
            ver = gpr_version.get(m.base, 0)
            lo, hi = m.offset, m.offset + m.size
            for (i, sp, base, v, l2, h2, st2) in mem_ops:
                if sp != m.space:
                    continue
                conflict = (base != m.base or v != ver) or (lo < h2 and l2 < hi)
                if conflict and (m.is_store or st2):
                    add_edge(i, j, 1 if st2 and not m.is_store else 1)
            mem_ops.append((j, m.space, m.base, ver, lo, hi, m.is_store))
        # GPR version bump for address computation
        if ins.unit is Unit.IU and ins.dest is not None:
            gpr_version[ins.dest] = gpr_version.get(ins.dest, 0) + 1

    return g


def critical_path_length(g: nx.DiGraph) -> int:
    """Longest weighted path through the DAG, including the final op's latency."""
    if g.number_of_nodes() == 0:
        return 0
    dist: Dict[int, int] = {}
    for n in nx.topological_sort(g):
        ins: Instr = g.nodes[n]["instr"]
        start = max((dist[p] + g[p][n]["weight"] for p in g.predecessors(n)),
                    default=0)
        dist[n] = start
    # completion = issue + issue_cycles of the last instruction
    return max(dist[n] + g.nodes[n]["instr"].issue_cycles for n in g.nodes)


def path_to_sink(g: nx.DiGraph) -> Dict[int, int]:
    """For each node, the longest weighted path from it to any sink (priority)."""
    pr: Dict[int, int] = {}
    for n in reversed(list(nx.topological_sort(g))):
        pr[n] = max((g[n][s]["weight"] + pr[s] for s in g.successors(n)),
                    default=g.nodes[n]["instr"].issue_cycles)
    return pr


def lower_bound(instrs: List[Instr], g: nx.DiGraph | None = None) -> int:
    """Paper eq. (1): L = max{critical path, 2*|LSU|, |FPU|}."""
    if g is None:
        g = build_dag(instrs)
    n_lsu = sum(1 for i in instrs if i.unit is Unit.LSU)
    n_fpu = sum(1 for i in instrs if i.unit is Unit.FPU)
    return max(critical_path_length(g), 2 * n_lsu, n_fpu)
