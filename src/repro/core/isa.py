"""PowerPC 450 "Double Hummer" instruction-set model (faithful-reproduction layer).

The PPC450 core issues at most one floating-point instruction per cycle (FPU),
one load/store every two cycles (LSU), and integer ops in parallel (IU).
SIMD floating-point registers (FPRs) are 16-byte pairs (primary, secondary);
GPRs are 4-byte scalars used here for addressing.

We model the orthogonal ``fxc*`` multiply(-add) family the paper's kernels use:
a *weight* operand W supplies one scalar half (primary or secondary) which
multiplies a *data* operand C either in parallel (same halves) or crossed
(swapped halves).  The paper's "cross copy-primary multiply" maps to
``fxcpmul``/``fxcsmul`` and its "cross complex multiply-add" to the ``*x*``
variants (``fxcsxmadd`` etc.).  Semantics are internally consistent and have
identical resource costs to the hardware family; codegen renders the closest
real mnemonic (documented in DESIGN.md §8).

Latencies (paper §3.2/§3.3): FPU result -> FPR: 5 cycles; L1 load -> FPR: 4
cycles (L2 ~15, L3 ~56 handled by the memory model); GPR writes: 1 cycle.
LSU instructions occupy the load/store pipe for 2 cycles (stores modeled at 2
as the paper assumes).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Optional, Sequence, Tuple

CLOCK_MHZ = 850.0

FPU_LATENCY = 5          # cycles until an FPU result may be consumed
L1_LOAD_LATENCY = 4      # cycles until a load from L1 may be consumed
L2_LOAD_LATENCY = 15
L3_LOAD_LATENCY = 56     # 50 memory + 6 instruction (paper sect. 3.2)
GPR_LATENCY = 1
LSU_ISSUE_CYCLES = 2     # one LSU op every other cycle
FPU_ISSUE_CYCLES = 1
IU_ISSUE_CYCLES = 1

NUM_FPRS = 32
NUM_GPRS = 32

# Bandwidths used by the paper's analytic model, bytes / cycle (sect. 5.1).
L1_READ_BW = 8.0
L3_READ_BW = 4.7
DDR_READ_BW = 3.7
WRITE_BW = 5.3


class Unit(enum.Enum):
    FPU = "FPU"
    LSU = "LSU"
    IU = "IU"


@dataclasses.dataclass(frozen=True)
class MemRef:
    """Symbolic memory operand: address = GPR[base] + offset (bytes)."""

    base: str           # symbolic GPR name holding the base address
    offset: int         # immediate byte offset
    size: int           # 8 (half FPR) or 16 (quad)
    is_store: bool
    space: str = "A"    # alias group ("A" input array, "R" output array, "W" weights)


@dataclasses.dataclass(frozen=True)
class Instr:
    """One PPC450 instruction with symbolic register operands."""

    mnemonic: str
    unit: Unit
    dest: Optional[str]                 # symbolic register written (FPR f* / GPR g*)
    srcs: Tuple[str, ...]               # symbolic registers read
    mem: Optional[MemRef] = None
    imm: int = 0                        # immediate (addi)
    comment: str = ""
    # Instructions like mutate loads & half-copies read the old dest value
    # implicitly (they preserve one half) -- in that case dest appears in srcs.

    @property
    def latency(self) -> int:
        if self.unit is Unit.FPU:
            return FPU_LATENCY
        if self.unit is Unit.LSU:
            return 0 if (self.mem and self.mem.is_store) else L1_LOAD_LATENCY
        return GPR_LATENCY

    @property
    def issue_cycles(self) -> int:
        if self.unit is Unit.LSU:
            return LSU_ISSUE_CYCLES
        return 1

    def __str__(self) -> str:  # pragma: no cover - debug aid
        m = f" {self.mem.base}+{self.mem.offset}" if self.mem else ""
        return f"{self.mnemonic} {self.dest} <- {','.join(self.srcs)}{m}"


# ---------------------------------------------------------------------------
# Instruction builders.  FPR values are (primary, secondary) pairs.
# W = weight register, C = data register, T = accumulator (dest).
# Parallel variants multiply one half of W against both halves of C in-place;
# cross variants swap C's halves into the opposite output half.
# ---------------------------------------------------------------------------

def _fpu(mn: str, dest: str, srcs: Sequence[str], comment: str = "") -> Instr:
    return Instr(mn, Unit.FPU, dest, tuple(srcs), comment=comment)


def fxcpmul(t: str, w: str, c: str, comment: str = "") -> Instr:
    """T.p = W.p*C.p ; T.s = W.p*C.s  (parallel, weight primary)."""
    return _fpu("fxcpmul", t, (w, c), comment)


def fxcsmul(t: str, w: str, c: str, comment: str = "") -> Instr:
    """T.p = W.s*C.p ; T.s = W.s*C.s  (parallel, weight secondary)."""
    return _fpu("fxcsmul", t, (w, c), comment)


def fxcpxmul(t: str, w: str, c: str, comment: str = "") -> Instr:
    """T.p = W.p*C.s ; T.s = W.p*C.p  (cross, weight primary)."""
    return _fpu("fxcpxmul", t, (w, c), comment)


def fxcsxmul(t: str, w: str, c: str, comment: str = "") -> Instr:
    """T.p = W.s*C.s ; T.s = W.s*C.p  (cross, weight secondary)."""
    return _fpu("fxcsxmul", t, (w, c), comment)


def fxcpmadd(t: str, w: str, c: str, comment: str = "") -> Instr:
    """T.p += W.p*C.p ; T.s += W.p*C.s."""
    return _fpu("fxcpmadd", t, (w, c, t), comment)


def fxcsmadd(t: str, w: str, c: str, comment: str = "") -> Instr:
    """T.p += W.s*C.p ; T.s += W.s*C.s."""
    return _fpu("fxcsmadd", t, (w, c, t), comment)


def fxcpxmadd(t: str, w: str, c: str, comment: str = "") -> Instr:
    """T.p += W.p*C.s ; T.s += W.p*C.p  (paper's cross complex madd)."""
    return _fpu("fxcpxmadd", t, (w, c, t), comment)


def fxcsxmadd(t: str, w: str, c: str, comment: str = "") -> Instr:
    """T.p += W.s*C.s ; T.s += W.s*C.p."""
    return _fpu("fxcsxmadd", t, (w, c, t), comment)


def fpmadd(t: str, a: str, c: str, b: str, comment: str = "") -> Instr:
    """T = A*C + B (both halves, plain parallel FMA)."""
    return _fpu("fpmadd", t, (a, c, b), comment)


def fpadd(t: str, a: str, b: str, comment: str = "") -> Instr:
    return _fpu("fpadd", t, (a, b), comment)


def fsmr_p(t: str, a: str, comment: str = "") -> Instr:
    """T.p = A.p, T.s unchanged -- the load-copy 'copy' op (FPU move)."""
    return Instr("fsmr_p", Unit.FPU, t, (a, t), comment=comment)


def fsmr_s(t: str, a: str, comment: str = "") -> Instr:
    """T.s = A.s, T.p unchanged."""
    return Instr("fsmr_s", Unit.FPU, t, (a, t), comment=comment)


def fpmr(t: str, a: str, comment: str = "") -> Instr:
    """T = A (move both halves)."""
    return Instr("fpmr", Unit.FPU, t, (a,), comment=comment)


def lfpdx(t: str, base: str, offset: int, space: str = "A", comment: str = "") -> Instr:
    """Quad (16B, aligned) load: T.p = mem[ea], T.s = mem[ea+8]."""
    return Instr("lfpdx", Unit.LSU, t, (base,),
                 mem=MemRef(base, offset, 16, False, space), comment=comment)


def lfdx(t: str, base: str, offset: int, space: str = "A", comment: str = "") -> Instr:
    """Mutate-primary load (8B): T.p = mem[ea], T.s unchanged."""
    return Instr("lfdx", Unit.LSU, t, (base, t),
                 mem=MemRef(base, offset, 8, False, space), comment=comment)


def lfsdx(t: str, base: str, offset: int, space: str = "A", comment: str = "") -> Instr:
    """Mutate-secondary load (8B): T.s = mem[ea], T.p unchanged."""
    return Instr("lfsdx", Unit.LSU, t, (base, t),
                 mem=MemRef(base, offset, 8, False, space), comment=comment)


def stfpdx(s: str, base: str, offset: int, space: str = "R", comment: str = "") -> Instr:
    """Quad (16B, aligned) store."""
    return Instr("stfpdx", Unit.LSU, None, (s, base),
                 mem=MemRef(base, offset, 16, True, space), comment=comment)


def addi(t: str, a: str, imm: int, comment: str = "") -> Instr:
    return Instr("addi", Unit.IU, t, (a,), imm=imm, comment=comment)


# Semantics table used by the functional simulator: fn(w, c, t) -> (p, s).
# w/c/t are (p, s) float tuples; returns the new dest pair.
FPU_SEMANTICS: dict[str, Callable] = {
    "fxcpmul":  lambda w, c, t: (w[0] * c[0], w[0] * c[1]),
    "fxcsmul":  lambda w, c, t: (w[1] * c[0], w[1] * c[1]),
    "fxcpxmul": lambda w, c, t: (w[0] * c[1], w[0] * c[0]),
    "fxcsxmul": lambda w, c, t: (w[1] * c[1], w[1] * c[0]),
    "fxcpmadd": lambda w, c, t: (t[0] + w[0] * c[0], t[1] + w[0] * c[1]),
    "fxcsmadd": lambda w, c, t: (t[0] + w[1] * c[0], t[1] + w[1] * c[1]),
    "fxcpxmadd": lambda w, c, t: (t[0] + w[0] * c[1], t[1] + w[0] * c[0]),
    "fxcsxmadd": lambda w, c, t: (t[0] + w[1] * c[1], t[1] + w[1] * c[0]),
}
