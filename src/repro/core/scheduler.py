"""Instruction scheduling: greedy list scheduler + exact branch-and-bound.

The greedy scheduler reproduces the paper's sect. 4.4 strategy: it behaves as
an infinite-lookahead, greedy out-of-order PPC450 -- each cycle it tries to
start one instruction on the FPU and one on the LSU (plus one IU op), picking
among ready instructions by longest-path-to-sink priority.  The emitted order
is then what the in-order hardware executes.

For small blocks an exact branch-and-bound solver certifies optimality of the
greedy result against the ILP lower bound (paper eqs. 2-15; NP-complete in
general, so B&B is gated on block size).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import networkx as nx

from .dag import build_dag, lower_bound, path_to_sink
from .isa import Instr, Unit


@dataclasses.dataclass
class Schedule:
    order: List[int]               # instruction indices in issue order
    issue_cycle: Dict[int, int]    # index -> cycle issued
    makespan: int                  # cycles to issue all instructions
    lower_bound: int

    @property
    def optimal(self) -> bool:
        return self.makespan == self.lower_bound


def _ready_time(g: nx.DiGraph, issue: Dict[int, int], n: int) -> int:
    return max((issue[p] + g[p][n]["weight"] for p in g.predecessors(n)
                if p in issue), default=0)


def greedy_schedule(instrs: List[Instr], g: Optional[nx.DiGraph] = None) -> Schedule:
    if g is None:
        g = build_dag(instrs)
    prio = path_to_sink(g)
    unscheduled = set(range(len(instrs)))
    issue: Dict[int, int] = {}
    order: List[int] = []
    pending_preds = {n: set(g.predecessors(n)) for n in g.nodes}
    lsu_free_at = 0
    cycle = 0
    guard = 0
    while unscheduled:
        guard += 1
        if guard > 100 * len(instrs) + 1000:  # pragma: no cover
            raise RuntimeError("scheduler livelock")
        # instructions whose deps are all scheduled AND data-ready this cycle
        ready = [n for n in unscheduled
                 if not (pending_preds[n] - issue.keys())
                 and _ready_time(g, issue, n) <= cycle]
        ready.sort(key=lambda n: (-prio[n], n))
        fpu_used = iu_used = False
        lsu_used = lsu_free_at > cycle
        for n in ready:
            u = instrs[n].unit
            if u is Unit.FPU and not fpu_used:
                fpu_used = True
            elif u is Unit.LSU and not lsu_used:
                lsu_used = True
                lsu_free_at = cycle + 2
            elif u is Unit.IU and not iu_used:
                iu_used = True
            else:
                continue
            issue[n] = cycle
            order.append(n)
            unscheduled.discard(n)
        cycle += 1
    makespan = max(issue[n] + instrs[n].issue_cycles for n in issue) if issue else 0
    return Schedule(order, issue, makespan, lower_bound(instrs, g))


def bb_schedule(instrs: List[Instr], max_nodes: int = 16,
                node_budget: int = 200_000) -> Optional[Schedule]:
    """Minimum-makespan schedule by branch & bound (small blocks only).

    Returns None if the block exceeds ``max_nodes``.  Implements the resource
    constraints of the paper's ILP (eqs. 2-5) exactly; register-count
    constraints (eqs. 6-13) are checked post-hoc by the allocator instead.
    Branching is beam-limited to the top-3 candidates per unit by
    path-to-sink priority, so the result is certified optimal only when
    ``Schedule.optimal`` (makespan == eq.-1 lower bound) holds.
    """
    n = len(instrs)
    if n > max_nodes:
        return None
    g = build_dag(instrs)
    lb = lower_bound(instrs, g)
    best = greedy_schedule(instrs, g)
    if best.makespan == lb:
        return best
    best_span = best.makespan
    best_state: Tuple[List[int], Dict[int, int]] = (best.order, best.issue_cycle)
    prio = path_to_sink(g)
    expanded = 0

    def recurse(issue: Dict[int, int], order: List[int], cycle: int,
                lsu_free: int) -> None:
        nonlocal best_span, best_state, expanded
        expanded += 1
        if expanded > node_budget:
            return
        if len(order) == n:
            span = max(issue[i] + instrs[i].issue_cycles for i in issue)
            if span < best_span:
                best_span, best_state = span, (list(order), dict(issue))
            return
        # bound: completion of what's already issued, and for every
        # unscheduled node its earliest issue (no earlier than ``cycle`` nor
        # its data-ready time from scheduled producers) plus its longest
        # path to a sink.  Prune whenever even this optimistic completion
        # can't beat the incumbent.
        span_so_far = max((issue[i] + instrs[i].issue_cycles for i in issue),
                          default=0)
        rem = [i for i in range(n) if i not in issue]
        bound = max(span_so_far,
                    max(max(cycle, _ready_time(g, issue, i)) + prio[i]
                        for i in rem))
        if bound >= best_span:
            return
        ready = [i for i in rem
                 if all(p in issue for p in g.predecessors(i))
                 and _ready_time(g, issue, i) <= cycle]
        ready.sort(key=lambda i: (-prio[i], i))
        fpu = [i for i in ready if instrs[i].unit is Unit.FPU]
        lsu = [i for i in ready if instrs[i].unit is Unit.LSU] \
            if lsu_free <= cycle else []
        iu = [i for i in ready if instrs[i].unit is Unit.IU]
        choices: List[Tuple[Optional[int], Optional[int], Optional[int]]] = []
        for f in (fpu[:3] + [None]):
            for l in (lsu[:3] + [None]):
                for u in (iu[:1] + [None]):
                    choices.append((f, l, u))
        for f, l, u in choices:
            picked = [x for x in (f, l, u) if x is not None]
            for x in picked:
                issue[x] = cycle
                order.append(x)
            recurse(issue, order,
                    cycle + 1, cycle + 2 if l is not None else lsu_free)
            for x in picked:
                del issue[x]
                order.pop()
            if best_span == lb:
                return

    recurse({}, [], 0, 0)
    order, issue = best_state
    return Schedule(order, issue, best_span, lb)


def ilp_formulation(instrs: List[Instr], horizon: Optional[int] = None):
    """Materialize the paper's ILP (eqs. 2-5, 15) as dense constraint rows.

    Returns (A_eq, b_eq, A_ub, b_ub, num_vars) over boolean x[i,j] with
    j in [0, M).  Provided for completeness/testing -- solving is delegated
    to ``bb_schedule`` (the paper likewise ships a greedy solver).
    """
    import numpy as np

    g = build_dag(instrs)
    n = len(instrs)
    m = horizon or (2 * greedy_schedule(instrs, g).makespan + 2)
    nv = n * m

    def x(i: int, j: int) -> int:
        return i * m + j

    a_eq, b_eq, a_ub, b_ub = [], [], [], []
    for i in range(n):                         # eq (2): schedule exactly once
        row = np.zeros(nv)
        row[[x(i, j) for j in range(m)]] = 1
        a_eq.append(row); b_eq.append(1.0)
    for j in range(m):                         # eq (3): one FPU op / cycle
        row = np.zeros(nv)
        for i in range(n):
            if instrs[i].unit is Unit.FPU:
                row[x(i, j)] = 1
        a_ub.append(row); b_ub.append(1.0)
    for j in range(m - 1):                     # eq (4): one LSU op / 2 cycles
        row = np.zeros(nv)
        for i in range(n):
            if instrs[i].unit is Unit.LSU:
                row[x(i, j)] = 1
                row[x(i, j + 1)] = 1
        a_ub.append(row); b_ub.append(1.0)
    for (u, v, d) in g.edges(data=True):       # eq (5): dependencies
        row = np.zeros(nv)
        for j in range(m):
            row[x(u, j)] += j
            row[x(v, j)] -= j
        a_ub.append(row); b_ub.append(-float(d["weight"]))
    return (np.array(a_eq), np.array(b_eq), np.array(a_ub), np.array(b_ub), nv)
