"""The paper's analytic performance model (sect. 5 / Tables 2-3).

Performance = min(instruction-issue limit, bandwidth limit), evaluated per
(kernel, unroll) configuration:

* naive instruction limit  = clock * stencils_per_iter / max(2*|LSU|, |FPU|)
* scheduled ("simulated")  = clock * stencils_per_iter / simulated cycles/iter
  from the greedy scheduler + in-order pipeline simulator
* L1 bandwidth limit       = clock / (read_bytes/8   + write_bytes/5.3)
* L3 bandwidth limit       = clock / (read_bytes/4.7 + write_bytes/5.3)
* streaming (DDR) limit    = clock / (read_bytes/3.7 + write_bytes/5.3)

byte counts are per stencil.  Units: Mstencil/s at 850 MHz.
PAPER_TABLE3 holds the published values for validation benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .dag import build_dag
from .isa import CLOCK_MHZ, DDR_READ_BW, L1_READ_BW, L3_READ_BW, WRITE_BW
from .scheduler import greedy_schedule
from .simulator import simulate_inorder
from .synth import Counts, StencilConfig, SynthKernel, synth_stencil


@dataclasses.dataclass
class PerfEstimate:
    config: StencilConfig
    counts: Counts
    naive_mstencil: float
    simulated_mstencil: float        # paper protocol: OOO-mode body makespan
    simulated_strict_mstencil: float  # in-order-safe (WAR=1) body makespan
    pipelined_mstencil: float        # steady-state cross-iteration overlap
    l1_bw_mstencil: float
    l3_bw_mstencil: float
    streaming_bw_mstencil: float
    cycles_per_iter: float
    schedule_lower_bound: int
    bytes_per_stencil: float
    lsu_util: float
    fpu_util: float

    @property
    def predicted_l1(self) -> float:
        return min(self.simulated_mstencil, self.l1_bw_mstencil)

    @property
    def predicted_streaming(self) -> float:
        return min(self.simulated_mstencil, self.streaming_bw_mstencil)

    @property
    def predicted_l3(self) -> float:
        return min(self.simulated_mstencil, self.l3_bw_mstencil)


def _bw_limit(read_bps: float, write_bps: float, read_bw: float) -> float:
    return CLOCK_MHZ / (read_bps / read_bw + write_bps / WRITE_BW)


@dataclasses.dataclass(frozen=True)
class StreamEstimate:
    """Generic streaming roofline for one kernel configuration: the paper's
    ``min(compute limit, bandwidth limit)`` applied to any (bytes, flops)
    per-point pair -- used by the Pallas engine's autotuner/benchmarks with
    TPU HBM/VPU rates the same way :func:`analyze` uses the BG/P ladder."""

    read_bytes_per_point: float
    write_bytes_per_point: float
    flops_per_point: float
    mem_bw: float                   # bytes/s
    compute_rate: float             # flop/s

    @property
    def bytes_per_point(self) -> float:
        return self.read_bytes_per_point + self.write_bytes_per_point

    @property
    def bw_points_per_s(self) -> float:
        return self.mem_bw / max(self.bytes_per_point, 1e-30)

    @property
    def compute_points_per_s(self) -> float:
        return self.compute_rate / max(self.flops_per_point, 1e-30)

    @property
    def points_per_s(self) -> float:
        return min(self.bw_points_per_s, self.compute_points_per_s)

    @property
    def bound(self) -> str:
        return ("bandwidth" if self.bw_points_per_s
                <= self.compute_points_per_s else "compute")


def streaming_roofline(read_bytes_per_point: float,
                       write_bytes_per_point: float,
                       flops_per_point: float, mem_bw: float,
                       compute_rate: float) -> StreamEstimate:
    """Roofline estimate for a streaming kernel: points/s limited by either
    ``mem_bw / bytes_per_point`` or ``compute_rate / flops_per_point`` --
    the paper's sect.-5 model with the BG/P DDR/FPU constants generalized
    so the TPU engine (HBM bytes, plan-derived VPU ops) can reuse it."""
    return StreamEstimate(read_bytes_per_point, write_bytes_per_point,
                          flops_per_point, mem_bw, compute_rate)


def analyze(cfg: StencilConfig, kern: Optional[SynthKernel] = None,
            n_iters: int = 24) -> PerfEstimate:
    kern = kern or synth_stencil(cfg)
    c = kern.counts
    st = cfg.stencils_per_iter
    rb, wb = c.read_bytes / st, c.write_bytes / st

    naive = CLOCK_MHZ * st / max(c.lsu_cycles, c.fpu)

    # Paper's "simulated" column: greedy-scheduled makespan of one logical
    # loop iteration under the paper simulator's out-of-order (register
    # renaming) semantics, sect. 4.4.
    one = kern.single_step
    sched_one = greedy_schedule(one, build_dag(one, war=False))
    simulated = CLOCK_MHZ * st / sched_one.makespan
    sched_strict = greedy_schedule(one, build_dag(one, war=True))
    simulated_strict = CLOCK_MHZ * st / sched_strict.makespan

    # Our steady-state number: the scheduled full body replayed in-order with
    # cross-iteration overlap (closer to real pipelined hardware).
    sched = greedy_schedule(kern.body)
    ordered = [kern.body[i] for i in sched.order]
    timing = simulate_inorder(ordered, n_iters=n_iters)
    cyc_per_logical = timing.per_iter_cycles / kern.k_steps
    pipelined = CLOCK_MHZ * st / cyc_per_logical

    lsu_util = min(1.0, c.lsu_cycles / max(c.lsu_cycles, c.fpu))
    fpu_util = min(1.0, c.fpu / max(c.lsu_cycles, c.fpu))

    return PerfEstimate(
        config=cfg, counts=c,
        naive_mstencil=naive,
        simulated_mstencil=simulated,
        simulated_strict_mstencil=simulated_strict,
        pipelined_mstencil=pipelined,
        l1_bw_mstencil=_bw_limit(rb, wb, L1_READ_BW),
        l3_bw_mstencil=_bw_limit(rb, wb, L3_READ_BW),
        streaming_bw_mstencil=_bw_limit(rb, wb, DDR_READ_BW),
        cycles_per_iter=float(sched_one.makespan),
        schedule_lower_bound=sched_one.lower_bound,
        bytes_per_stencil=(c.read_bytes + c.write_bytes) / st,
        lsu_util=lsu_util, fpu_util=fpu_util,
    )


# Published values (paper Table 3), Mstencil/s: columns are
# (naive, simulated, l1_bw, streaming_bw, pred_l1, obs_l1, pred_stream, obs_stream)
PAPER_TABLE3: Dict[str, tuple] = {
    "27-mm-1x1": (44.74, 11.93, 80.88, 40.54, 11.93, 11.92, 11.93, 12.37),
    "27-mm-1x2": (62.96, 23.35, 113.19, 58.69, 23.35, 23.39, 23.35, 22.56),
    "27-mm-1x3": (62.96, 34.30, 130.58, 68.99, 34.30, 34.23, 34.30, 28.26),
    "27-mm-2x2": (62.96, 44.59, 154.28, 83.68, 44.59, 44.53, 44.59, 38.37),
    "27-mm-2x3": (62.96, 54.62, 175.52, 97.51, 54.62, 54.17, 54.62, 42.64),
    "7-mm-2x3": (182.14, 126.84, 203.54, 116.84, 126.84, 124.43, 116.84, 59.69),
    "7-lc-2x3": (212.50, 143.83, 203.54, 116.84, 143.83, 132.10, 116.84, 74.21),
    "3-lc-1x1": (425.00, 88.12, 338.72, 231.51, 88.12, 81.33, 88.12, 67.44),
    "3-lc-2x1": (425.00, 147.29, 338.72, 231.51, 147.29, 142.04, 147.29, 119.99),
    "3-lc-2x2": (425.00, 193.36, 338.72, 231.51, 193.36, 184.84, 193.36, 96.23),
    "3-lc-2x3": (425.00, 202.31, 338.72, 231.51, 202.31, 195.83, 202.31, 86.62),
    "3-lc-2x4": (425.00, 197.10, 338.72, 231.51, 197.10, 199.05, 197.10, 83.90),
}

# Published per-iteration resource counts (paper Table 2):
# (streams/rows, stencils_iter, input_regs, result_regs, weight_regs,
#  loads, stores, fpu, bytes_per_stencil)
PAPER_TABLE2: Dict[str, tuple] = {
    "27-mm-1x1": (9, 2, 9, 1, 4, 18, 1, 27, 80.0),
    "27-mm-1x2": (12, 4, 12, 2, 4, 24, 2, 54, 56.0),
    "27-mm-1x3": (15, 6, 15, 3, 4, 30, 3, 81, 48.0),
    "27-mm-2x2": (16, 8, 16, 4, 4, 32, 4, 108, 40.0),
    "27-mm-2x3": (20, 12, 20, 6, 4, 40, 6, 162, 34.667),
    "7-mm-2x3": (16, 12, 16, 6, 2, 22, 6, 42, 29.333),
    "7-lc-2x3": (16, 12, 22, 6, 2, 16, 6, 48, 29.333),
    "3-lc-1x1": (1, 2, 2, 1, 1, 1, 1, 4, 16.0),
    "3-lc-2x1": (2, 4, 4, 2, 1, 2, 2, 8, 16.0),
    "3-lc-2x2": (4, 8, 8, 4, 1, 4, 4, 16, 16.0),
    "3-lc-2x3": (6, 12, 12, 6, 1, 6, 6, 24, 16.0),
    "3-lc-2x4": (8, 16, 16, 8, 1, 8, 8, 32, 16.0),
}
