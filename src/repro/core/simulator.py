"""Cycle-accurate in-order PPC450 pipeline simulator + functional executor.

Two roles, exactly as in the paper (sect. 4.1/4.4):

* **Functional**: execute an instruction stream against virtual GPR/FPR files
  and a virtual memory, so synthesized kernels can be verified bit-for-bit
  against a numpy oracle.
* **Timing**: replay a (scheduled) stream through an in-order dual-issue model
  -- at each cycle the next instructions in program order may issue on the
  FPU / LSU / IU if their unit is free and operands are ready; a blocked
  instruction stalls everything behind it.  Steady-state cycles/iteration are
  measured by replaying the loop body ``n_iters`` times and differencing the
  middle iterations, which captures cross-iteration overlap the way real
  hardware would.

The memory model assigns per-load latency from a stream-aware hierarchy model
(L1 hit 4 cycles; L2-prefetch hit 15; L3 56) with the PPC450's limit of three
outstanding L1 misses (sect. 3.2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .isa import (FPU_SEMANTICS, Instr, L1_LOAD_LATENCY, L2_LOAD_LATENCY,
                  L3_LOAD_LATENCY, Unit)


class Machine:
    """Virtual architectural state (functional simulation)."""

    def __init__(self, mem_words: int = 1 << 20):
        self.fpr: Dict[str, Tuple[float, float]] = {}
        self.gpr: Dict[str, int] = {}
        self.mem = np.zeros(mem_words, dtype=np.float64)  # word == 8 bytes

    def write_array(self, byte_addr: int, values: np.ndarray) -> None:
        assert byte_addr % 8 == 0
        w = byte_addr // 8
        self.mem[w:w + values.size] = values.reshape(-1)

    def read_array(self, byte_addr: int, n: int) -> np.ndarray:
        w = byte_addr // 8
        return self.mem[w:w + n].copy()

    def execute(self, instrs: List[Instr]) -> None:
        for ins in instrs:
            self.execute_one(ins)

    def execute_one(self, ins: Instr) -> None:
        if ins.unit is Unit.IU:
            if ins.mnemonic == "addi":
                self.gpr[ins.dest] = self.gpr.get(ins.srcs[0], 0) + ins.imm
            else:  # pragma: no cover
                raise NotImplementedError(ins.mnemonic)
            return
        if ins.unit is Unit.LSU:
            ea = self.gpr[ins.mem.base] + ins.mem.offset
            if ea % 8 != 0:
                raise ValueError(f"unaligned access at {ea}: {ins}")
            w = ea // 8
            if ins.mnemonic == "lfpdx":
                if ea % 16 != 0:
                    raise ValueError(f"misaligned quad load at {ea}: {ins}")
                self.fpr[ins.dest] = (float(self.mem[w]), float(self.mem[w + 1]))
            elif ins.mnemonic == "lfdx":
                old = self.fpr.get(ins.dest, (0.0, 0.0))
                self.fpr[ins.dest] = (float(self.mem[w]), old[1])
            elif ins.mnemonic == "lfsdx":
                old = self.fpr.get(ins.dest, (0.0, 0.0))
                self.fpr[ins.dest] = (old[0], float(self.mem[w]))
            elif ins.mnemonic == "stfpdx":
                if ea % 16 != 0:
                    raise ValueError(f"misaligned quad store at {ea}: {ins}")
                v = self.fpr[ins.srcs[0]]
                self.mem[w], self.mem[w + 1] = v
            else:  # pragma: no cover
                raise NotImplementedError(ins.mnemonic)
            return
        # FPU
        mn = ins.mnemonic
        if mn in FPU_SEMANTICS:
            w = self.fpr[ins.srcs[0]]
            c = self.fpr[ins.srcs[1]]
            t = self.fpr.get(ins.dest, (0.0, 0.0))
            self.fpr[ins.dest] = FPU_SEMANTICS[mn](w, c, t)
        elif mn == "fpmadd":
            a, c, b = (self.fpr[s] for s in ins.srcs)
            self.fpr[ins.dest] = (a[0] * c[0] + b[0], a[1] * c[1] + b[1])
        elif mn == "fpadd":
            a, b = self.fpr[ins.srcs[0]], self.fpr[ins.srcs[1]]
            self.fpr[ins.dest] = (a[0] + b[0], a[1] + b[1])
        elif mn == "fsmr_p":
            a = self.fpr[ins.srcs[0]]
            t = self.fpr.get(ins.dest, (0.0, 0.0))
            self.fpr[ins.dest] = (a[0], t[1])
        elif mn == "fsmr_s":
            a = self.fpr[ins.srcs[0]]
            t = self.fpr.get(ins.dest, (0.0, 0.0))
            self.fpr[ins.dest] = (t[0], a[1])
        elif mn == "fpmr":
            self.fpr[ins.dest] = self.fpr[ins.srcs[0]]
        else:  # pragma: no cover
            raise NotImplementedError(mn)


@dataclasses.dataclass
class MemoryModel:
    """Stream-aware load-latency model of the L1/L2-prefetch/L3 hierarchy."""

    level: str = "L1"              # "L1" | "L2" | "L3" -- where streams live
    line_bytes: int = 32
    max_streams: int = 5           # deep-fetch prefetch streams (sect. 3.2)

    def __post_init__(self):
        self._lines_seen: set[int] = set()
        self._streams: Dict[int, int] = {}   # stream id (line) -> last line

    def load_latency(self, ea: int) -> int:
        if self.level == "L1":
            return L1_LOAD_LATENCY
        line = ea // self.line_bytes
        if line in self._lines_seen:
            return L1_LOAD_LATENCY
        self._lines_seen.add(line)
        # sequential-next line of a tracked stream: prefetched (L2 latency);
        # more concurrent streams than the prefetcher tracks degrade to L3.
        hit_stream = None
        for sid, last in self._streams.items():
            if line == last + 1:
                hit_stream = sid
                break
        if hit_stream is not None:
            self._streams[hit_stream] = line
            return L2_LOAD_LATENCY
        self._streams[line] = line
        if len(self._streams) > self.max_streams:
            oldest = next(iter(self._streams))
            del self._streams[oldest]
        return L3_LOAD_LATENCY if self.level == "L3" else L2_LOAD_LATENCY


@dataclasses.dataclass
class TimingResult:
    total_cycles: int
    per_iter_cycles: float
    stalls: Dict[str, int]
    issue_trace: Optional[List[Tuple[int, int]]] = None  # (instr idx, cycle)


def simulate_inorder(body: List[Instr], n_iters: int = 12,
                     gpr_init: Optional[Dict[str, int]] = None,
                     memory: Optional[MemoryModel] = None,
                     trace: bool = False) -> TimingResult:
    """In-order dual-issue timing simulation of ``body`` repeated n_iters times.

    Register/memory *values* are not tracked here (use Machine for that); only
    readiness times.  GPR values are tracked just enough to compute effective
    addresses for the memory model when provided.
    """
    ready: Dict[str, int] = {}
    gpr_val: Dict[str, int] = dict(gpr_init or {})
    stalls = {"data": 0, "fpu_busy": 0, "lsu_busy": 0}
    lsu_free = 0
    cycle = 0
    iter_marks: List[int] = []
    issue_trace: List[Tuple[int, int]] = []
    outstanding_misses: List[int] = []   # completion cycles of >L1 loads

    for it in range(n_iters):
        for bi, ins in enumerate(body):
            # earliest cycle all source operands are ready
            t_ready = max((ready.get(r, 0) for r in ins.srcs), default=0)
            t = max(cycle, t_ready)
            if ins.unit is Unit.LSU:
                t = max(t, lsu_free)
            if t > cycle and t > t_ready:
                stalls["lsu_busy" if ins.unit is Unit.LSU else "fpu_busy"] += t - max(cycle, t_ready)
            elif t > cycle:
                stalls["data"] += t - cycle
            lat = ins.latency
            if ins.unit is Unit.LSU and ins.mem and not ins.mem.is_store:
                if memory is not None:
                    ea = gpr_val.get(ins.mem.base, 0) + ins.mem.offset
                    lat = memory.load_latency(ea)
                    if lat > L1_LOAD_LATENCY:
                        # at most 3 outstanding L1 misses (sect. 3.2)
                        outstanding_misses[:] = [c for c in outstanding_misses
                                                 if c > t]
                        while len(outstanding_misses) >= 3:
                            t = min(outstanding_misses)
                            outstanding_misses[:] = [c for c in outstanding_misses
                                                     if c > t]
                        outstanding_misses.append(t + lat)
            if ins.unit is Unit.LSU:
                lsu_free = t + 2
            if ins.dest is not None:
                ready[ins.dest] = t + max(1, lat)
            if ins.unit is Unit.IU and ins.mnemonic == "addi":
                gpr_val[ins.dest] = gpr_val.get(ins.srcs[0], 0) + ins.imm
            if trace:
                issue_trace.append((bi, t))
            # in-order: next instruction cannot issue before this one
            cycle = t  # same-cycle dual issue allowed; unit checks enforce slots
            # advance cycle if both units would collide is handled by unit locks:
            # an FPU instr occupies the slot this cycle:
            if ins.unit is Unit.FPU:
                ready.setdefault("__fpu__", 0)
                if ready["__fpu__"] > t:
                    stalls["fpu_busy"] += ready["__fpu__"] - t
                    t = ready["__fpu__"]
                    if ins.dest is not None:
                        ready[ins.dest] = t + max(1, lat)
                ready["__fpu__"] = t + 1
                cycle = t
            elif ins.unit is Unit.IU:
                ready.setdefault("__iu__", 0)
                if ready["__iu__"] > t:
                    t = ready["__iu__"]
                    if ins.dest is not None:
                        ready[ins.dest] = t + max(1, lat)
                ready["__iu__"] = t + 1
                cycle = t
        iter_marks.append(cycle)

    total = max(ready.values()) if ready else 0
    if n_iters >= 6:
        # steady state: difference across the middle iterations
        a, b = n_iters // 3, 2 * n_iters // 3
        per_iter = (iter_marks[b] - iter_marks[a]) / (b - a)
    else:
        per_iter = iter_marks[-1] / n_iters
    return TimingResult(total, per_iter, stalls,
                        issue_trace if trace else None)
