"""Functional verification of synthesized kernels against numpy oracles.

Lays out real (frame-rows x P) arrays in the virtual machine's memory,
initializes the kernel's register state, executes the *scheduled* body T
times, and compares the written output region against a pure-numpy stencil.
This is the paper's "simulate ... to debug the code for results correctness"
loop (sect. 4.1).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from .scheduler import greedy_schedule
from .simulator import Machine
from .synth import StencilConfig, SynthKernel, synth_stencil


@dataclasses.dataclass
class VerifyResult:
    ok: bool
    max_abs_err: float
    produced: np.ndarray
    expected: np.ndarray


def _weights(cfg: StencilConfig, rng: np.random.Generator) -> Dict:
    if cfg.points == 3:
        return {"w": rng.uniform(0.5, 1.5, size=2)}          # [edge, center]
    if cfg.points == 7:
        return {"w": rng.uniform(0.5, 1.5, size=4)}          # [wc, wk, wi, wj]
    return {"w": rng.uniform(0.5, 1.5, size=(2, 2, 2))}      # w[|di|,|dj|,|dk|]


def _oracle(cfg: StencilConfig, a: np.ndarray, w) -> np.ndarray:
    """a: (I, J, P) frame; returns full-frame result (valid in the interior)."""
    r = np.zeros_like(a)
    if cfg.points == 3:
        r[:, :, 1:-1] = (w[0] * a[:, :, :-2] + w[1] * a[:, :, 1:-1]
                         + w[0] * a[:, :, 2:])
    elif cfg.points == 7:
        wc, wk, wi, wj = w
        r[1:-1, 1:-1, 1:-1] = (
            wc * a[1:-1, 1:-1, 1:-1]
            + wk * (a[1:-1, 1:-1, :-2] + a[1:-1, 1:-1, 2:])
            + wj * (a[1:-1, :-2, 1:-1] + a[1:-1, 2:, 1:-1])
            + wi * (a[:-2, 1:-1, 1:-1] + a[2:, 1:-1, 1:-1]))
    else:
        r3 = np.zeros_like(a)
        for di in (-1, 0, 1):
            for dj in (-1, 0, 1):
                for dk in (-1, 0, 1):
                    r3[1:-1, 1:-1, 1:-1] += (
                        w[abs(di), abs(dj), abs(dk)]
                        * a[1 + di:a.shape[0] - 1 + di,
                            1 + dj:a.shape[1] - 1 + dj,
                            1 + dk:a.shape[2] - 1 + dk])
        r = r3
    return r


def run_kernel(cfg: StencilConfig, t_iters: int = 8, seed: int = 0,
               kern: Optional[SynthKernel] = None,
               schedule: bool = True) -> VerifyResult:
    kern = kern or synth_stencil(cfg)
    rng = np.random.default_rng(seed)
    w = _weights(cfg, rng)["w"]

    frame_i = max(r[0] for r in kern.rows) + 1
    frame_j = max(r[1] for r in kern.rows) + 1
    p_words = 2 * (t_iters * kern.k_steps) + 8
    a = rng.standard_normal((frame_i, frame_j, p_words))

    m = Machine(mem_words=1 << 18)
    a_base = 64                          # byte addr, 16B aligned
    row_stride = p_words * 8
    # R array origin: staggered by 8 bytes for straddling result pairs so the
    # quad stores land on 16-byte boundaries (paper sect. 5.4 remark).
    r0 = a_base + frame_i * frame_j * row_stride + 64
    if not kern.aligned_results:
        r0 += 8
    m.write_array(a_base, a)

    # initial register state
    k0 = 2 if kern.aligned_results else 0   # first k of the first iteration
    for reg, spec in kern.init_fprs.items():
        tag, _, arg = spec.partition(":")
        if tag == "W3":
            m.fpr[reg] = (float(w[0]), float(w[1]))
        elif tag == "W27":
            p, q = (int(x) for x in arg.split(","))
            m.fpr[reg] = (float(w[p, q, 0]), float(w[p, q, 1]))
        elif tag == "W7kc":
            m.fpr[reg] = (float(w[0]), float(w[1]))
        elif tag == "W7ij":
            m.fpr[reg] = (float(w[2]), float(w[3]))
        else:
            ii, jj = (int(x) for x in arg.split(",")[:2])
            row = a[ii, jj]
            if tag in ("X3",):                       # [a_0 | a_1]
                m.fpr[reg] = (float(row[0]), float(row[1]))
            elif tag == "X7":                        # [a_{k0-1} | a_{k0}]
                m.fpr[reg] = (float(row[k0 - 1]), float(row[k0]))
            elif tag == "Qm1":                       # [a_{k0-2} | a_{k0-1}]
                m.fpr[reg] = (float(row[k0 - 2]), float(row[k0 - 1]))
            elif tag in ("Q", "Q7"):                 # [a_{k0} | a_{k0+1}]
                m.fpr[reg] = (float(row[k0]), float(row[k0 + 1]))
            else:  # pragma: no cover
                raise ValueError(spec)

    ks = 1 if not kern.aligned_results else k0   # k index of first stored word
    for (ii, jj), g in kern.row_gpr.items():
        m.gpr[g] = a_base + (ii * frame_j + jj) * row_stride + 8 * k0
    for (i, j), g in kern.out_gpr.items():
        m.gpr[g] = r0 + (i * frame_j + j) * row_stride + 8 * ks

    body = kern.body
    if schedule:
        sched = greedy_schedule(kern.body)
        body = [kern.body[i] for i in sched.order]
    for _ in range(t_iters):
        m.execute(body)

    expected_full = _oracle(cfg, a, w)
    n_written = 2 * t_iters * kern.k_steps
    prod_rows, exp_rows = [], []
    for (i, j) in kern.out_rows:
        base = r0 + (i * frame_j + j) * row_stride + 8 * ks
        prod_rows.append(m.read_array(base, n_written))
        exp_rows.append(expected_full[i, j, ks:ks + n_written])
    produced = np.stack(prod_rows)
    expected = np.stack(exp_rows)
    err = float(np.max(np.abs(produced - expected))) if produced.size else 0.0
    ok = bool(np.allclose(produced, expected, rtol=1e-12, atol=1e-12))
    return VerifyResult(ok, err, produced, expected)
