"""Faithful reproduction of the paper's code-synthesis + scheduling framework."""

from .dag import build_dag, critical_path_length, lower_bound  # noqa: F401
from .isa import CLOCK_MHZ, Instr, Unit  # noqa: F401
from .perfmodel import (PAPER_TABLE2, PAPER_TABLE3, PerfEstimate,  # noqa: F401
                        analyze)
from .scheduler import Schedule, bb_schedule, greedy_schedule  # noqa: F401
from .simulator import Machine, MemoryModel, simulate_inorder  # noqa: F401
from .synth import (PAPER_CONFIGS, StencilConfig, SynthKernel,  # noqa: F401
                    synth_stencil)
from .verify import run_kernel  # noqa: F401
