"""Unroll-and-jam synthesis of 3-, 7-, 27-point stencil kernels (paper sect. 4.2/4.3).

Builds straight-line steady-state loop bodies from the mutate-mutate (mm) and
load-copy (lc) 3-point sub-kernels.  SIMD FPRs pack two consecutive k-elements
(one register computes two stencils).  Per-iteration resource counts reproduce
the paper's Tables 1 and 2 exactly (see tests); the single documented
exception is the 7-lc input-register column (DESIGN.md sect. 8).

Register schemes (k index 2t per iteration t):

* ``mm`` row, *straddling* results [r_{2t+1}|r_{2t+2}] (3-pt, 27-pt):
  one register X cycles [a_{2t}|a_{2t+1}] -(lfdx a_{2t+2})-> [a_{2t+2}|a_{2t+1}]
  -(lfsdx a_{2t+3})-> [a_{2t+2}|a_{2t+3}]; per served output: parallel-edge,
  cross-center, parallel-edge multiply-adds on the three phases.
* ``mm`` row, *aligned* results [r_{2t}|r_{2t+1}] (7-pt):
  X cycles [a_{2t-1}|a_{2t}] -> [a_{2t+1}|a_{2t}] -> [a_{2t+1}|a_{2t+2}];
  the middle (reversed) phase also serves transverse-neighbour outputs with a
  single cross madd each.
* ``lc`` stream (3-pt, straddling results): two registers; per iteration one
  aligned quad load, one half-copy (fsmr_p) forming the reversed unaligned
  pair, three multiply(-add)s -- exactly the paper's Figure 7 sequence.
* quad side row (7-pt, aligned results): one aligned quad load feeding one
  parallel madd per served output.

The 27-point stencil is the superposition of nine 3-point kernels, one per
(di,dj) input row, sharing four packed weight registers W[|di|][|dj|] =
[w_center | w_edge]; the 7-point uses W1=[wc|wk], W2=[wi|wj].
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .isa import (Instr, addi, fsmr_p, fxcpmadd, fxcpmul, fxcpxmadd,
                  fxcpxmul, fxcsmadd, fxcsmul, fxcsxmadd, fxcsxmul, lfdx,
                  lfpdx, lfsdx, stfpdx)


@dataclasses.dataclass(frozen=True)
class StencilConfig:
    points: int          # 3 | 7 | 27
    kernel: str          # "mm" | "lc"
    ui: int              # unroll (jam) factor in i
    uj: int              # unroll (jam) factor in j

    @property
    def name(self) -> str:
        return f"{self.points}-{self.kernel}-{self.ui}x{self.uj}"

    @property
    def stencils_per_iter(self) -> int:
        return 2 * self.ui * self.uj


@dataclasses.dataclass
class Counts:
    mutate_loads: int = 0
    quad_loads: int = 0
    stores: int = 0
    fpu_arith: int = 0      # mul/madd
    fpu_copies: int = 0
    iu_ops: int = 0
    input_regs: int = 0
    result_regs: int = 0
    weight_regs: int = 0

    @property
    def loads(self) -> int:
        return self.mutate_loads + self.quad_loads

    @property
    def fpu(self) -> int:
        return self.fpu_arith + self.fpu_copies

    @property
    def lsu_cycles(self) -> int:
        return 2 * (self.loads + self.stores)

    @property
    def read_bytes(self) -> int:
        return 8 * self.mutate_loads + 16 * self.quad_loads

    @property
    def write_bytes(self) -> int:
        return 16 * self.stores


@dataclasses.dataclass
class SynthKernel:
    """A synthesized steady-state loop body plus metadata for verification."""

    config: StencilConfig
    body: List[Instr]                    # k_steps logical iterations + bumps
    k_steps: int                         # logical iterations per body
    counts: Counts                       # per ONE logical iteration
    rows: List[Tuple[int, int]]          # input rows (ii, jj) in the frame
    out_rows: List[Tuple[int, int]]      # output rows (i, j)
    row_gpr: Dict[Tuple[int, int], str]
    out_gpr: Dict[Tuple[int, int], str]
    init_fprs: Dict[str, str]            # reg -> spec, e.g. "X:ii,jj" | "W:p,q"
    aligned_results: bool                # False => straddling result pairs
    steps: List[List[Instr]] = dataclasses.field(default_factory=list)
    bumps: List[Instr] = dataclasses.field(default_factory=list)

    @property
    def single_step(self) -> List[Instr]:
        """One logical iteration (the unit the paper's simulator times)."""
        return self.steps[0] if self.steps else self.body


def _acc(i: int, j: int) -> str:
    return f"f_acc_{i}_{j}"


def _first_op(initialized: set, acc: str, mul_fn, madd_fn, *args):
    """Emit the accumulator-initializing mul for the first touch, madd after."""
    if acc in initialized:
        return madd_fn(acc, *args)
    initialized.add(acc)
    return mul_fn(acc, *args)


def synth_stencil(cfg: StencilConfig) -> SynthKernel:
    if cfg.points == 3:
        return _synth_3pt(cfg)
    if cfg.points == 7:
        return _synth_7pt(cfg)
    if cfg.points == 27:
        return _synth_27pt(cfg)
    raise ValueError(f"unsupported stencil: {cfg.points}")


# ---------------------------------------------------------------------------
# 3-point: independent 1-D streams, jammed over ui x uj rows.
# ---------------------------------------------------------------------------

def _synth_3pt(cfg: StencilConfig) -> SynthKernel:
    rows = [(i, j) for i in range(cfg.ui) for j in range(cfg.uj)]
    row_gpr = {r: f"g_a_{r[0]}_{r[1]}" for r in rows}
    out_gpr = {r: f"g_r_{r[0]}_{r[1]}" for r in rows}
    init_fprs: Dict[str, str] = {"f_W": "W3"}
    body: List[Instr] = []
    counts = Counts(stores=len(rows), weight_regs=1,
                    result_regs=len(rows))

    steps: List[List[Instr]] = []
    if cfg.kernel == "lc":
        k_steps = 2
        counts.quad_loads = len(rows)
        counts.fpu_arith = 3 * len(rows)
        counts.fpu_copies = len(rows)
        counts.input_regs = 2 * len(rows)
        for r in rows:
            init_fprs[f"f_q_{r[0]}_{r[1]}_0"] = f"Q:{r[0]},{r[1]},0"
        for s in range(k_steps):
            step_start = len(body)
            for r in rows:
                g, gr = row_gpr[r], out_gpr[r]
                cur = f"f_q_{r[0]}_{r[1]}_{s % 2}"      # [a_2t | a_2t+1]
                nxt = f"f_q_{r[0]}_{r[1]}_{(s + 1) % 2}"
                acc = _acc(*r)
                body.append(lfpdx(nxt, g, 16 + 16 * s, comment=f"Q_next {r}"))
                # r = w0 * [a_2t | a_2t+1]  (parallel, W.p = w_edge)
                body.append(fxcpmul(acc, "f_W", cur, comment="(a) edge par"))
                # copy: cur becomes [a_2t+2 | a_2t+1] (the reversed pair)
                body.append(fsmr_p(cur, nxt, comment="(copy)"))
                # r += w1 * reversed pair (cross, W.s = w_center)
                body.append(fxcsxmadd(acc, "f_W", cur, comment="(b) center cross"))
                # r += w0 * [a_2t+2 | a_2t+3]  (parallel)
                body.append(fxcpmadd(acc, "f_W", nxt, comment="(c) edge par"))
                body.append(stfpdx(acc, gr, 16 * s))
            steps.append(body[step_start:])
    elif cfg.kernel == "mm":
        k_steps = 1
        counts.mutate_loads = 2 * len(rows)
        counts.fpu_arith = 3 * len(rows)
        counts.input_regs = len(rows)
        for r in rows:
            init_fprs[f"f_x_{r[0]}_{r[1]}"] = f"X3:{r[0]},{r[1]}"
        for r in rows:
            g, gr = row_gpr[r], out_gpr[r]
            x = f"f_x_{r[0]}_{r[1]}"
            acc = _acc(*r)
            # X = [a_2t | a_2t+1]
            body.append(fxcpmul(acc, "f_W", x, comment="(A) edge par"))
            body.append(lfdx(x, g, 16, comment="mutate.p <- a_2t+2"))
            # X = [a_2t+2 | a_2t+1]
            body.append(fxcsxmadd(acc, "f_W", x, comment="(B) center cross"))
            body.append(lfsdx(x, g, 24, comment="mutate.s <- a_2t+3"))
            # X = [a_2t+2 | a_2t+3]
            body.append(fxcpmadd(acc, "f_W", x, comment="(C) edge par"))
            body.append(stfpdx(acc, gr, 0))
        steps.append(list(body))
    else:
        raise ValueError(cfg.kernel)

    bumps = _bumps(row_gpr, out_gpr, k_steps, counts)
    body.extend(bumps)
    return SynthKernel(cfg, body, k_steps, counts, rows, rows, row_gpr,
                       out_gpr, init_fprs, aligned_results=False,
                       steps=steps, bumps=bumps)


# ---------------------------------------------------------------------------
# 27-point: every frame row contributes a full 3-point to every output within
# Chebyshev distance 1.  Straddling results; all rows mutate-mutate.
# ---------------------------------------------------------------------------

def _synth_27pt(cfg: StencilConfig) -> SynthKernel:
    if cfg.kernel != "mm":
        raise ValueError("27-point kernels use mutate-mutate (paper sect. 5.3)")
    rows = [(ii, jj) for ii in range(cfg.ui + 2) for jj in range(cfg.uj + 2)]
    outs = [(i, j) for i in range(1, cfg.ui + 1) for j in range(1, cfg.uj + 1)]
    row_gpr = {r: f"g_a_{r[0]}_{r[1]}" for r in rows}
    out_gpr = {o: f"g_r_{o[0]}_{o[1]}" for o in outs}
    init_fprs = {f"f_W_{p}_{q}": f"W27:{p},{q}" for p in (0, 1) for q in (0, 1)}
    for r in rows:
        init_fprs[f"f_x_{r[0]}_{r[1]}"] = f"X3:{r[0]},{r[1]}"

    counts = Counts(mutate_loads=2 * len(rows), stores=len(outs),
                    fpu_arith=27 * len(outs), input_regs=len(rows),
                    result_regs=len(outs), weight_regs=4)
    body: List[Instr] = []
    initialized: set = set()
    served = {r: [o for o in outs
                  if abs(o[0] - r[0]) <= 1 and abs(o[1] - r[1]) <= 1]
              for r in rows}
    for r in rows:
        g = row_gpr[r]
        x = f"f_x_{r[0]}_{r[1]}"
        for o in served[r]:
            w = f"f_W_{abs(o[0] - r[0])}_{abs(o[1] - r[1])}"
            # phase A on X1=[a_2t|a_2t+1]: parallel edge (W.s)
            body.append(_first_op(initialized, _acc(*o), fxcsmul, fxcsmadd,
                                  w, x))
        body.append(lfdx(x, g, 16, comment=f"mutate.p row {r}"))
        for o in served[r]:
            w = f"f_W_{abs(o[0] - r[0])}_{abs(o[1] - r[1])}"
            # phase B on X2=[a_2t+2|a_2t+1]: cross center (W.p)
            body.append(fxcpxmadd(_acc(*o), w, x))
        body.append(lfsdx(x, g, 24, comment=f"mutate.s row {r}"))
        for o in served[r]:
            w = f"f_W_{abs(o[0] - r[0])}_{abs(o[1] - r[1])}"
            # phase C on X3=[a_2t+2|a_2t+3]: parallel edge (W.s)
            body.append(fxcsmadd(_acc(*o), w, x))
    for o in outs:
        body.append(stfpdx(_acc(*o), out_gpr[o], 0))
    steps = [list(body)]
    bumps = _bumps(row_gpr, out_gpr, 1, counts)
    body.extend(bumps)
    return SynthKernel(cfg, body, 1, counts, rows, outs, row_gpr, out_gpr,
                       init_fprs, aligned_results=False, steps=steps,
                       bumps=bumps)


# ---------------------------------------------------------------------------
# 7-point: aligned results.  Centre rows = output rows (full 3-pt in k);
# transverse neighbours contribute the single k-centre element.
# ---------------------------------------------------------------------------

def _synth_7pt(cfg: StencilConfig) -> SynthKernel:
    frame = [(ii, jj) for ii in range(cfg.ui + 2) for jj in range(cfg.uj + 2)]
    corners = {(0, 0), (0, cfg.uj + 1), (cfg.ui + 1, 0), (cfg.ui + 1, cfg.uj + 1)}
    rows = [r for r in frame if r not in corners]
    outs = [(i, j) for i in range(1, cfg.ui + 1) for j in range(1, cfg.uj + 1)]
    centers = set(outs)
    row_gpr = {r: f"g_a_{r[0]}_{r[1]}" for r in rows}
    out_gpr = {o: f"g_r_{o[0]}_{o[1]}" for o in outs}
    init_fprs: Dict[str, str] = {"f_W1": "W7kc", "f_W2": "W7ij"}

    counts = Counts(stores=len(outs), result_regs=len(outs), weight_regs=2)
    body: List[Instr] = []
    initialized: set = set()

    def side_served(r: Tuple[int, int]) -> List[Tuple[Tuple[int, int], str]]:
        """Outputs receiving this row's k-centre pair, with direction i|j."""
        out: List[Tuple[Tuple[int, int], str]] = []
        for o in outs:
            di, dj = abs(o[0] - r[0]), abs(o[1] - r[1])
            if (di, dj) == (1, 0):
                out.append((o, "i"))
            elif (di, dj) == (0, 1):
                out.append((o, "j"))
        return out

    if cfg.kernel == "mm":
        k_steps = 1
        counts.mutate_loads = 2 * len(centers)
        counts.quad_loads = len(rows) - len(centers)
        counts.fpu_arith = 7 * len(outs)
        counts.input_regs = len(rows)
        for r in rows:
            tag = "X7" if r in centers else "Q7"
            init_fprs[f"f_x_{r[0]}_{r[1]}"] = f"{tag}:{r[0]},{r[1]}"
        for r in rows:
            g = row_gpr[r]
            x = f"f_x_{r[0]}_{r[1]}"
            if r in centers:
                acc = _acc(*r)
                # X1=[a_2t-1|a_2t]: parallel wk (W1.s)
                body.append(_first_op(initialized, acc, fxcsmul, fxcsmadd,
                                      "f_W1", x))
                body.append(lfdx(x, g, 8, comment=f"mutate.p row {r}"))
                # X2=[a_2t+1|a_2t]: cross wc (W1.p) + transverse serves
                body.append(fxcpxmadd(acc, "f_W1", x))
                for (o, d) in side_served(r):
                    mulv = fxcpxmul if d == "i" else fxcsxmul
                    maddv = fxcpxmadd if d == "i" else fxcsxmadd
                    body.append(_first_op(initialized, _acc(*o), mulv, maddv,
                                          "f_W2", x))
                body.append(lfsdx(x, g, 16, comment=f"mutate.s row {r}"))
                # X3=[a_2t+1|a_2t+2]: parallel wk (W1.s)
                body.append(fxcsmadd(acc, "f_W1", x))
            else:
                body.append(lfpdx(x, g, 0, comment=f"side quad row {r}"))
                for (o, d) in side_served(r):
                    mulv = fxcpmul if d == "i" else fxcsmul
                    maddv = fxcpmadd if d == "i" else fxcsmadd
                    body.append(_first_op(initialized, _acc(*o), mulv, maddv,
                                          "f_W2", x))
    elif cfg.kernel == "lc":
        k_steps = 3
        counts.quad_loads = len(rows)
        counts.fpu_arith = 7 * len(outs)
        counts.fpu_copies = len(centers)
        counts.input_regs = 3 * len(centers) + (len(rows) - len(centers))
        for r in rows:
            if r in centers:
                init_fprs[f"f_q_{r[0]}_{r[1]}_0"] = f"Qm1:{r[0]},{r[1]}"  # Q_{t-1}
                init_fprs[f"f_q_{r[0]}_{r[1]}_1"] = f"Q7:{r[0]},{r[1]}"   # Q_t
            else:
                init_fprs[f"f_x_{r[0]}_{r[1]}"] = f"Q7:{r[0]},{r[1]}"
        steps: List[List[Instr]] = []
        for s in range(k_steps):
            initialized.clear()
            step_start = len(body)
            for r in rows:
                g = row_gpr[r]
                if r in centers:
                    acc = _acc(*r)
                    q_m1 = f"f_q_{r[0]}_{r[1]}_{s % 3}"        # Q_{t-1}
                    q_t = f"f_q_{r[0]}_{r[1]}_{(s + 1) % 3}"   # Q_t
                    q_p1 = f"f_q_{r[0]}_{r[1]}_{(s + 2) % 3}"  # Q_{t+1} (free)
                    body.append(lfpdx(q_p1, g, 16 + 16 * s,
                                      comment=f"Q_next row {r}"))
                    # Y = [a_2t+2 | a_2t-1]
                    body.append(fsmr_p(q_m1, q_p1, comment="(copy)"))
                    # op1: cross wk on Y (W1.s)
                    body.append(_first_op(initialized, acc, fxcsxmul,
                                          fxcsxmadd, "f_W1", q_m1))
                    # op2: parallel wc on Q_t (W1.p)
                    body.append(fxcpmadd(acc, "f_W1", q_t))
                    # op3: cross wk on Q_t (W1.s)
                    body.append(fxcsxmadd(acc, "f_W1", q_t))
                    for (o, d) in side_served(r):
                        mulv = fxcpmul if d == "i" else fxcsmul
                        maddv = fxcpmadd if d == "i" else fxcsmadd
                        body.append(_first_op(initialized, _acc(*o), mulv,
                                              maddv, "f_W2", q_t))
                else:
                    x = f"f_x_{r[0]}_{r[1]}"
                    body.append(lfpdx(x, g, 16 * s, comment=f"side quad {r}"))
                    for (o, d) in side_served(r):
                        mulv = fxcpmul if d == "i" else fxcsmul
                        maddv = fxcpmadd if d == "i" else fxcsmadd
                        body.append(_first_op(initialized, _acc(*o), mulv,
                                              maddv, "f_W2", x))
            for o in outs:
                body.append(stfpdx(_acc(*o), out_gpr[o], 16 * s))
            steps.append(body[step_start:])
        bumps = _bumps(row_gpr, out_gpr, k_steps, counts)
        body.extend(bumps)
        return SynthKernel(cfg, body, k_steps, counts, rows, outs, row_gpr,
                           out_gpr, init_fprs, aligned_results=True,
                           steps=steps, bumps=bumps)
    else:
        raise ValueError(cfg.kernel)

    for o in outs:
        body.append(stfpdx(_acc(*o), out_gpr[o], 0))
    steps = [list(body)]
    bumps = _bumps(row_gpr, out_gpr, 1, counts)
    body.extend(bumps)
    return SynthKernel(cfg, body, 1, counts, rows, outs, row_gpr, out_gpr,
                       init_fprs, aligned_results=True, steps=steps,
                       bumps=bumps)


def _bumps(row_gpr: Dict, out_gpr: Dict, k_steps: int, counts: Counts) -> List[Instr]:
    out: List[Instr] = []
    step = 16 * k_steps
    for g in row_gpr.values():
        out.append(addi(g, g, step))
    for g in out_gpr.values():
        out.append(addi(g, g, step))
    counts.iu_ops = len(out)
    return out


PAPER_CONFIGS: List[StencilConfig] = [
    StencilConfig(27, "mm", 1, 1),
    StencilConfig(27, "mm", 1, 2),
    StencilConfig(27, "mm", 1, 3),
    StencilConfig(27, "mm", 2, 2),
    StencilConfig(27, "mm", 2, 3),
    StencilConfig(7, "mm", 2, 3),
    StencilConfig(7, "lc", 2, 3),
    StencilConfig(3, "lc", 1, 1),
    StencilConfig(3, "lc", 2, 1),
    StencilConfig(3, "lc", 2, 2),
    StencilConfig(3, "lc", 2, 3),
    StencilConfig(3, "lc", 2, 4),
]
