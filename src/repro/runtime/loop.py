"""Fault-tolerant training loop: accumulation, compression, checkpoints.

``make_train_step`` builds the jitted step:
  * GSPMD path (default): loss over the sharded global batch; autodiff's
    implicit collectives carry the DP reduction (overlapped by XLA's
    latency-hiding scheduler).
  * Compressed-DP path: shard_map over the data axis with an explicit int8
    error-feedback all-reduce (compression/gradient.py).

Gradient accumulation scans over microbatches inside the step.  The Trainer
wraps the loop with checkpoint/restart (atomic keep-K, async), preemption
("checkpoint now") handling, and straggler detection.
"""

from __future__ import annotations

import dataclasses
import signal
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..compression import compressed_psum, init_error_feedback
from ..models.api import Model
from ..optim import Optimizer, clip_by_global_norm
from .straggler import StepTimer

Params = Any


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    accum: int = 1                  # gradient-accumulation microbatches
    clip_norm: float = 1.0
    compress_grads: bool = False    # int8 error-feedback DP all-reduce
    log_every: int = 10


def make_train_step(model: Model, opt: Optimizer, lr_fn: Callable,
                    tc: TrainConfig, mesh=None, data_axis: str = "data"):
    """Returns step(state, batch) -> (state, metrics); jit at call site."""

    def grads_of(params, batch):
        if tc.accum == 1:
            return jax.value_and_grad(model.loss_fn)(params, batch)

        def micro(c, mb):
            loss, g = jax.value_and_grad(model.loss_fn)(params, mb)
            acc_loss, acc_g = c
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_g, g)), None

        mbs = jax.tree.map(
            lambda x: x.reshape(tc.accum, x.shape[0] // tc.accum,
                                *x.shape[1:]), batch)
        zero = jax.tree.map(jnp.zeros_like, params)
        (loss, g), _ = jax.lax.scan(micro, (jnp.zeros(()), zero), mbs)
        inv = 1.0 / tc.accum
        return loss * inv, jax.tree.map(lambda t: t * inv, g)

    if not tc.compress_grads:
        def step(state, batch):
            loss, grads = grads_of(state["params"], batch)
            grads, gnorm = clip_by_global_norm(grads, tc.clip_norm)
            lr = lr_fn(state["step"])
            new_p, new_opt = opt.update(grads, state["opt"], state["params"],
                                        lr)
            return ({"params": new_p, "opt": new_opt,
                     "step": state["step"] + 1},
                    {"loss": loss, "gnorm": gnorm, "lr": lr})
        return step

    # Compressed-DP path: explicit collectives via shard_map.
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def sharded_step(state, batch):
        def inner(st, b):
            loss, grads = grads_of(st["params"], b)
            grads, new_ef = compressed_psum(grads, st["ef"], data_axis)
            loss = jax.lax.pmean(loss, data_axis)
            grads, gnorm = clip_by_global_norm(grads, tc.clip_norm)
            lr = lr_fn(st["step"])
            new_p, new_opt = opt.update(grads, st["opt"], st["params"], lr)
            return ({"params": new_p, "opt": new_opt, "ef": new_ef,
                     "step": st["step"] + 1},
                    {"loss": loss, "gnorm": gnorm, "lr": lr})

        state_spec = jax.tree.map(lambda _: P(), state)
        state_spec["ef"] = jax.tree.map(lambda _: P(), state["ef"])
        batch_spec = jax.tree.map(lambda _: P(data_axis), batch)
        return shard_map(inner, mesh=mesh,
                         in_specs=(state_spec, batch_spec),
                         out_specs=(state_spec,
                                    jax.tree.map(lambda _: P(),
                                                 {"loss": 0, "gnorm": 0,
                                                  "lr": 0})),
                         check_rep=False)(state, batch)

    return sharded_step


def init_train_state(model: Model, opt: Optimizer, key,
                     compress: bool = False) -> Dict:
    params = model.init(key)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if compress:
        state["ef"] = init_error_feedback(params)
    return state


class Trainer:
    """Checkpointed, preemption-safe, straggler-aware training driver."""

    def __init__(self, model: Model, opt: Optimizer, lr_fn, tc: TrainConfig,
                 dataset, mesh=None):
        self.model, self.opt, self.lr_fn, self.tc = model, opt, lr_fn, tc
        self.dataset = dataset
        self.mesh = mesh
        self.ckpt = (CheckpointManager(tc.ckpt_dir, keep=tc.keep)
                     if tc.ckpt_dir else None)
        self.timer = StepTimer()
        self._preempted = False
        self.metrics_log = []

    def _handle_preemption(self, *_):
        self._preempted = True

    def run(self, key, state: Optional[Dict] = None) -> Dict:
        step_fn = jax.jit(make_train_step(self.model, self.opt, self.lr_fn,
                                          self.tc, mesh=self.mesh))
        if state is None:
            state = init_train_state(self.model, self.opt, key,
                                     self.tc.compress_grads)
            start = 0
            if self.ckpt and self.ckpt.latest_step() is not None:
                state, manifest = self.ckpt.restore(state)
                start = int(manifest["step"])
        else:
            start = int(state["step"])

        old = signal.signal(signal.SIGTERM, self._handle_preemption)
        try:
            for step in range(start, self.tc.steps):
                batch = jax.tree.map(jnp.asarray,
                                     self.dataset.global_batch(step))
                self.timer.start()
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                self.timer.stop(step)
                if step % self.tc.log_every == 0 or step == self.tc.steps - 1:
                    self.metrics_log.append(
                        {"step": step,
                         "loss": float(metrics["loss"]),
                         "gnorm": float(metrics["gnorm"])})
                if self.ckpt and ((step + 1) % self.tc.ckpt_every == 0
                                  or self._preempted):
                    self.ckpt.save_async(step + 1, state,
                                         meta={"preempted": self._preempted})
                if self._preempted:
                    break
        finally:
            if self.ckpt:
                self.ckpt.wait()
            signal.signal(signal.SIGTERM, old)
        return state
