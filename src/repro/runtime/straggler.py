"""Per-step wall-time monitoring / straggler mitigation hooks.

On a real multi-pod deployment every SPMD step is gang-scheduled, so a
straggling host surfaces as a slow *global* step.  The mitigation ladder is:
flag (log), then checkpoint + evict via the elastic-restart path (the
checkpoint layer restores onto any mesh).  Here we implement the detector
and the policy hook; the restart itself is exercised in tests through
CheckpointManager's elastic restore.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StepTimer:
    window: int = 32
    threshold: float = 2.0                 # x median => straggler suspicion
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def __post_init__(self):
        self._times: List[float] = []
        self._t0: Optional[float] = None
        self.flagged: List[int] = []

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        if self._t0 is None:
            raise RuntimeError(
                f"StepTimer.stop(step={step}) called before start(); call "
                f"start() at the top of each timed step")
        dt = time.perf_counter() - self._t0
        self._t0 = None
        med = statistics.median(self._times) if self._times else dt
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) >= 8 and dt > self.threshold * med:
            self.flagged.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, med)
        return dt

    @property
    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0
