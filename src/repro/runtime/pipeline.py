"""Pipeline parallelism: GPipe-style microbatch pipeline over a mesh axis.

An alternative mapping for the multi-pod ``pod`` axis (DESIGN.md sect. 6):
layer stages live on successive devices of the pipe axis; activations flow
stage-to-stage via ``lax.ppermute`` while microbatches stream through a
(M + S - 1)-tick schedule.  Bubble fraction is the usual (S-1)/(M+S-1);
each tick overlaps one send with the next compute (XLA schedules the
ppermute against the stage computation).

This module is deliberately model-agnostic: ``stage_fn(stage_params, x)``
is any per-stage function with matching in/out activation shapes (the
transformer trunk satisfies this).  Used standalone + in tests; the
production meshes in this repo default to DP over the pod axis.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Params = Any


def pipeline_forward(stage_fn: Callable, stage_params: Params, x: jax.Array,
                     mesh: Mesh, axis: str = "pipe",
                     n_microbatches: int | None = None) -> jax.Array:
    """Run x through S pipeline stages laid out on ``axis``.

    stage_params: pytree with leading axis S (one slice per stage).
    x: (B, ...) global batch; B must divide into n_microbatches.
    Returns f_{S-1}(...f_0(x)) with identical semantics to the sequential
    composition (verified in tests/test_pipeline.py).
    """
    s = mesh.shape[axis]
    m = n_microbatches or s
    b = x.shape[0]
    if b % m:
        raise ValueError(f"batch {b} must divide into {m} microbatches")
    mb = b // m
    xm = x.reshape(m, mb, *x.shape[1:])

    def local(params_all, xm_loc):
        # params_all arrives as this stage's slice (leading dim 1)
        params_stage = jax.tree.map(lambda t: t[0], params_all)
        idx = jax.lax.axis_index(axis)
        ticks = m + s - 1
        fwd_perm = [(i, i + 1) for i in range(s - 1)]

        def tick(buf, t):
            # stage 0 injects microbatch t; others consume the ppermuted buf
            mb_idx = jnp.clip(t, 0, m - 1)
            inp = jnp.where(idx == 0, xm_loc[mb_idx], buf)
            out = stage_fn(params_stage, inp)
            nxt = jax.lax.ppermute(out, axis, fwd_perm)
            # last stage emits microbatch t - (s - 1) at tick t
            emit_m = t - (s - 1)
            keep = (idx == s - 1) & (emit_m >= 0) & (emit_m < m)
            emitted = jnp.where(keep, out, jnp.zeros_like(out))
            return nxt, emitted

        zero = jnp.zeros_like(xm_loc[0])
        _, emitted = jax.lax.scan(tick, zero, jnp.arange(ticks))
        outs = emitted[s - 1:]                     # (M, mb, ...)
        # broadcast the last stage's results to every pipe rank
        return jax.lax.psum(outs, axis)

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    out = shard_map(local, mesh=mesh,
                    in_specs=(spec_p, P()),
                    out_specs=P(),
                    check_rep=False)(stage_params, xm)
    return out.reshape(b, *x.shape[1:])


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
