from .loop import Trainer, TrainConfig, make_train_step  # noqa: F401
from .pipeline import bubble_fraction, pipeline_forward  # noqa: F401
from .straggler import StepTimer  # noqa: F401
