"""int8 gradient compression with error feedback for the DP all-reduce.

Inside a ``shard_map``-ed data-parallel train step, gradients are quantized
to int8 with a per-tensor scale, summed across the data axis (int32
accumulator -- 4x less traffic than fp32 on the wire), and dequantized; the
quantization residual is carried as error feedback so the compression is
unbiased over time (Karimireddy et al., 2019).  Under pure GSPMD the
all-reduce is implicit and uncompressible, so the compressed path is an
explicit-collective alternative train step (runtime/loop.py selects it).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def init_error_feedback(grads_template: Params) -> Params:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_template)


def compress_decompress(g: jax.Array, ef: jax.Array
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize g+ef to int8; returns (q, scale, new_ef)."""
    target = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(target)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, target - deq


def compressed_psum(grads: Params, ef: Params, axis_name: str
                    ) -> Tuple[Params, Params]:
    """All-reduce-mean int8-compressed grads over ``axis_name``.

    Returns (mean_grads_fp32, new_error_feedback).  Scales are reduced with
    max so one shared scale decodes every shard's payload.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        local_scale = jnp.maximum(jnp.max(jnp.abs(target)), 1e-12) / 127.0
        scale = jax.lax.pmax(local_scale, axis_name)
        q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
        new_ef = target - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
        return mean, new_ef

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
