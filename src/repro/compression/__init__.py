from .gradient import (compress_decompress, compressed_psum,  # noqa: F401
                       init_error_feedback)
