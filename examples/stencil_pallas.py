"""The paper's stencils through the unified Pallas engine (interpret mode).

Shows the TPU adaptation: one kernel body serves every mask in the registry
at any radius (radius-1 built-ins plus the radius-2 star13/box125); the jam
factor became the cost-model-chosen VMEM i-block; fused Jacobi sweeps keep
the working set VMEM-resident across operator applications (the paper's
register-resident steady-state stream); and the i-axis shards over devices
with halo exchange.

Run:  PYTHONPATH=src python examples/stencil_pallas.py
(sharded demo needs >1 device, e.g.
 XLA_FLAGS=--xla_force_host_platform_device_count=2)
"""

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import (bytes_per_point, list_stencils, spec_from_mask,
                           stencil_apply, stencil_ref, stencil_sharded)
from repro.kernels.stencil_engine import autotune_engine


def main() -> None:
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((32, 48, 128)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)

    names = sorted({s.name for s in list_stencils().values()})
    print(f"[engine] registry: {names}")
    path, bi, bj = autotune_engine(*a.shape, a.dtype.itemsize, sweeps=1)
    print(f"[engine] grid {a.shape}, cost model picks path={path!r}, "
          f"i-block = {bi} (roofline max(DMA, VPU) per point, cf. paper "
          f"Table 2): {bytes_per_point('stream', a.dtype.itemsize):.0f} "
          f"B/point streamed vs "
          f"{bytes_per_point('replicate', a.dtype.itemsize):.0f} replicated")

    t0 = time.perf_counter()
    out = stencil_apply(a, w, "stencil27", block_i=bi)   # streams by default
    ref = stencil_ref(a, w, "stencil27")
    err = float(jnp.max(jnp.abs(out - ref)))
    errp = float(jnp.max(jnp.abs(
        stencil_apply(a, w, "stencil27", block_i=bi, path="replicate")
        - out)))
    # f32 stream-vs-replicate is tolerance-level (bit-exact only for
    # f64/integer data -- per-program fma contraction, see plan.py)
    print(f"[engine] 27-point interpret run {time.perf_counter()-t0:.2f}s, "
          f"max err vs jnp oracle = {err:.2e}, streamed-vs-replicated = "
          f"{errp:.2e} ({'OK' if err < 1e-4 and errp < 1e-5 else 'FAIL'})")

    # Batched + fused: 3 Jacobi sweeps in ONE pallas_call (1 HBM round-trip).
    ab = jnp.asarray(rng.standard_normal((2, 16, 24, 128)), jnp.float32)
    t0 = time.perf_counter()
    fused = stencil_apply(ab, w, "stencil27", block_i=4, sweeps=3)
    errf = float(jnp.max(jnp.abs(
        fused - stencil_ref(ab, w, "stencil27", sweeps=3))))
    print(f"[engine] batched(2) fused s=3 run {time.perf_counter()-t0:.2f}s, "
          f"max err = {errf:.2e} ({'OK' if errf < 1e-4 else 'FAIL'})")

    # Radius-2: the 4th-order Laplacian star through the same engine -- the
    # factored plan reuses per-distance pair sums; streaming still moves
    # ~2 bytes/point where the replicated path would pay 6.
    from repro.kernels import compile_plan
    p13 = compile_plan("star13")
    w13 = jnp.asarray([-7.5, 4.0 / 3.0, -1.0 / 12.0], jnp.float32)
    out13 = stencil_apply(a, w13, "star13", block_i=bi)
    err13 = float(jnp.max(jnp.abs(out13 - stencil_ref(a, w13, "star13"))))
    print(f"[engine] radius-2 'star13' (4th-order Laplacian): plan "
          f"{p13.shifts} shifts + {p13.flops} flops (direct: "
          f"{compile_plan('star13', 'direct').shifts} + "
          f"{compile_plan('star13', 'direct').flops}), "
          f"{bytes_per_point('stream', 4, radius=2):.0f} vs "
          f"{bytes_per_point('replicate', 4, radius=2):.0f} B/point, "
          f"max err = {err13:.2e} ({'OK' if err13 < 1e-3 else 'FAIL'})")

    # Temporal wavefront tiling: s sweeps in one pass over the i-blocks --
    # each input plane fetched from HBM once per s applications (modeled
    # 2*itemsize/s bytes/point vs 2*itemsize per chained call), with the
    # fused call and s chained calls as the raced alternatives.
    from repro.kernels import (autotune_sweeps, stencil_sweep_driver,
                               stencil_wavefront)
    s = 4
    m, n, p = a.shape
    sel = autotune_sweeps(m, n, p, a.dtype.itemsize, s,
                          compile_plan("stencil27"))
    t0 = time.perf_counter()
    wavef = stencil_sweep_driver(a, w, "stencil27", sweeps=s)
    chain = a
    for _ in range(s):
        chain = stencil_apply(chain, w, "stencil27", block_i=bi, sweeps=1)
    errw = float(jnp.max(jnp.abs(wavef - chain)))
    cands = {c["mode"]: c["bytes_per_point"]
             for c in sel.describe()["selection"]["candidates"]}
    print(f"[engine] temporal wavefront s={s}: autotuner picks "
          f"{sel.mode!r} (modeled B/point: "
          + ", ".join(f"{mo}={bpp:.1f}" for mo, bpp in sorted(cands.items()))
          + f"), run {time.perf_counter()-t0:.2f}s, max err vs chained = "
          f"{errw:.2e} ({'OK' if errw < 1e-4 else 'FAIL'})")

    # Red-black Gauss-Seidel ordering: checkerboard half-sweeps (the
    # smoother workloads' ordering), same engine, doubled effective halo.
    wrb = stencil_wavefront(a, w, "stencil27_redblack", sweeps=2)
    errrb = float(jnp.max(jnp.abs(
        wrb - stencil_ref(a, w, "stencil27_redblack", sweeps=2))))
    print(f"[engine] red-black Gauss-Seidel s=2 through the wavefront, "
          f"max err vs oracle = {errrb:.2e} "
          f"({'OK' if errrb < 1e-4 else 'FAIL'})")

    # Custom mask: an i-j cross (5 taps) nobody hand-wrote a kernel for.
    mask = -np.ones((3, 3, 3), np.int64)
    mask[1, 1, 1] = 0
    for di, dj in ((-1, 0), (1, 0), (0, -1), (0, 1)):
        mask[1 + di, 1 + dj, 1] = 1
    cross = spec_from_mask("cross5", mask)
    wc = jnp.asarray([1.0, -0.25], jnp.float32)
    out5 = stencil_apply(a, wc, cross, block_i=bi)
    err5 = float(jnp.max(jnp.abs(out5 - stencil_ref(a, wc, cross))))
    print(f"[engine] custom mask '{cross.name}' ({cross.taps} taps), "
          f"max err = {err5:.2e} ({'OK' if err5 < 1e-4 else 'FAIL'})")

    if jax.device_count() > 1:
        sh = stencil_sharded(a, w, "stencil27", sweeps=2)
        errs = float(jnp.max(jnp.abs(
            sh - stencil_apply(a, w, "stencil27", block_i=bi, sweeps=2))))
        print(f"[engine] sharded over {jax.device_count()} devices (halo "
              f"exchange, s=2), max err vs single = {errs:.2e} "
              f"({'OK' if errs < 1e-4 else 'FAIL'})")
    else:
        print("[engine] 1 device: skipping sharded demo (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=2 to see it)")

    flops = 27 * 2 * (a.shape[0] - 2) * (a.shape[1] - 2) * (a.shape[2] - 2)
    bytes_moved = 2 * a.size * 4
    print(f"[engine] arithmetic intensity {flops / bytes_moved:.1f} flop/B; "
          f"TPU v5e roofline: {min(197e12, 819e9 * flops / bytes_moved)/1e12:.1f}"
          f" TFLOP/s upper bound (VPU-bound in practice; see stencil_mxu"
          f" hillclimb in EXPERIMENTS.md)")


if __name__ == "__main__":
    main()
