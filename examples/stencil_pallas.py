"""The paper's 27-point stencil as a Pallas TPU kernel (interpret mode here).

Shows the TPU adaptation: the jam factor became the VMEM i-block, the SIMD
pair became the 128-lane axis, and the block autotuner plays the role of the
paper's performance model.

Run:  PYTHONPATH=src python examples/stencil_pallas.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import stencil27, stencil27_ref
from repro.kernels._stencil_common import pick_block_i


def main() -> None:
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((32, 48, 128)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, (2, 2, 2)), jnp.float32)

    bi = pick_block_i(*a.shape, a.dtype.itemsize)
    print(f"[pallas] grid {a.shape}, model-chosen i-block = {bi} "
          f"(VMEM budget heuristic, cf. paper Table 2 reasoning)")

    t0 = time.perf_counter()
    out = stencil27(a, w, block_i=bi)
    ref = stencil27_ref(a, w)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"[pallas] interpret-mode run {time.perf_counter()-t0:.2f}s, "
          f"max err vs jnp oracle = {err:.2e} ({'OK' if err < 1e-4 else 'FAIL'})")

    flops = 27 * 2 * (a.shape[0] - 2) * (a.shape[1] - 2) * (a.shape[2] - 2)
    bytes_moved = 2 * a.size * 4
    print(f"[pallas] arithmetic intensity {flops / bytes_moved:.1f} flop/B; "
          f"TPU v5e roofline: {min(197e12, 819e9 * flops / bytes_moved)/1e12:.1f}"
          f" TFLOP/s upper bound (VPU-bound in practice; see stencil_mxu"
          f" hillclimb in EXPERIMENTS.md)")


if __name__ == "__main__":
    main()
