"""End-to-end training driver: a ~100M-param qwen-family LM for a few
hundred steps with checkpoint/restart, on whatever devices are available.

The same driver scales to the production mesh (launch/train.py); on this CPU
container a reduced width keeps the wall-clock sane -- pass --full-width on
real hardware.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced_config
from repro.data import SyntheticDataset
from repro.models import build_model, param_count
from repro.models.common import ShapeConfig
from repro.optim import adamw, warmup_cosine
from repro.runtime import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-width", action="store_true",
                    help="the real qwen2-0.5b config (use on TPU)")
    args = ap.parse_args()

    if args.full_width:
        cfg = get_config("qwen2-0.5b")
    else:
        # ~linear scale-down of qwen2-0.5b that keeps the topology
        cfg = dataclasses.replace(
            get_config("qwen2-0.5b"), n_layers=4, d_model=448, n_heads=7,
            n_kv_heads=1, d_ff=1536, vocab_size=8192, dtype=jnp.float32)
    model = build_model(cfg)
    print(f"[example] {cfg.name}: {param_count(cfg)/1e6:.0f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    ds = SyntheticDataset(cfg, ShapeConfig("ex", args.seq, args.batch,
                                           "train"), seed=0)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tc = TrainConfig(steps=args.steps, ckpt_every=max(50, args.steps // 4),
                         ckpt_dir=ckpt_dir, log_every=max(1, args.steps // 10))
        trainer = Trainer(model, adamw(),
                          warmup_cosine(3e-4, args.steps // 10, args.steps),
                          tc, ds)
        trainer.run(jax.random.PRNGKey(0))
        for m in trainer.metrics_log:
            print(f"[example] step {m['step']:5d} loss {m['loss']:.4f}")
        first, last = trainer.metrics_log[0], trainer.metrics_log[-1]
        print(f"[example] loss {first['loss']:.3f} -> {last['loss']:.3f} "
              f"({'improved' if last['loss'] < first['loss'] else 'FLAT'}); "
              f"median step {trainer.timer.median*1e3:.0f} ms")


if __name__ == "__main__":
    main()
