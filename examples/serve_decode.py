"""Batched serving example: prefill + streaming decode with caches, across
three architecture families (KV-cache dense, SSM state, hybrid).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.launch.serve import generate
from repro.models import build_model


def main() -> None:
    rng = np.random.default_rng(0)
    for aid in ("qwen1.5-0.5b", "falcon-mamba-7b", "zamba2-7b"):
        cfg = dataclasses.replace(get_reduced_config(aid), dtype=jnp.float32)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 16)),
                              jnp.int32)
        t0 = time.perf_counter()
        out = generate(model, params, prompts, gen=12)
        dt = time.perf_counter() - t0
        print(f"[serve] {aid:18s} batch=4 prompt=16 gen=12 "
              f"({dt:.1f}s) first row: {np.asarray(out[0])[:8]}")


if __name__ == "__main__":
    main()
