"""Quickstart: the paper's pipeline end-to-end in ~40 lines.

Synthesize a 27-point stencil kernel (mutate-mutate, 2x3 unroll-and-jam),
schedule it for the PPC450, verify the scheduled code against numpy, and
print the performance prediction next to the paper's published numbers --
then render the inline-assembly C the paper's framework would emit.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.codegen import allocate_registers, render_c
from repro.core.perfmodel import PAPER_TABLE3, analyze
from repro.core.scheduler import greedy_schedule
from repro.core.synth import StencilConfig, synth_stencil
from repro.core.verify import run_kernel


def main() -> None:
    cfg = StencilConfig(points=27, kernel="mm", ui=2, uj=3)
    kern = synth_stencil(cfg)
    c = kern.counts
    print(f"synthesized {cfg.name}: {len(kern.body)} instructions/iteration "
          f"({c.loads} loads, {c.stores} stores, {c.fpu} FPU ops, "
          f"{c.input_regs}+{c.result_regs}+{c.weight_regs} registers)")

    sched = greedy_schedule(kern.single_step)
    print(f"scheduled: makespan {sched.makespan} cycles "
          f"(lower bound {sched.lower_bound}, "
          f"optimal={'yes' if sched.optimal else 'within bound'})")

    result = run_kernel(cfg, t_iters=6)
    print(f"verified vs numpy oracle: ok={result.ok} "
          f"max_err={result.max_abs_err:.2e}")

    est = analyze(cfg)
    paper = PAPER_TABLE3[cfg.name]
    print(f"predicted in-L1:   {est.predicted_l1:7.2f} Mstencil/s "
          f"(paper observed {paper[5]})")
    print(f"predicted stream:  {est.predicted_streaming:7.2f} Mstencil/s "
          f"(paper observed {paper[7]})")
    print(f"fraction of arithmetic peak: {est.predicted_l1 / 62.96:.1%} "
          f"(paper: 85%)")

    small = synth_stencil(StencilConfig(27, "mm", 1, 1))
    s = greedy_schedule(small.body)
    src = render_c([small.body[i] for i in s.order], name="stencil27_mm_1x1")
    print("\n--- generated C (first 18 lines) ---")
    print("\n".join(src.splitlines()[:18]))


if __name__ == "__main__":
    main()
